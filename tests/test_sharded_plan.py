"""Mesh-aware sharded SpGEMM plans.

Two layers of coverage:

* the panel-schedule partitioner (pure numpy) is tested in-process:
  slice/rebase reconstruction, triple-count balance on the paper
  matrices, ragged and empty shards, validation;
* sharded ``execute``/``execute_batch`` are tested against the
  single-device plan under 8 forced host devices via the subprocess-safe
  ``forced_devices`` fixture (XLA device count must be set before jax
  import — see tests/conftest.py).
"""
import numpy as np
import pytest

from repro.core.schedule import (
    build_spgemm_schedule,
    partition_spgemm_schedule,
)
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo
from repro.sparse.formats import COO
from repro.sparse.random import random_coo, suite_matrix


def _paper_schedule(name, scale, tile=16, group=2):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0])).sum_duplicates()
    a_bcsv, _ = bcsv_from_coo(a, (tile, tile), group)
    b_bcsr, _ = bcsr_from_coo(b, (tile, tile))
    return build_spgemm_schedule(a_bcsv, b_bcsr)


class TestPartitioner:
    @pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 8])
    def test_slices_reconstruct_parent(self, n_shards):
        """Every shard is a contiguous rebased slice: concatenating the
        shards (with offsets restored) reproduces the parent schedule."""
        coo = random_coo(200, 160, 0.05, "uniform", seed=3)
        b = COO(coo.col, coo.row, coo.val, (160, 200))
        a_bcsv, _ = bcsv_from_coo(coo, (8, 8), 2)
        b_bcsr, _ = bcsr_from_coo(b, (8, 8))
        sch = build_spgemm_schedule(a_bcsv, b_bcsr)
        shards = partition_spgemm_schedule(sch, n_shards)
        assert len(shards) == n_shards
        assert np.array_equal(
            np.concatenate([s.schedule.a_slot + s.a_lo for s in shards]),
            sch.a_slot)
        assert np.array_equal(
            np.concatenate([s.schedule.b_slot for s in shards]), sch.b_slot)
        assert np.array_equal(
            np.concatenate([s.schedule.panel + s.panel_lo for s in shards]),
            sch.panel)
        assert np.array_equal(
            np.concatenate([s.schedule.sub_row for s in shards]),
            sch.sub_row)
        assert np.array_equal(
            np.concatenate(
                [s.schedule.c_brow + s.group_lo * sch.group for s in shards]),
            sch.c_brow)
        assert np.array_equal(
            np.concatenate([s.schedule.c_bcol for s in shards]), sch.c_bcol)
        # Ranges tile the parent contiguously.
        for prev, cur in zip(shards, shards[1:]):
            assert prev.group_hi == cur.group_lo
            assert prev.triple_hi == cur.triple_lo
            assert prev.panel_hi == cur.panel_lo
        assert shards[0].triple_lo == 0
        assert shards[-1].triple_hi == sch.num_triples

    @pytest.mark.parametrize(
        "name,scale",
        [("poisson3Da", 0.05), ("2cubes_sphere", 0.01), ("cage12", 0.01),
         ("offshore", 0.005)],
    )
    def test_triple_balance_on_paper_matrices(self, name, scale):
        """Acceptance: max/mean triple-count imbalance <= 1.25 at 2/4/8
        shards on the (scaled) paper patterns."""
        sch = _paper_schedule(name, scale)
        for n in (2, 4, 8):
            t = np.array([
                s.num_triples for s in partition_spgemm_schedule(sch, n)
            ])
            assert t.sum() == sch.num_triples
            imbalance = t.max() / t.mean()
            assert imbalance <= 1.25, (name, n, imbalance, t.tolist())

    def test_more_shards_than_groups_yields_empty_shards(self):
        sch = _paper_schedule("poisson3Da", 0.004)
        n_groups = -(-sch.grid_m // sch.group)
        shards = partition_spgemm_schedule(sch, n_groups + 5)
        empty = [s for s in shards if s.num_triples == 0]
        assert empty, "expected empty shards"
        for s in empty:
            assert s.n_panels == 0
            assert s.schedule.nnzb_c == 0
            assert s.a_lo == s.a_hi
        assert sum(s.num_triples for s in shards) == sch.num_triples

    def test_validation(self):
        sch = _paper_schedule("poisson3Da", 0.004)
        with pytest.raises(ValueError, match="n_shards"):
            partition_spgemm_schedule(sch, 0)


SHARDED_VS_SINGLE = """
import numpy as np
import jax
from repro.sparse.random import suite_matrix
from repro.sparse.formats import COO
from repro.launch.mesh import make_shard_mesh
from repro.spgemm import PlanCache, ShardedSpGEMMPlan, spgemm_plan

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.default_rng(0)
for name, scale in (("poisson3Da", 0.004), ("scircuit", 0.004),
                    ("cage12", 0.004)):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    # Small-integer values: exact in float32 under any accumulation
    # order, so single- vs multi-device results must be bitwise equal.
    v = rng.integers(-4, 5, a.nnz).astype(np.float32)
    a.val = np.where(v == 0, np.float32(1.0), v)
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
    single = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                         cache=PlanCache())
    c0 = single.execute()
    # Canonical (row-major pattern order) value vectors — b.val is in
    # A^T coordinate order, which is NOT B's canonical order.
    av0 = single.a_pattern.val
    bv0 = single.b_pattern.val
    av = rng.integers(-3, 4, (3, a.nnz)).astype(np.float32)
    bv = rng.integers(-3, 4, (3, b.nnz)).astype(np.float32)
    cb0 = single.execute_batch(av, bv)
    for n in (1, 2, 4, 8):
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache(), mesh=make_shard_mesh(n))
        assert isinstance(plan, ShardedSpGEMMPlan)
        stats = plan.shard_stats()
        assert stats["n_shards"] == n
        # jnp path acceptance: bitwise-equal CSR pattern AND data.
        c = plan.execute()
        assert np.array_equal(c.indptr, c0.indptr), (name, n)
        assert np.array_equal(c.indices, c0.indices), (name, n)
        assert np.array_equal(c.data, c0.data), (name, n)
        # Fused fresh-values path (A row-sharded, B replicated).
        c1 = plan.execute(av0 * 2.0, bv0)
        c1s = single.execute(av0 * 2.0, bv0)
        assert np.array_equal(c1.data, c1s.data), (name, n, "values")
        # Batched path: one shard_map call, chunked like the single plan.
        cb = plan.execute_batch(av, bv)
        for i in range(3):
            assert np.array_equal(cb[i].data, cb0[i].data), (name, n, i)
            assert np.array_equal(cb[i].indptr, cb0[i].indptr)
        # execute_batch never reads staged values: works after release.
        plan.release_values()
        cr = plan.execute_batch(av0[None], bv0[None])
        assert np.array_equal(cr[0].data, c0.data), (name, n, "released")
    print(name, "OK")
print("SHARDED_MATCH_OK")
"""


RAGGED_EMPTY_BLOCK = """
import numpy as np
import jax
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse, random_coo
from repro.sparse.formats import COO
from repro.launch.mesh import make_shard_mesh
from repro.spgemm import PlanCache, spgemm_plan

assert len(jax.devices()) == 8

# Ragged: 5 block-row groups over 2/4 shards (panel counts indivisible),
# and empty shards: 8 shards over 3 groups.
rng = np.random.default_rng(1)
coo = random_coo(77, 63, 0.09, "uniform", seed=11)  # 10 brows @8 / g2 -> 5
v = rng.integers(-4, 5, coo.nnz).astype(np.float32)
coo.val = np.where(v == 0, np.float32(1.0), v)
b = COO(coo.col, coo.row, coo.val, (63, 77))
single = spgemm_plan(coo, b, tile=8, group=2, backend="jnp",
                     cache=PlanCache())
c0 = single.execute()
for n in (2, 4, 8):
    plan = spgemm_plan(coo, b, tile=8, group=2, backend="jnp",
                       cache=PlanCache(), mesh=make_shard_mesh(n))
    if n == 8:
        assert 0 in plan.shard_stats()["triples"], "expected an empty shard"
    c = plan.execute()
    assert np.array_equal(c.indptr, c0.indptr), n
    assert np.array_equal(c.indices, c0.indices), n
    assert np.array_equal(c.data, c0.data), n

# Block (BCSV/BCSR) plans shard over packed block slices.
ad = random_block_sparse(96, 96, (16, 16), 0.4, seed=21)
bd = random_block_sparse(96, 96, (16, 16), 0.4, seed=22)
ab, bb = to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16))
sb = spgemm_plan(ab, bb, backend="jnp", cache=PlanCache())
c0 = sb.execute()
for n in (2, 8):
    plan = spgemm_plan(ab, bb, backend="jnp", cache=PlanCache(),
                       mesh=make_shard_mesh(n))
    c = plan.execute()
    assert np.array_equal(c.data, c0.data), n
    av = np.stack([ab.blocks, ab.blocks * 2.0])
    bv = np.stack([bb.blocks, bb.blocks])
    cb = plan.execute_batch(av, bv)
    cbs = sb.execute_batch(av, bv)
    assert np.array_equal(cb[0].data, cbs[0].data)
    assert np.array_equal(cb[1].data, cbs[1].data)

# Cache key includes the mesh axis: same pattern, different shard counts
# and the single-device plan coexist; pattern-equal sharded calls hit.
cache = PlanCache()
m4 = make_shard_mesh(4)
p1 = spgemm_plan(ab, bb, backend="jnp", cache=cache, mesh=m4)
p2 = spgemm_plan(ab, bb, backend="jnp", cache=cache, mesh=m4)
p3 = spgemm_plan(ab, bb, backend="jnp", cache=cache)
p4 = spgemm_plan(ab, bb, backend="jnp", cache=cache,
                 mesh=make_shard_mesh(2))
assert p1 is p2 and p1 is not p3 and p1 is not p4
assert cache.stats.hits == 1 and cache.stats.misses == 3
s = cache.stats()
assert s["resident_plans"] == 3 and s["resident_bytes"] > 0
print("RAGGED_EMPTY_BLOCK_OK")
"""


class TestShardedExecution:
    def test_matches_single_device_on_paper_matrices(self, forced_devices):
        """Acceptance: sharded execute/execute_batch bitwise-equal (jnp
        path) to the single-device plan at 1/2/4/8 shards."""
        out = forced_devices(SHARDED_VS_SINGLE, devices=8)
        assert "SHARDED_MATCH_OK" in out

    def test_ragged_empty_and_block_paths(self, forced_devices):
        out = forced_devices(RAGGED_EMPTY_BLOCK, devices=8)
        assert "RAGGED_EMPTY_BLOCK_OK" in out

    def test_single_device_mesh_works_without_forced_devices(self):
        """A 1-device mesh shards trivially in the normal test process."""
        from repro.launch.mesh import make_shard_mesh
        from repro.spgemm import PlanCache, ShardedSpGEMMPlan, spgemm_plan

        coo = random_coo(60, 50, 0.1, "uniform", seed=5)
        rng = np.random.default_rng(6)
        v = rng.integers(-4, 5, coo.nnz).astype(np.float32)
        coo.val = np.where(v == 0, np.float32(1.0), v)
        b = COO(coo.col, coo.row, coo.val, (50, 60))
        single = spgemm_plan(coo, b, tile=8, group=2, backend="jnp",
                             cache=PlanCache())
        plan = spgemm_plan(coo, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(), mesh=make_shard_mesh(1))
        assert isinstance(plan, ShardedSpGEMMPlan)
        assert np.array_equal(
            plan.execute().todense(), single.execute().todense())
        assert plan.shard_stats()["imbalance"] == 1.0
