"""OMAR (paper Eq. 1) + buffering-scheme tests."""
import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.core.buffering import (
    b_fetch_trace,
    block_b_fetch_trace,
    block_omar,
    omar,
    omar_from_trace,
)
from repro.core.schedule import build_spgemm_schedule
from repro.sparse.convert import to_bcsr, to_bcsv, to_csr, to_csv
from repro.sparse.random import random_coo, random_block_sparse, suite_matrix


class TestOMAR:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2000), num_pe=st.integers(1, 32))
    def test_eq1_equals_fetch_trace(self, seed, num_pe):
        """Eq. 1 and the actual fetch-trace count must agree exactly."""
        a = to_csr(random_coo(30, 24, 0.15, "uniform", seed=seed))
        assert omar(a, num_pe) == pytest.approx(omar_from_trace(a, num_pe))

    def test_omar_monotone_in_num_pe(self):
        """Fig. 6: OMAR monotonically improves with the number of PEs."""
        a = suite_matrix("scircuit", scale=0.01)
        vals = [omar(a, p) for p in (1, 2, 4, 8, 16, 32)]
        assert vals == sorted(vals)
        assert vals[0] == 0.0  # one row per group -> no sharing

    def test_omar_bounds(self):
        a = to_csr(random_coo(50, 50, 0.1, "uniform", seed=3))
        for p in (1, 4, 64):
            v = omar(a, p)
            assert 0.0 <= v < 100.0

    def test_dense_column_best_case(self):
        """A matrix whose nonzeros share one column: with all rows in one
        group, every fetch after the first is saved."""
        a = np.zeros((8, 8), np.float32)
        a[:, 3] = 1.0
        assert omar(to_csr(a), 8) == pytest.approx(100.0 * 7 / 8)

    def test_fetch_trace_contents(self):
        a = np.zeros((4, 6), np.float32)
        a[0, 2] = a[1, 2] = a[0, 4] = a[3, 1] = 1.0
        # groups of 2: g0 rows {0,1}, g1 rows {2,3}
        trace = b_fetch_trace(to_csr(a), 2)
        # g0: col 2 (shared by rows 0,1), col 4; g1: col 1.
        assert trace.tolist() == [2, 4, 1]


class TestBlockOMAR:
    @pytest.mark.parametrize("group", [1, 2, 4])
    def test_block_omar_matches_schedule(self, group):
        ad = random_block_sparse(128, 96, (16, 16), 0.3, seed=5)
        bd = random_block_sparse(96, 128, (16, 32), 0.4, seed=6)
        a = to_bcsv(ad, (16, 16), group=group)
        b = to_bcsr(bd, (16, 32))
        sched = build_spgemm_schedule(a, b)
        # The schedule's B-fetch elision can only improve on the format-
        # level bound (the schedule also reuses across the j loop).
        assert sched.b_fetches() <= max(sched.num_triples, 1)
        assert 0.0 <= sched.block_omar() <= 100.0

    def test_block_trace_len_equals_distinct_runs(self):
        ad = random_block_sparse(64, 64, (16, 16), 0.5, seed=9)
        a = to_bcsv(ad, (16, 16), group=2)
        trace = block_b_fetch_trace(a)
        assert 0.0 <= block_omar(a) < 100.0
        assert trace.shape[0] + int(
            block_omar(a) / 100.0 * a.nnzb + 0.5) == a.nnzb
