"""Multi-tenant serving gateway semantics.

The load-bearing invariants, in order of importance:

1. **Bitwise equality** — every admitted request's result equals a
   direct ``plan.execute`` of the same values, regardless of how it was
   micro-batched or interleaved with other tenants.
2. **Typed overload** — queue-full / byte-budget / cache-pressure /
   closed conditions resolve tickets with shed outcomes; they never hang
   and never raise out of the scheduler.
3. **Fairness** — a hot tenant's backlog cannot starve a cold tenant
   (deficit round-robin by pending value bytes).
4. **Pin guard** — pool eviction never tears down a pipeline with
   in-flight tickets.

Sharded coverage runs under 8 forced host devices via the
subprocess-safe ``forced_devices`` fixture (tests/conftest.py).
"""
import threading
import time

import numpy as np
import pytest

from repro.data.pipeline import SpGEMMValueStream
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse, random_coo
from repro.spgemm import (
    GatewayShed,
    Outcome,
    PlanCache,
    SpGEMMGateway,
    SpGEMMPipeline,
)

WAIT = 120  # generous per-ticket timeout: CPU jit compiles per batch size


def _patterns(seed=0, m=96, k=72, n=80, density=0.06):
    a = random_coo(m, k, density, "uniform", seed=seed).sum_duplicates()
    b = random_coo(k, n, density, "uniform", seed=seed + 1).sum_duplicates()
    return a, b


def _gateway(**kw):
    kw.setdefault("cache", PlanCache())
    return SpGEMMGateway(**kw)


def _assert_same_csr(x, y):
    assert np.array_equal(x.indptr, y.indptr)
    assert np.array_equal(x.indices, y.indices)
    assert np.array_equal(x.data, y.data)


class TestResults:
    def test_bitwise_equal_direct_execute_two_patterns(self):
        gw = _gateway(max_pipelines=2, depth=2, max_batch=4,
                      batch_window=0.002)
        p0 = gw.register("p0", *_patterns(0), tile=8, group=2, backend="jnp")
        p1 = gw.register("p1", *_patterns(4, m=64, k=64, n=64, density=0.08),
                         tile=8, group=2, backend="jnp")
        s0 = SpGEMMValueStream(p0.a_pattern, p0.b_pattern, seed=7)
        s1 = SpGEMMValueStream(p1.a_pattern, p1.b_pattern, seed=8)
        tickets = []
        for s in range(8):
            tickets.append(("p0", s, gw.submit("p0", *s0.values_at(s))))
            tickets.append(("p1", s, gw.submit("p1", *s1.values_at(s))))
        results = [(tok, s, t.wait(timeout=WAIT)) for tok, s, t in tickets]
        gw.close()
        assert all(r.outcome is Outcome.OK for _, _, r in results)
        for tok, s, r in results:
            plan, st = (p0, s0) if tok == "p0" else (p1, s1)
            _assert_same_csr(plan.execute(*st.values_at(s)), r.value)

    def test_micro_batching_fills_batches(self):
        """A burst queued before the scheduler starts dispatches as full
        micro-batches: fill == max_batch, dispatches == burst/max_batch."""
        gw = _gateway(max_batch=4, start=False)
        plan = gw.register("p", *_patterns(0), tile=8, group=2,
                           backend="jnp")
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        tickets = [gw.submit("p", *st.values_at(s)) for s in range(8)]
        gw.start()
        assert all(t.wait(WAIT).outcome is Outcome.OK for t in tickets)
        stats = gw.stats()["patterns"]["p"]
        gw.close()
        assert stats["dispatches"] == 2
        assert stats["batched_requests"] == 8
        assert stats["batch_fill"] == 4.0

    def test_block_plan_requests(self):
        """Packed-block operands flow through the same queue/batch path."""
        ad = random_block_sparse(128, 128, (32, 32), 0.3, seed=3)
        bd = random_block_sparse(128, 128, (32, 32), 0.3, seed=4)
        cache = PlanCache()
        from repro.spgemm import spgemm_plan

        plan = spgemm_plan(to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 32)),
                           backend="jnp", cache=cache)
        gw = _gateway(cache=cache, max_batch=2)
        gw.register_plan("blk", plan)
        rng = np.random.default_rng(0)
        wa, wb = plan.value_shapes()
        sets = [
            (rng.standard_normal(wa).astype(np.float32),
             rng.standard_normal(wb).astype(np.float32))
            for _ in range(3)
        ]
        tickets = [gw.submit("blk", a, b) for a, b in sets]
        results = [t.wait(WAIT) for t in tickets]
        gw.close()
        assert all(r.outcome is Outcome.OK for r in results)
        for (a, b), r in zip(sets, results):
            _assert_same_csr(plan.execute(a, b), r.value)

    def test_ticket_api_and_validation(self):
        gw = _gateway(start=False)
        plan = gw.register("p", *_patterns(0), tile=8, group=2,
                           backend="jnp")
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        with pytest.raises(KeyError):
            gw.submit("nope", *st.values_at(0))
        with pytest.raises(ValueError):
            gw.submit("p", np.zeros(3, np.float32), np.zeros(3, np.float32))
        t = gw.submit("p", *st.values_at(0))
        assert not t.done()
        with pytest.raises(TimeoutError):
            t.wait(timeout=0.01)
        gw.start()
        res = t.wait(WAIT)
        assert res.outcome is Outcome.OK and res.latency_s > 0
        assert t.result() is res.value  # resolved: no blocking, no raise
        gw.close()

    def test_duplicate_registration(self):
        gw = _gateway(start=False)
        a, b = _patterns(0)
        plan = gw.register("p", a, b, tile=8, group=2, backend="jnp")
        assert gw.register("p", a, b, tile=8, group=2, backend="jnp") is plan
        other = gw.register("q", *_patterns(4), tile=8, group=2,
                            backend="jnp")
        with pytest.raises(ValueError):
            gw.register_plan("p", other)
        gw.close()


class TestBackpressure:
    def test_queue_full_sheds_typed(self):
        gw = _gateway(max_queue=2, start=False)
        plan = gw.register("p", *_patterns(0), tile=8, group=2,
                           backend="jnp")
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        tickets = [gw.submit("p", *st.values_at(s)) for s in range(5)]
        shed = [t for t in tickets if t.done()]
        assert len(shed) == 3
        assert all(
            t.wait(0).outcome is Outcome.SHED_QUEUE_FULL for t in shed
        )
        with pytest.raises(GatewayShed) as ei:
            shed[0].result()
        assert ei.value.outcome is Outcome.SHED_QUEUE_FULL
        gw.start()
        for t, s in zip(tickets[:2], range(2)):  # admitted work completes
            res = t.wait(WAIT)
            assert res.outcome is Outcome.OK
            _assert_same_csr(plan.execute(*st.values_at(s)), res.value)
        stats = gw.stats()["patterns"]["p"]
        gw.close()
        assert stats["shed"]["shed_queue_full"] == 3
        assert stats["shed_total"] == 3

    def test_byte_budget_sheds_not_hangs(self):
        a, b = _patterns(0)
        cache = PlanCache()
        from repro.spgemm import spgemm_plan

        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache)
        nb = plan.value_nbytes()
        gw = _gateway(cache=cache, max_inflight_bytes=3 * nb + 16,
                      start=False)
        gw.register_plan("p", plan)
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        tickets = [gw.submit("p", *st.values_at(s)) for s in range(6)]
        outcomes = [t.wait(0).outcome if t.done() else None for t in tickets]
        assert outcomes.count(Outcome.SHED_BYTES) == 3
        gw.start()
        done = [t.wait(WAIT) for t in tickets]
        gw.close()
        ok = [r for r in done if r.outcome is Outcome.OK]
        assert len(ok) == 3  # every admitted request resolved OK
        for s, r in enumerate(done[:3]):
            _assert_same_csr(plan.execute(*st.values_at(s)), r.value)

    def test_cache_pressure_sheds(self):
        cache = PlanCache(max_bytes=1)  # any plan overflows: newest kept
        gw = _gateway(cache=cache, start=False)
        plan = gw.register("p", *_patterns(0), tile=8, group=2,
                           backend="jnp")
        assert cache.over_budget
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        t = gw.submit("p", *st.values_at(0))
        assert t.wait(0).outcome is Outcome.SHED_CACHE_PRESSURE
        gw.close()

    def test_close_without_drain_sheds_queued(self):
        gw = _gateway(start=False)
        plan = gw.register("p", *_patterns(0), tile=8, group=2,
                           backend="jnp")
        st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        tickets = [gw.submit("p", *st.values_at(s)) for s in range(3)]
        gw.close(drain=False)
        assert all(
            t.wait(0).outcome is Outcome.SHED_CLOSED for t in tickets
        )
        t = gw.submit("p", *st.values_at(9))  # post-close submit: shed too
        assert t.wait(0).outcome is Outcome.SHED_CLOSED

    def test_context_manager_drains(self):
        with _gateway(max_batch=4) as gw:
            plan = gw.register("p", *_patterns(0), tile=8, group=2,
                               backend="jnp")
            st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
            tickets = [gw.submit("p", *st.values_at(s)) for s in range(4)]
        assert all(t.wait(0).outcome is Outcome.OK for t in tickets)


class TestFairness:
    def test_hot_tenant_cannot_starve_cold(self):
        """32 hot requests queued ahead of 2 cold ones: DRR by bytes must
        complete the cold pattern long before the hot backlog drains."""
        gw = _gateway(max_pipelines=2, max_batch=4, batch_window=0.0,
                      start=False)
        hot = gw.register("hot", *_patterns(0), tile=8, group=2,
                          backend="jnp")
        cold = gw.register("cold", *_patterns(4), tile=8, group=2,
                           backend="jnp")
        sh = SpGEMMValueStream(hot.a_pattern, hot.b_pattern, seed=7)
        sc = SpGEMMValueStream(cold.a_pattern, cold.b_pattern, seed=8)
        hot_t = [gw.submit("hot", *sh.values_at(s)) for s in range(32)]
        cold_t = [gw.submit("cold", *sc.values_at(s)) for s in range(2)]
        gw.start()
        hot_seq = [t.wait(WAIT).seq for t in hot_t]
        cold_seq = [t.wait(WAIT).seq for t in cold_t]
        stats = gw.stats()
        gw.close()
        # Cold completes within the first rounds, not after the backlog.
        assert max(cold_seq) < 0.5 * max(hot_seq), (cold_seq, max(hot_seq))
        assert stats["patterns"]["hot"]["completed"] == 32
        assert stats["patterns"]["cold"]["completed"] == 2
        assert stats["patterns"]["hot"]["throughput_rps"] > 0
        assert stats["patterns"]["cold"]["latency_s"]["p99"] > 0


class TestPipelinePool:
    def test_pool_eviction_bounded_and_counted(self):
        gw = _gateway(max_pipelines=1, max_batch=2, batch_window=0.0)
        pA = gw.register("A", *_patterns(0), tile=8, group=2, backend="jnp")
        pB = gw.register("B", *_patterns(4), tile=8, group=2, backend="jnp")
        sA = SpGEMMValueStream(pA.a_pattern, pA.b_pattern, seed=7)
        sB = SpGEMMValueStream(pB.a_pattern, pB.b_pattern, seed=8)
        tickets = []
        for s in range(6):
            tickets.append(gw.submit("A", *sA.values_at(s)))
            tickets.append(gw.submit("B", *sB.values_at(s)))
        assert all(t.wait(WAIT).outcome is Outcome.OK for t in tickets)
        stats = gw.stats()
        gw.close()
        assert stats["pipelines_live"] <= 1
        assert stats["pipeline_evictions"] >= 1

    def test_eviction_never_tears_down_inflight_pipeline(self):
        """The PR-5 pin guard at gateway level: with the pool exhausted by
        a busy pipeline, another pattern's work WAITS — the busy
        pipeline's ticket stays collectable, nothing is discarded."""
        gw = _gateway(max_pipelines=1, batch_window=0.0, start=False)
        pA = gw.register("A", *_patterns(0), tile=8, group=2, backend="jnp")
        pB = gw.register("B", *_patterns(4), tile=8, group=2, backend="jnp")
        sA = SpGEMMValueStream(pA.a_pattern, pA.b_pattern, seed=7)
        sB = SpGEMMValueStream(pB.a_pattern, pB.b_pattern, seed=8)
        # Occupy the whole pool with a busy pipeline (1 in-flight ticket).
        stA = gw._states["A"]
        stA.pipeline = SpGEMMPipeline(pA, depth=2)
        gw._pipelines_live = 1
        ta = stA.pipeline.submit(*sA.values_at(0))
        tb = gw.submit("B", *sB.values_at(0))
        gw.start()
        time.sleep(0.25)  # many dispatch rounds: B must still be waiting
        assert not tb.done()
        assert stA.pipeline is gw._states["A"].pipeline  # not torn down
        assert stA.pipeline.in_flight == 1
        ca = stA.pipeline.collect(ta)  # the pinned ticket still redeems
        _assert_same_csr(pA.execute(*sA.values_at(0)), ca)
        res = tb.wait(WAIT)  # freed slot: B now evicts idle A and runs
        gw.close()
        assert res.outcome is Outcome.OK
        _assert_same_csr(pB.execute(*sB.values_at(0)), res.value)


class TestConcurrentSubmitters:
    def test_threads_submit_concurrently(self):
        gw = _gateway(max_pipelines=2, max_batch=4, batch_window=0.002)
        p0 = gw.register("p0", *_patterns(0), tile=8, group=2,
                         backend="jnp")
        p1 = gw.register("p1", *_patterns(4), tile=8, group=2,
                         backend="jnp")
        streams = {
            "p0": SpGEMMValueStream(p0.a_pattern, p0.b_pattern, seed=7),
            "p1": SpGEMMValueStream(p1.a_pattern, p1.b_pattern, seed=8),
        }
        results = {}
        lock = threading.Lock()

        def tenant(tid, token):
            for s in range(6):
                step = tid * 100 + s
                t = gw.submit(token, *streams[token].values_at(step))
                r = t.wait(WAIT)
                with lock:
                    results[(token, step)] = r

        threads = [
            threading.Thread(target=tenant, args=(i, tok))
            for i, tok in enumerate(["p0", "p1", "p0", "p1"])
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        gw.close()
        assert len(results) == 24
        assert all(r.outcome is Outcome.OK for r in results.values())
        for (token, step), r in results.items():
            plan = p0 if token == "p0" else p1
            _assert_same_csr(
                plan.execute(*streams[token].values_at(step)), r.value
            )


class TestShardedGateway:
    def test_gateway_over_sharded_plan(self, forced_devices):
        """Gateway requests against a mesh-sharded plan reproduce the
        plan's own execute bitwise (8 forced host devices, 4-way shard)."""
        out = forced_devices(
            """
            import numpy as np
            from repro.data.pipeline import SpGEMMValueStream
            from repro.launch.mesh import make_shard_mesh
            from repro.sparse.random import random_coo
            from repro.spgemm import (
                Outcome, PlanCache, SpGEMMGateway, spgemm_plan,
            )

            a = random_coo(96, 72, 0.06, "uniform", seed=0).sum_duplicates()
            b = random_coo(72, 80, 0.06, "uniform", seed=1).sum_duplicates()
            cache = PlanCache()
            plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                               cache=cache, mesh=make_shard_mesh(4))
            gw = SpGEMMGateway(cache=cache, max_batch=2, batch_window=0.0)
            gw.register_plan("sharded", plan)
            st = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
            tickets = [gw.submit("sharded", *st.values_at(s))
                       for s in range(4)]
            results = [t.wait(timeout=180) for t in tickets]
            gw.close()
            assert all(r.outcome is Outcome.OK for r in results)
            for s, r in enumerate(results):
                c = plan.execute(*st.values_at(s))
                assert np.array_equal(c.indptr, r.value.indptr)
                assert np.array_equal(c.indices, r.value.indices)
                assert np.array_equal(c.data, r.value.data)
            print("sharded-gateway-ok")
            """,
            devices=8,
        )
        assert "sharded-gateway-ok" in out
