"""Plan/execute SpGEMM API tests: correctness, reuse, caching, and the
sparse-native conversions (no dense round-trip)."""
import threading
import tracemalloc

import numpy as np
import pytest

from repro.core.gustavson import spgemm_gustavson
from repro.data.pipeline import SpGEMMValueStream
from repro.kernels import ops
from repro.sparse.convert import (
    bcsr_from_coo,
    bcsv_from_coo,
    pad_to_blocks,
    to_bcsr,
    to_bcsv,
    to_csr,
)
from repro.sparse.formats import BCSR, BCSV, COO
from repro.sparse.random import random_block_sparse, random_coo, suite_matrix
from repro.spgemm import (
    PlanCache,
    schedule_build_count,
    spgemm_plan,
)


def _int_coo(m, n, density, seed):
    """Sparse matrix with small-integer float32 values: exact in float32
    under any accumulation order, so oracle comparisons are bit-for-bit."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo


class TestPlanCorrectness:
    @pytest.mark.parametrize("backend", ["pallas_interpret", "jnp"])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_execute_matches_gustavson_bitwise(self, backend, seed):
        a = _int_coo(90, 70, 0.08, seed)
        b = _int_coo(70, 110, 0.1, seed + 10)
        plan = spgemm_plan(a, b, tile=16, group=2, backend=backend,
                           cache=PlanCache())
        c = plan.execute()
        ref = spgemm_gustavson(to_csr(a), to_csr(b))
        assert np.array_equal(c.todense(), ref.todense())

    @pytest.mark.parametrize("name", ["poisson3Da", "scircuit", "cage12"])
    def test_paper_suite_matches_gustavson(self, name):
        """Acceptance: plan/execute vs spgemm_gustavson on (scaled) paper
        matrices."""
        a = suite_matrix(name, scale=0.004)
        coo = a.to_coo()
        b = COO(coo.col, coo.row, coo.val, (a.shape[1], a.shape[0]))  # A^T
        plan = spgemm_plan(a, b, tile=32, group=4,
                           backend="pallas_interpret", cache=PlanCache())
        c = plan.execute()
        ref = spgemm_gustavson(a, to_csr(b))
        np.testing.assert_allclose(c.todense(), ref.todense(),
                                   rtol=1e-4, atol=1e-4)

    def test_empty_inputs(self):
        a = COO(np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (32, 16))
        b = _int_coo(16, 24, 0.2, 3)
        plan = spgemm_plan(a, b, tile=8, group=2,
                           backend="pallas_interpret", cache=PlanCache())
        c = plan.execute()
        assert c.nnz == 0 and c.shape == (32, 24)


class TestPlanReuse:
    def test_two_value_sets_match_gustavson_bitwise(self):
        """One plan, two value sets: both executes match the Gustavson
        oracle bit-for-bit, with zero extra symbolic work."""
        a = _int_coo(80, 60, 0.1, 11)
        b = _int_coo(60, 80, 0.12, 12)
        plan = spgemm_plan(a, b, tile=16, group=2,
                           backend="pallas_interpret", cache=PlanCache())
        builds_after_plan = schedule_build_count()

        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=5,
                                   integer_values=True)
        for step in range(2):
            a_vals, b_vals = stream.values_at(step)
            c = plan.execute(a_vals, b_vals)
            ref = spgemm_gustavson(
                to_csr(COO(plan.a_pattern.row, plan.a_pattern.col, a_vals,
                           a.shape)),
                to_csr(COO(plan.b_pattern.row, plan.b_pattern.col, b_vals,
                           b.shape)),
            )
            assert np.array_equal(c.todense(), ref.todense())
        # Acceptance: re-execution did zero schedule-construction work.
        assert schedule_build_count() == builds_after_plan
        assert plan.report.schedule_builds == 1
        assert plan.report.executes == 2

    def test_cache_returns_identical_plan_object(self):
        a = _int_coo(64, 48, 0.1, 21)
        b = _int_coo(48, 64, 0.1, 22)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=16, group=2, backend="jnp", cache=cache)
        # Pattern-equal input with different values: same plan object.
        a2 = COO(a.row, a.col, a.val * 2.0, a.shape)
        p2 = spgemm_plan(a2, b, tile=16, group=2, backend="jnp", cache=cache)
        assert p2 is p1
        assert cache.stats.hits == 1 and cache.stats.misses == 1
        assert p1.report.cache_hits == 1
        # The hit rebinds the caller's values: execute() uses a2's values.
        c = p2.execute()
        ref = spgemm_gustavson(to_csr(a2), to_csr(b))
        assert np.array_equal(c.todense(), ref.todense())

    def test_cache_misses_on_different_pattern_or_params(self):
        a = _int_coo(64, 48, 0.1, 31)
        b = _int_coo(48, 64, 0.1, 32)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=16, group=2, backend="jnp", cache=cache)
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        p3 = spgemm_plan(a, b, tile=16, group=4, backend="jnp", cache=cache)
        assert p1 is not p2 and p1 is not p3 and p2 is not p3
        assert cache.stats.misses == 3 and cache.stats.hits == 0

    def test_concurrent_executes_on_shared_plan(self):
        """Cached plans are shared objects: concurrent executes with
        different value sets must each return their own C (no torn
        A/B pairs, no aliased staging buffers)."""
        a = _int_coo(40, 30, 0.12, 81)
        b = _int_coo(30, 40, 0.12, 82)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        mismatches = []

        def worker(seed):
            rng = np.random.default_rng(seed)
            av = rng.integers(-3, 4, a.nnz).astype(np.float32)
            bv = rng.integers(-3, 4, b.nnz).astype(np.float32)
            c = plan.execute(av, bv)
            ref = spgemm_gustavson(
                to_csr(COO(plan.a_pattern.row, plan.a_pattern.col, av,
                           a.shape)),
                to_csr(COO(plan.b_pattern.row, plan.b_pattern.col, bv,
                           b.shape)),
            )
            if not np.array_equal(c.todense(), ref.todense()):
                mismatches.append(seed)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not mismatches
        assert plan.report.executes == 8

    def test_value_stream_iter_does_not_leak_producer(self):
        """Dropping a prefetching iterator must stop its producer thread
        even when the producer is blocked on a full queue."""
        a = _int_coo(20, 20, 0.1, 91)
        b = _int_coo(20, 20, 0.1, 92)
        stream = SpGEMMValueStream(a, b, seed=0)
        before = set(threading.enumerate())
        it = stream.iter(prefetch=1)
        assert set(next(it)) == {"a_vals", "b_vals"}
        producers = [t for t in threading.enumerate() if t not in before]
        assert producers, "expected a producer thread"
        it.close()
        for t in producers:
            t.join(timeout=2.0)
            assert not t.is_alive(), "producer thread leaked"

    def test_shim_does_not_break_direct_plan_holders(self):
        """ops.spgemm releases device copies of the shared cached plan,
        but a direct spgemm_plan holder's no-arg execute() must keep
        working (host values stay staged)."""
        ad = random_block_sparse(96, 96, (32, 32), 0.5, seed=101)
        bd = random_block_sparse(96, 96, (32, 32), 0.5, seed=102)
        a, b = to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 32))
        p = spgemm_plan(a, b, backend="jnp")  # default (shared) cache
        ops.spgemm(a, b, backend="jnp")
        c = p.execute()  # restages from host on demand
        np.testing.assert_allclose(
            c.todense(), ad.astype(np.float64) @ bd.astype(np.float64),
            rtol=1e-4, atol=1e-4)

    def test_ops_spgemm_shim_uses_cache_and_fresh_values(self):
        ad = random_block_sparse(128, 128, (32, 32), 0.4, seed=41)
        bd = random_block_sparse(128, 128, (32, 32), 0.4, seed=42)
        c1 = ops.spgemm(to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 32)),
                        backend="pallas_interpret")
        np.testing.assert_allclose(
            c1.todense(), ad.astype(np.float64) @ bd.astype(np.float64),
            rtol=1e-4, atol=1e-4)
        # Same pattern, new values — must NOT serve stale numerics.
        ad2 = (ad * 3.0).astype(np.float32)
        c2 = ops.spgemm(to_bcsv(ad2, (32, 32), 2), to_bcsr(bd, (32, 32)),
                        backend="pallas_interpret")
        np.testing.assert_allclose(
            c2.todense(), ad2.astype(np.float64) @ bd.astype(np.float64),
            rtol=1e-4, atol=1e-4)


class TestSparseNativeConversion:
    def test_matches_dense_path(self):
        rng = np.random.default_rng(7)
        for _ in range(5):
            m, n = rng.integers(10, 100, 2)
            bm, bn = rng.choice([4, 8, 16], 2)
            g = int(rng.integers(1, 4))
            d = rng.standard_normal((m, n)).astype(np.float32)
            d[rng.random((m, n)) >= 0.1] = 0.0
            ref_r = BCSR.fromdense(pad_to_blocks(d, (bm, bn)), (bm, bn))
            got_r = to_bcsr(d, (bm, bn))
            assert np.array_equal(got_r.indptr, ref_r.indptr)
            assert np.array_equal(got_r.indices, ref_r.indices)
            assert np.array_equal(got_r.blocks, ref_r.blocks)
            ref_v = BCSV.fromdense(pad_to_blocks(d, (bm, bn)), (bm, bn), g)
            got_v = to_bcsv(d, (bm, bn), g)
            got_v.validate()
            assert np.array_equal(got_v.brow, ref_v.brow)
            assert np.array_equal(got_v.bcol, ref_v.bcol)
            assert np.array_equal(got_v.group_ptr, ref_v.group_ptr)
            assert np.array_equal(got_v.blocks, ref_v.blocks)

    def test_scatter_rebinds_values(self):
        coo = _int_coo(60, 44, 0.1, 51)
        fmt, scatter = bcsv_from_coo(coo, (8, 8), 2)
        v2 = np.arange(coo.nnz, dtype=np.float32) + 1.0
        fmt.blocks.reshape(-1)[scatter] = v2
        want = np.zeros(fmt.shape, np.float32)
        want[coo.row, coo.col] = v2
        assert np.array_equal(fmt.todense(), want)

    def test_large_sparse_never_densifies(self):
        """50k x 50k with nnz ~= 100k: the old dense round-trip needed
        ~10 GB; the sparse-native path must stay orders of magnitude
        below that."""
        n = 50_000
        nnz = 100_000
        rng = np.random.default_rng(0)
        row = rng.integers(0, n, nnz).astype(np.int32)
        col = rng.integers(0, n, nnz).astype(np.int32)
        val = rng.standard_normal(nnz).astype(np.float32)
        coo = COO(row, col, val, (n, n)).sum_duplicates()
        tracemalloc.start()
        bcsv = to_bcsv(coo, (8, 8), 4)
        bcsr = to_bcsr(coo, (8, 8))
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < 500 * 1024 * 1024, f"peak {peak / 1e6:.0f} MB"
        assert bcsv.nnzb <= coo.nnz and bcsr.nnzb <= coo.nnz
        # Spot-check structural fidelity without densifying.
        back = bcsv.to_coo().sum_duplicates().sort_rowmajor()
        assert back.nnz == coo.nnz
        s = coo.sort_rowmajor()
        assert np.array_equal(back.row, s.row)
        assert np.array_equal(back.col, s.col)
        np.testing.assert_array_equal(back.val, s.val)

    def test_block_to_coo_roundtrip(self):
        d = random_block_sparse(64, 96, (16, 16), 0.3, seed=61)
        for fmt in (to_bcsr(d, (16, 16)), to_bcsv(d, (16, 16), 2)):
            assert np.array_equal(fmt.to_coo().todense(), fmt.todense())


class TestPlanReport:
    def test_report_fields(self):
        a = _int_coo(64, 64, 0.1, 71)
        b = _int_coo(64, 64, 0.1, 72)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        rep = plan.report
        assert rep.nnz_a == a.nnz and rep.nnz_b == b.nnz
        assert rep.num_triples >= rep.b_fetches >= 1
        assert 0.0 <= rep.block_omar < 100.0
        assert rep.tile == (16, 16, 16) and rep.group == 2
        assert rep.shape == (64, 64)
        d = rep.as_dict()
        assert d["pattern_key"] == rep.pattern_key
        assert d["schedule_builds"] == 1


class TestPatternToken:
    """spgemm_plan(..., pattern_token=): the serving warm path's fast
    cache key — resident lookups skip to_coo + the pattern digest."""

    def test_token_hit_skips_digest_and_returns_same_plan(self, monkeypatch):
        cache = PlanCache()
        a = _int_coo(64, 48, 0.1, 11)
        b = _int_coo(48, 64, 0.1, 12)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=cache, pattern_token="layer0")
        assert plan.report.pattern_token == "layer0"
        assert plan.report.as_dict()["pattern_token"] == "layer0"
        # A token hit must never touch the digest path.
        from repro.spgemm import plan as plan_mod

        def boom(*a, **k):
            raise AssertionError("token hit paid the pattern digest")

        monkeypatch.setattr(plan_mod, "pattern_digest", boom)
        p2 = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                         cache=cache, pattern_token="layer0")
        assert p2 is plan
        assert cache.stats.token_hits == 1
        assert plan.report.cache_hits == 1

    def test_token_hit_rebinds_canonical_coo_values(self):
        cache = PlanCache()
        a = _int_coo(64, 48, 0.1, 21)
        b = _int_coo(48, 64, 0.1, 22)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=cache, pattern_token="t")
        a2 = COO(a.row, a.col, a.val * 3.0, a.shape)
        b2 = COO(b.row, b.col, b.val * 0.5, b.shape)
        p2 = spgemm_plan(a2, b2, tile=16, group=2, backend="jnp",
                         cache=cache, pattern_token="t")
        assert p2 is plan
        want = spgemm_gustavson(to_csr(a2), to_csr(b2))
        got = p2.execute()  # staged values must be this call's
        assert np.allclose(got.todense(), want.todense())

    def test_pure_lookup_without_operands(self):
        cache = PlanCache()
        a = _int_coo(32, 32, 0.15, 31)
        b = _int_coo(32, 32, 0.15, 32)
        with pytest.raises(KeyError, match="not resident"):
            spgemm_plan(None, None, tile=16, group=2, backend="jnp",
                        cache=cache, pattern_token="missing")
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=cache, pattern_token="tok")
        p2 = spgemm_plan(None, None, tile=16, group=2, backend="jnp",
                         cache=cache, pattern_token="tok")
        assert p2 is plan

    def test_token_digest_conflict_raises(self):
        """Binding one token to two different patterns is the caller lie
        the digest validation catches — whenever the digest path runs
        (here: the aliased plan was evicted, so the token lookup misses
        and the full path computes the conflicting digest)."""
        cache = PlanCache(capacity=1)
        a = _int_coo(32, 32, 0.15, 41)
        b = _int_coo(32, 32, 0.15, 42)
        spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                    cache=cache, pattern_token="tok")
        a2 = _int_coo(32, 32, 0.2, 43)  # different pattern
        b2 = _int_coo(32, 32, 0.2, 44)
        spgemm_plan(a2, b2, tile=16, group=2, backend="jnp",
                    cache=cache)  # evicts the aliased plan
        with pytest.raises(ValueError, match="already bound"):
            spgemm_plan(a2, b2, tile=16, group=2, backend="jnp",
                        cache=cache, pattern_token="tok")

    def test_token_scopes_by_config(self):
        """The same token under a different tile/group/backend resolves
        independently (the token names a pattern *per config*)."""
        cache = PlanCache()
        a = _int_coo(64, 48, 0.1, 51)
        b = _int_coo(48, 64, 0.1, 52)
        p16 = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                          cache=cache, pattern_token="tok")
        p8 = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                         cache=cache, pattern_token="tok")
        assert p8 is not p16
        assert spgemm_plan(None, None, tile=16, group=2, backend="jnp",
                           cache=cache, pattern_token="tok") is p16
        assert spgemm_plan(None, None, tile=8, group=2, backend="jnp",
                           cache=cache, pattern_token="tok") is p8

    def test_evicted_plan_falls_back_to_full_path(self):
        cache = PlanCache(capacity=1)
        a = _int_coo(32, 32, 0.15, 61)
        b = _int_coo(32, 32, 0.15, 62)
        spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                    cache=cache, pattern_token="tok")
        # Evict by inserting a different pattern.
        a2 = _int_coo(32, 32, 0.2, 63)
        b2 = _int_coo(32, 32, 0.2, 64)
        spgemm_plan(a2, b2, tile=16, group=2, backend="jnp", cache=cache)
        # Token lookup misses (plan evicted) and the full digest path
        # rebuilds + re-binds the alias.
        p = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                        cache=cache, pattern_token="tok")
        assert p.report.pattern_token == "tok"
        assert spgemm_plan(None, None, tile=16, group=2, backend="jnp",
                           cache=cache, pattern_token="tok") is p

    def test_token_hit_canonicalizes_unsorted_coo(self):
        """A token hit with a permuted (non-canonical) COO must produce
        the same results as the digest path — the hit verifies canonical
        order and sorts only when needed (review regression)."""
        cache = PlanCache()
        a = _int_coo(48, 40, 0.12, 71)
        b = _int_coo(40, 48, 0.12, 72)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache, pattern_token="tok")
        rng = np.random.default_rng(0)
        pa = rng.permutation(a.nnz)
        pb = rng.permutation(b.nnz)
        a_shuf = COO(a.row[pa], a.col[pa], (a.val * 2.0)[pa], a.shape)
        b_shuf = COO(b.row[pb], b.col[pb], (b.val * 3.0)[pb], b.shape)
        p2 = spgemm_plan(a_shuf, b_shuf, tile=8, group=2, backend="jnp",
                         cache=cache, pattern_token="tok")
        assert p2 is plan
        got = p2.execute()
        want = spgemm_gustavson(to_csr(a_shuf.sum_duplicates()),
                                to_csr(b_shuf.sum_duplicates()))
        assert np.array_equal(got.todense(), want.todense())

    def test_token_hit_rejects_wrong_nnz(self):
        cache = PlanCache()
        a = _int_coo(48, 40, 0.12, 81)
        b = _int_coo(40, 48, 0.12, 82)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=cache, pattern_token="tok")
        a_less = COO(a.row[:-1], a.col[:-1], a.val[:-1], a.shape)
        with pytest.raises(ValueError, match="does not match the token"):
            spgemm_plan(a_less, b, tile=8, group=2, backend="jnp",
                        cache=cache, pattern_token="tok")

    def test_token_never_serves_across_value_dtypes(self):
        """A float64 request must not be served (and silently downcast)
        by a float32-built plan through the token fast path — it falls
        to the digest path, which raises the token conflict."""
        cache = PlanCache()
        a = _int_coo(48, 40, 0.12, 91)
        b = _int_coo(40, 48, 0.12, 92)
        p32 = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                          cache=cache, pattern_token="tok")
        a64 = COO(a.row, a.col, a.val.astype(np.float64), a.shape)
        b64 = COO(b.row, b.col, b.val.astype(np.float64), b.shape)
        with pytest.raises(ValueError, match="already bound"):
            spgemm_plan(a64, b64, tile=8, group=2, backend="jnp",
                        cache=cache, pattern_token="tok")
        # ... and without the token the float64 plan is simply distinct.
        p64 = spgemm_plan(a64, b64, tile=8, group=2, backend="jnp",
                          cache=cache)
        assert p64 is not p32

    def test_release_evicts_dead_plan_from_cache(self):
        """release() must not leave the dead plan resident — the next
        spgemm_plan for the pattern rebuilds instead of hitting a plan
        that can only raise (review regression)."""
        cache = PlanCache()
        a = _int_coo(48, 40, 0.12, 95)
        b = _int_coo(40, 48, 0.12, 96)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache)
        plan.release()
        assert len(cache) == 0
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                         cache=cache)
        assert p2 is not plan
        p2.execute()  # alive and serving

    def test_token_hit_rebinds_block_inputs(self):
        """Block-plan token hits must rebind this call's packed blocks —
        never serve the previous caller's staged values silently."""
        cache = PlanCache()
        d_a = random_block_sparse(64, 64, (16, 16), 0.4, seed=71)
        d_b = random_block_sparse(64, 64, (16, 16), 0.4, seed=72)
        a1, b1 = to_bcsv(d_a, (16, 16), 2), to_bcsr(d_b, (16, 16))
        plan = spgemm_plan(a1, b1, backend="jnp", cache=cache,
                           pattern_token="blk")
        a2 = BCSV(a1.blocks * 2.0, a1.brow, a1.bcol, a1.group_ptr,
                  a1.shape, a1.group)
        b2 = BCSR(b1.indptr, b1.indices, b1.blocks * 0.5, b1.shape)
        p2 = spgemm_plan(a2, b2, backend="jnp", cache=cache,
                         pattern_token="blk")
        assert p2 is plan
        got = p2.execute()
        assert np.allclose(got.todense(), (d_a * 2.0) @ (d_b * 0.5),
                           atol=1e-4)

    def test_token_hit_rejects_unrebindable_input_type(self):
        """CSR (or any other) inputs on a token hit would keep stale
        staged values — the fast path refuses them instead."""
        cache = PlanCache()
        a = _int_coo(48, 40, 0.12, 75)
        b = _int_coo(40, 48, 0.12, 76)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                    pattern_token="tok")
        with pytest.raises(ValueError, match="token fast path"):
            spgemm_plan(to_csr(a), to_csr(b), tile=8, group=2,
                        backend="jnp", cache=cache, pattern_token="tok")

    def test_stale_release_leaves_rebuilt_plan_alone(self):
        """release() on a plan whose cache slot was evicted and rebuilt
        must not evict (or complain about) the new live plan."""
        cache = PlanCache(capacity=1)
        a = _int_coo(48, 40, 0.12, 85)
        b = _int_coo(40, 48, 0.12, 86)
        old = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                          cache=cache)
        a2 = _int_coo(48, 40, 0.2, 87)
        b2 = _int_coo(40, 48, 0.2, 88)
        spgemm_plan(a2, b2, tile=8, group=2, backend="jnp", cache=cache)
        fresh = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                            cache=cache)  # rebuilt under old's key
        assert fresh is not old
        old.release()
        assert len(cache) == 1  # fresh survived
        assert spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache) is fresh
