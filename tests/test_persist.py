"""Persistent on-disk plan cache (warm-restart SpGEMM).

Three layers of coverage:

* the flat-array codecs in ``repro.core.schedule`` are bitwise round-trips
  (schedule, assembly map, shard partition);
* the :class:`~repro.spgemm.persist.PlanStore` file format is integrity
  checked — corrupted, version-bumped, wrong-digest, and cross-key files
  all degrade to a miss (and a fresh symbolic build), never an error or a
  wrong plan;
* warm restarts (a fresh :class:`PlanCache` on a populated directory, and
  a genuinely fresh *process* via the ``forced_devices`` subprocess
  helper) skip the symbolic phase — ``report.schedule_builds == 0``,
  ``report.load_hits >= 1`` — and produce results bitwise-equal to a
  cold-built plan on the element, block, batched, and sharded (1/2/4/8)
  paths.
"""
import json
import os

import numpy as np
import pytest

from repro.core.schedule import (
    assembly_from_arrays,
    assembly_to_arrays,
    build_assembly_map,
    build_spgemm_schedule,
    partition_spgemm_schedule,
    schedule_from_arrays,
    schedule_to_arrays,
    shards_from_bounds,
    shards_to_bounds,
)
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo, to_bcsr, to_bcsv
from repro.sparse.formats import COO
from repro.sparse.random import random_block_sparse, random_coo, suite_matrix
from repro.spgemm import PlanCache, spgemm_plan
from repro.spgemm import persist
from repro.spgemm.persist import PlanStore


def _int_coo(m, n, density, seed):
    """Small-integer float32 values: exact under any accumulation order,
    so cold-vs-warm comparisons can demand bitwise equality."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo


def _schedule(seed=3, shape=(140, 100), tile=8, group=2):
    a = _int_coo(shape[0], shape[1], 0.07, seed)
    b = COO(a.col, a.row, a.val, (shape[1], shape[0]))
    a_bcsv, _ = bcsv_from_coo(a, (tile, tile), group)
    b_bcsr, _ = bcsr_from_coo(b, (tile, tile))
    return build_spgemm_schedule(a_bcsv, b_bcsr)


def _assert_schedules_equal(s1, s2):
    for f in ("a_slot", "b_slot", "panel", "sub_row", "start",
              "panel_group", "panel_bcol", "c_brow", "c_bcol"):
        a1, a2 = getattr(s1, f), getattr(s2, f)
        assert a1.dtype == a2.dtype and np.array_equal(a1, a2), f
    for f in ("group", "grid_m", "grid_n", "grid_k"):
        assert getattr(s1, f) == getattr(s2, f), f


class TestCodecs:
    def test_schedule_roundtrip_bitwise(self):
        sch = _schedule()
        back = schedule_from_arrays(schedule_to_arrays(sch))
        _assert_schedules_equal(sch, back)

    def test_assembly_roundtrip_bitwise(self):
        sch = _schedule()
        asm = build_assembly_map(sch, (8, 8), (140, 140))
        back = assembly_from_arrays(assembly_to_arrays(asm))
        assert back.gather.dtype == asm.gather.dtype
        assert np.array_equal(back.gather, asm.gather)
        assert np.array_equal(back.indptr, asm.indptr)
        assert np.array_equal(back.indices, asm.indices)
        assert back.shape == asm.shape

    @pytest.mark.parametrize("n_shards", [1, 2, 3, 8])
    def test_shard_bounds_roundtrip_bitwise(self, n_shards):
        """The group-bound vector alone reconstructs every shard slice."""
        sch = _schedule()
        shards = partition_spgemm_schedule(sch, n_shards)
        back = shards_from_bounds(sch, shards_to_bounds(shards))
        assert len(back) == len(shards)
        for s1, s2 in zip(shards, back):
            for f in ("group_lo", "group_hi", "triple_lo", "triple_hi",
                      "panel_lo", "panel_hi", "a_lo", "a_hi"):
                assert getattr(s1, f) == getattr(s2, f), f
            _assert_schedules_equal(s1.schedule, s2.schedule)

    def test_bad_bounds_raise(self):
        sch = _schedule()
        with pytest.raises(ValueError):
            shards_from_bounds(sch, np.asarray([0, 3, 2], np.int64))
        with pytest.raises(ValueError):
            shards_from_bounds(sch, np.asarray([1, 2], np.int64))
        with pytest.raises(ValueError):  # does not cover all groups
            shards_from_bounds(sch, np.asarray([0, 1], np.int64))


class TestPlanStore:
    KEY = ("pat", (8, 8, 8), 2, "jnp", None)

    def _arrays(self):
        return {"x": np.arange(7, dtype=np.int32),
                "y": np.linspace(0, 1, 5, dtype=np.float64)}

    def test_save_load_roundtrip(self, tmp_path):
        store = PlanStore(str(tmp_path))
        meta = {"kind": "element", "group": 2}
        assert store.save(self.KEY, self._arrays(), meta) is not None
        out = store.load(self.KEY)
        assert out is not None
        arrays, got_meta = out
        assert got_meta == meta
        for k, v in self._arrays().items():
            assert arrays[k].dtype == v.dtype and np.array_equal(arrays[k], v)
        assert self.KEY in store and len(store) == 1

    def test_missing_is_none(self, tmp_path):
        assert PlanStore(str(tmp_path)).load(self.KEY) is None

    def test_corrupted_file_is_miss_and_removed(self, tmp_path):
        store = PlanStore(str(tmp_path))
        store.save(self.KEY, self._arrays(), {})
        path = store.path_for(self.KEY)
        with open(path, "r+b") as f:
            f.seek(30)
            f.write(b"\xde\xad\xbe\xef" * 8)
        assert store.load(self.KEY) is None
        assert not os.path.exists(path), "corrupt file should be dropped"

    def test_version_bump_is_miss(self, tmp_path, monkeypatch):
        store = PlanStore(str(tmp_path))
        store.save(self.KEY, self._arrays(), {})
        monkeypatch.setattr(persist, "FORMAT_VERSION",
                            persist.FORMAT_VERSION + 1)
        assert store.load(self.KEY) is None

    def test_wrong_digest_is_miss(self, tmp_path):
        """A well-formed file whose payload no longer matches its header
        digest (silent bit rot / partial overwrite) must be a miss."""
        store = PlanStore(str(tmp_path))
        store.save(self.KEY, self._arrays(), {})
        path = store.path_for(self.KEY)
        with np.load(path, allow_pickle=False) as z:
            payload = {n: z[n] for n in z.files}
        payload["x"] = payload["x"] + 1  # tamper; header digest kept
        with open(path, "wb") as f:
            np.savez(f, **payload)
        assert store.load(self.KEY) is None

    def test_tampered_meta_is_miss(self, tmp_path):
        """The header meta (geometry, dtypes, kind) is inside the payload
        digest: a parseable-but-tampered JSON header must be a miss."""
        store = PlanStore(str(tmp_path))
        store.save(self.KEY, self._arrays(), {"group": 2})
        path = store.path_for(self.KEY)
        with np.load(path, allow_pickle=False) as z:
            payload = {n: z[n] for n in z.files}
        header = json.loads(bytes(np.asarray(payload["__meta__"])).decode())
        header["meta"]["group"] = 4  # digest left untouched
        payload["__meta__"] = np.frombuffer(
            json.dumps(header).encode(), np.uint8)
        with open(path, "wb") as f:
            np.savez(f, **payload)
        assert store.load(self.KEY) is None

    def test_stale_tmp_files_are_collected(self, tmp_path):
        """An orphaned *.tmp (writer crashed mid-save) is deleted by the
        next store construction once it is old enough."""
        stray = tmp_path / (persist.plan_file_name(self.KEY) + ".123.4.tmp")
        stray.write_bytes(b"half-written")
        old = os.path.getmtime(str(stray)) - 7200
        os.utime(str(stray), (old, old))
        PlanStore(str(tmp_path))
        assert not stray.exists()
        # A fresh tmp (another process mid-write) is spared...
        stray.write_bytes(b"in-flight")
        store = PlanStore(str(tmp_path))
        assert stray.exists()
        # ...but clear() drops everything.
        store.clear()
        assert not stray.exists()

    def test_cross_key_file_is_miss(self, tmp_path):
        """A valid file renamed onto another key's slot (or a filename
        digest collision) must not serve the wrong plan."""
        store = PlanStore(str(tmp_path))
        other = ("other-pattern",) + self.KEY[1:]
        store.save(self.KEY, self._arrays(), {})
        os.replace(store.path_for(self.KEY), store.path_for(other))
        assert store.load(other) is None

    def test_byte_budget_evicts_oldest(self, tmp_path):
        store = PlanStore(str(tmp_path))
        store.save(("k1",), self._arrays(), {})
        size = store.total_bytes()
        store.max_bytes = int(size * 2.5)  # room for two files
        store.load(("k1",))  # refresh k1's recency
        store.save(("k2",), self._arrays(), {})
        store.save(("k3",), self._arrays(), {})
        assert store.evictions >= 1
        assert store.total_bytes() <= store.max_bytes
        assert ("k3",) in store, "just-written file must survive eviction"


class TestWarmRestart:
    """Fresh PlanCache instances over one directory model the restart;
    TestWarmRestartProcess does it with real processes."""

    def _mats(self, seed=11):
        a = _int_coo(120, 90, 0.08, seed)
        b = COO(a.col, a.row, a.val, (90, 120))
        return a, b

    @pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
    def test_element_warm_start_bitwise(self, tmp_path, backend):
        a, b = self._mats()
        cold_cache = PlanCache(disk_dir=str(tmp_path))
        cold = spgemm_plan(a, b, tile=8, group=2, backend=backend,
                           cache=cold_cache)
        c_cold = cold.execute()
        assert cold.report.schedule_builds == 1
        assert cold.report.loads == 0
        assert cold_cache.stats.stores == 1

        warm_cache = PlanCache(disk_dir=str(tmp_path))
        warm = spgemm_plan(a, b, tile=8, group=2, backend=backend,
                           cache=warm_cache)
        assert warm is not cold
        assert warm.report.schedule_builds == 0
        assert warm.report.loads == 1 and warm.report.load_hits >= 1
        assert warm_cache.stats.disk_hits == 1
        c_warm = warm.execute()
        assert np.array_equal(c_cold.indptr, c_warm.indptr)
        assert np.array_equal(c_cold.indices, c_warm.indices)
        assert np.array_equal(c_cold.data, c_warm.data)
        # Fresh values through the fused path, still bitwise-equal.
        av = np.asarray(warm.a_pattern.val) * 2.0
        bv = np.asarray(warm.b_pattern.val) * 3.0
        assert np.array_equal(cold.execute(av, bv).data,
                              warm.execute(av, bv).data)

    def test_batched_warm_start_bitwise(self, tmp_path):
        a, b = self._mats(21)
        cold = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)))
        rng = np.random.default_rng(5)
        av = rng.integers(-3, 4, (4, a.nnz)).astype(np.float32)
        bv = rng.integers(-3, 4, (4, b.nnz)).astype(np.float32)
        cb_cold = cold.execute_batch(av, bv)
        warm = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)))
        assert warm.report.schedule_builds == 0
        cb_warm = warm.execute_batch(av, bv)
        for c1, c2 in zip(cb_cold, cb_warm):
            assert np.array_equal(c1.data, c2.data)
            assert np.array_equal(c1.indptr, c2.indptr)

    def test_block_warm_start_bitwise(self, tmp_path):
        ad = random_block_sparse(96, 96, (16, 16), 0.4, seed=31)
        bd = random_block_sparse(96, 96, (16, 16), 0.4, seed=32)
        ab, bb = to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16))
        cold = spgemm_plan(ab, bb, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)))
        c_cold = cold.execute()
        warm = spgemm_plan(ab, bb, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)))
        assert warm.report.schedule_builds == 0
        assert warm.report.load_hits >= 1
        c_warm = warm.execute()
        assert np.array_equal(c_cold.data, c_warm.data)
        # Lazy nnz report fields still resolve on the loaded plan.
        assert warm.report.nnz_a == cold.report.nnz_a

    def test_sharded_warm_start_single_device(self, tmp_path):
        from repro.launch.mesh import make_shard_mesh
        from repro.spgemm import ShardedSpGEMMPlan

        a, b = self._mats(41)
        mesh = make_shard_mesh(1)
        cold = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)),
                           mesh=mesh)
        c_cold = cold.execute()
        warm = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)),
                           mesh=mesh)
        assert isinstance(warm, ShardedSpGEMMPlan)
        assert warm.report.schedule_builds == 0
        assert warm.shard_stats() == cold.shard_stats()
        assert np.array_equal(c_cold.data, warm.execute().data)

    def test_corrupt_entry_falls_back_to_build(self, tmp_path):
        a, b = self._mats(51)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=PlanCache(disk_dir=str(tmp_path)))
        store = PlanStore(str(tmp_path))
        (path,) = store.files()
        with open(path, "r+b") as f:
            f.seek(40)
            f.write(b"garbage!" * 16)
        cache = PlanCache(disk_dir=str(tmp_path))
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        assert plan.report.schedule_builds == 1  # silent rebuild
        assert plan.report.loads == 0
        assert cache.stats.disk_misses == 1
        # ...and the rebuild re-populated the store for the next restart.
        assert cache.stats.stores == 1
        warm = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)))
        assert warm.report.schedule_builds == 0

    def test_loader_rejection_falls_back_to_build(self, tmp_path, monkeypatch):
        """A verified file whose content the rehydrator rejects (here: a
        future plan kind) silently rebuilds."""
        a, b = self._mats(61)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=PlanCache(disk_dir=str(tmp_path)))
        store = PlanStore(str(tmp_path))
        key_path = store.files()[0]
        with np.load(key_path, allow_pickle=False) as z:
            payload = {n: z[n] for n in z.files}
        header = json.loads(bytes(np.asarray(payload["__meta__"])).decode())
        header["meta"]["kind"] = "from-the-future"
        arrays = {n: v for n, v in payload.items() if n != "__meta__"}
        header["digest"] = persist._payload_digest(arrays, header["meta"])
        payload["__meta__"] = np.frombuffer(
            json.dumps(header).encode(), np.uint8)
        with open(key_path, "wb") as f:
            np.savez(f, **payload)
        cache = PlanCache(disk_dir=str(tmp_path))
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        assert plan.report.schedule_builds == 1
        assert cache.stats.load_failures == 1

    def test_memory_tier_still_wins(self, tmp_path):
        """Within one process the memory tier serves repeat lookups; disk
        is only consulted on memory misses."""
        a, b = self._mats(71)
        cache = PlanCache(disk_dir=str(tmp_path))
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        assert p1 is p2
        assert cache.stats.hits == 1 and cache.stats.disk_hits == 0

    def test_no_disk_dir_keeps_old_behavior(self):
        a, b = self._mats(81)
        cache = PlanCache()
        assert cache.store is None
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        assert plan.report.schedule_builds == 1
        s = cache.stats()
        assert s["disk_hits"] == 0 and s["stores"] == 0
        assert "disk_files" not in s


WARM_COLD_PROCESS = """
import hashlib, os
import numpy as np
from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.launch.mesh import make_shard_mesh
from repro.spgemm import default_cache, spgemm_plan

assert os.environ["REPRO_SPGEMM_PLAN_DIR"]  # disk tier via env, no code
WARM = {warm}
rng = np.random.default_rng(0)
digests = []
for name, scale in (("poisson3Da", 0.004), ("cage12", 0.004)):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    v = rng.integers(-4, 5, a.nnz).astype(np.float32)
    a.val = np.where(v == 0, np.float32(1.0), v)
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
    av = rng.integers(-3, 4, (3, a.nnz)).astype(np.float32)
    bv = rng.integers(-3, 4, (3, b.nnz)).astype(np.float32)
    for n in (None, 1, 2, 4, 8):
        mesh = None if n is None else make_shard_mesh(n)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp", mesh=mesh)
        rep = plan.report
        if WARM:
            assert rep.schedule_builds == 0, (name, n, "symbolic phase ran")
            assert rep.loads == 1 and rep.load_hits >= 1, (name, n)
        else:
            assert rep.schedule_builds == 1, (name, n)
        c = plan.execute()
        cb = plan.execute_batch(av, bv)
        h = hashlib.blake2b(digest_size=12)
        for arr in (c.indptr, c.indices, c.data, *(x.data for x in cb)):
            h.update(np.ascontiguousarray(arr).tobytes())
        digests.append(f"{{name}}:{{n}}:{{h.hexdigest()}}")
stats = default_cache().stats()
if WARM:
    assert stats["disk_hits"] == len(digests), stats
else:
    assert stats["stores"] == len(digests), stats
print("RESULT " + ";".join(digests))
"""


class TestWarmRestartProcess:
    def test_second_process_skips_symbolic_phase(self, tmp_path,
                                                 forced_devices):
        """The acceptance scenario: process 1 builds plans (element +
        sharded 1/2/4/8 on paper matrices) under REPRO_SPGEMM_PLAN_DIR;
        process 2 — a genuinely fresh interpreter — loads every one of
        them (schedule_builds == 0, load_hits >= 1) and its execute /
        execute_batch results are bitwise-identical to process 1's."""
        os.environ["REPRO_SPGEMM_PLAN_DIR"] = str(tmp_path)
        try:
            cold = forced_devices(
                WARM_COLD_PROCESS.format(warm=False), devices=8)
            assert len(PlanStore(str(tmp_path)).files()) == 10
            warm = forced_devices(
                WARM_COLD_PROCESS.format(warm=True), devices=8)
        finally:
            del os.environ["REPRO_SPGEMM_PLAN_DIR"]
        get = lambda out: [ln for ln in out.splitlines()
                           if ln.startswith("RESULT ")][0]
        assert get(cold) == get(warm), "warm results diverged from cold"


class TestCrashConsistency:
    """The save path's durability discipline: data is fsynced before the
    rename, and the rename's directory record is fsynced after."""

    def test_save_fsyncs_payload_and_directory(self, tmp_path, monkeypatch):
        store = PlanStore(str(tmp_path))
        real_fsync, synced = os.fsync, []

        def recording_fsync(fd):
            synced.append(os.fstat(fd).st_mode)
            return real_fsync(fd)

        monkeypatch.setattr(os, "fsync", recording_fsync)
        assert store.save(TestPlanStore.KEY,
                          {"x": np.arange(3, dtype=np.int32)}, {})
        import stat
        # At least the tmp payload file AND the store directory.
        assert len(synced) >= 2
        assert any(stat.S_ISREG(m) for m in synced), "payload not fsynced"
        assert any(stat.S_ISDIR(m) for m in synced), "directory not fsynced"

    def test_alias_put_fsyncs_index_and_directory(self, tmp_path,
                                                  monkeypatch):
        store = PlanStore(str(tmp_path))
        real_fsync, synced = os.fsync, []

        def recording_fsync(fd):
            synced.append(os.fstat(fd).st_mode)
            return real_fsync(fd)

        monkeypatch.setattr(os, "fsync", recording_fsync)
        assert store.alias_put("('tok',)", "('key',)")
        import stat
        assert any(stat.S_ISREG(m) for m in synced)
        assert any(stat.S_ISDIR(m) for m in synced)

    def test_failed_save_leaves_no_tmp(self, tmp_path, monkeypatch):
        store = PlanStore(str(tmp_path))
        monkeypatch.setattr(os, "replace",
                            lambda *a: (_ for _ in ()).throw(OSError("no")))
        assert store.save(TestPlanStore.KEY,
                          {"x": np.arange(3, dtype=np.int32)}, {}) is None
        assert [n for n in os.listdir(str(tmp_path))
                if n.endswith(".tmp")] == []

    def test_equal_mtime_order_is_name_deterministic(self, tmp_path):
        """Filesystems with coarse timestamps give many entries one
        mtime; files() (the eviction order) must still be deterministic:
        (mtime, name) ascending."""
        store = PlanStore(str(tmp_path))
        arrays = {"x": np.arange(16, dtype=np.int32)}
        for k in (("ka",), ("kb",), ("kc",), ("kd",)):
            store.save(k, arrays, {})
        t = os.path.getmtime(store.files()[0])
        for p in store.files():
            os.utime(p, (t, t))
        got = store.files()
        assert got == sorted(got), "equal-mtime order not name-sorted"
        # Eviction follows the same deterministic order: with room for
        # all but one file, exactly the name-smallest entry is evicted.
        size = os.path.getsize(got[0])
        store.max_bytes = store.total_bytes() - 1  # force one eviction
        store._evict()
        assert store.evictions == 1
        left = store.files()
        assert got[0] not in left and left == got[1:]
        del size


class TestAliasIndex:
    """The pattern_token -> plan-key alias index (tokens.index.json)."""

    def test_roundtrip_across_store_instances(self, tmp_path):
        store = PlanStore(str(tmp_path))
        # alias_get only resolves aliases whose target artifact exists
        # (a dangling alias is a miss), so save the targets first.
        arrays = {"x": np.arange(4, dtype=np.int32)}
        store.save(("full", "key"), arrays, {})
        store.save(("full", "key2"), arrays, {})
        assert store.alias_get("('t', 'x')") is None
        assert store.alias_put("('t', 'x')", "('full', 'key')")
        assert store.alias_get("('t', 'x')") == "('full', 'key')"
        # Last-writer-wins rebind, durable across a fresh instance.
        assert store.alias_put("('t', 'x')", "('full', 'key2')")
        fresh = PlanStore(str(tmp_path))
        assert fresh.alias_get("('t', 'x')") == "('full', 'key2')"

    def test_missing_target_is_a_miss(self, tmp_path):
        store = PlanStore(str(tmp_path))
        store.alias_put("('t', 'x')", "('full', 'never-saved')")
        assert store.alias_get("('t', 'x')") is None

    def test_bad_json_degrades_to_miss_then_recovers(self, tmp_path):
        store = PlanStore(str(tmp_path))
        arrays = {"x": np.arange(4, dtype=np.int32)}
        store.save(("k",), arrays, {})
        store.save(("k2",), arrays, {})
        store.alias_put("('t',)", "('k',)")
        with open(store.alias_path(), "w", encoding="utf-8") as f:
            f.write("{this is not json")
        assert store.alias_get("('t',)") is None  # never raises
        # A put after corruption rewrites a valid index.
        assert store.alias_put("('t',)", "('k2',)")
        assert store.alias_get("('t',)") == "('k2',)"

    def test_version_bump_degrades_to_miss(self, tmp_path):
        store = PlanStore(str(tmp_path))
        store.alias_put("('t',)", "('k',)")
        with open(store.alias_path(), "r", encoding="utf-8") as f:
            doc = json.load(f)
        doc["format_version"] = persist.FORMAT_VERSION + 1
        with open(store.alias_path(), "w", encoding="utf-8") as f:
            json.dump(doc, f)
        assert store.alias_get("('t',)") is None

    def test_clear_drops_alias_index(self, tmp_path):
        store = PlanStore(str(tmp_path))
        store.alias_put("('t',)", "('k',)")
        assert os.path.exists(store.alias_path())
        store.clear()
        assert not os.path.exists(store.alias_path())


class TestTokenDiskRestart:
    """A restarted worker's pattern_token lookup resolves straight to a
    disk load — no COO canonicalization digest — via the alias index."""

    def _mats(self, seed=61):
        a = _int_coo(96, 80, 0.08, seed)
        b = COO(a.col, a.row, a.val, (80, 96))
        return a, b

    def test_token_lookup_skips_digest_on_restart(self, tmp_path,
                                                  monkeypatch):
        a, b = self._mats()
        c1 = PlanCache(disk_dir=str(tmp_path))
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c1,
                         pattern_token="svc/l0")
        ref = p1.execute()
        # Restart: fresh cache, and the digest is booby-trapped — the
        # token path must never need it.
        import repro.spgemm.plan as plan_mod

        def boom(*_a, **_k):
            raise AssertionError("pattern digest computed on token path")

        monkeypatch.setattr(plan_mod, "pattern_digest", boom)
        c2 = PlanCache(disk_dir=str(tmp_path))
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         pattern_token="svc/l0")
        assert c2.stats.token_disk_hits == 1
        assert c2.stats.disk_hits == 1 and c2.stats.load_failures == 0
        assert p2.report.schedule_builds == 0
        assert p2.report.pattern_token == "svc/l0"
        got = p2.execute()
        assert np.array_equal(got.indptr, ref.indptr)
        assert np.array_equal(got.indices, ref.indices)
        assert np.array_equal(got.data, ref.data)
        # Second lookup in the restarted process: memory token hit, same
        # plan object, no second disk load.
        p3 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         pattern_token="svc/l0")
        assert p3 is p2
        assert c2.stats.token_disk_hits == 1

    def test_missing_alias_falls_back_to_digest(self, tmp_path):
        a, b = self._mats(62)
        c1 = PlanCache(disk_dir=str(tmp_path))
        spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c1,
                    pattern_token="svc/l1")
        # Wipe just the alias index: the token path misses, the digest
        # path still finds the artifact on disk.
        os.unlink(PlanStore(str(tmp_path)).alias_path())
        c2 = PlanCache(disk_dir=str(tmp_path))
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         pattern_token="svc/l1")
        assert c2.stats.token_disk_hits == 0
        assert c2.stats.disk_hits == 1
        assert p2.report.schedule_builds == 0

    def test_stale_alias_degrades_to_rebuild(self, tmp_path):
        """An alias pointing at a deleted artifact must degrade to the
        normal build path, never error."""
        a, b = self._mats(63)
        c1 = PlanCache(disk_dir=str(tmp_path))
        spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c1,
                    pattern_token="svc/l2")
        store = PlanStore(str(tmp_path))
        for p in store.files():
            os.unlink(p)  # artifacts gone, alias survives
        c2 = PlanCache(disk_dir=str(tmp_path))
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         pattern_token="svc/l2")
        assert p2.report.schedule_builds == 1  # fresh symbolic build
        assert c2.stats.token_disk_hits == 0
