"""Sparse format unit + property tests (paper Sec. 2.1, 3)."""
import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.sparse.convert import (
    csr_to_csv, csv_to_csr, pad_to_blocks, to_bcsr, to_bcsv, to_csc, to_csr,
    to_csv,
)
from repro.sparse.formats import BCSR, BCSV, COO, CSC, CSR, CSV
from repro.sparse.random import random_coo, suite_matrix, SUITE


def _rand_dense(rng, m, n, density):
    a = rng.standard_normal((m, n)).astype(np.float32)
    a[rng.random((m, n)) >= density] = 0.0
    return a


class TestRoundTrips:
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.3, 1.0])
    def test_coo_csr_csc_dense_roundtrip(self, density):
        rng = np.random.default_rng(1)
        a = _rand_dense(rng, 37, 23, density)
        assert np.array_equal(COO.fromdense(a).todense(), a)
        assert np.array_equal(CSR.fromdense(a).todense(), a)
        assert np.array_equal(CSC.fromdense(a).todense(), a)

    @pytest.mark.parametrize("num_pe", [1, 2, 7, 32])
    def test_csv_roundtrip_and_order(self, num_pe):
        rng = np.random.default_rng(2)
        a = _rand_dense(rng, 40, 31, 0.2)
        csv = CSV.fromdense(a, num_pe)
        csv.validate()  # vector-major order invariant
        assert np.array_equal(csv.todense(), a)

    def test_csr_csv_csr(self):
        a = suite_matrix("poisson3Da", scale=0.02)
        csv = csr_to_csv(a, 8)
        back = csv_to_csr(csv)
        assert np.array_equal(back.todense(), a.todense())

    @pytest.mark.parametrize("bs", [(4, 4), (8, 16)])
    def test_block_formats_roundtrip(self, bs):
        rng = np.random.default_rng(3)
        a = _rand_dense(rng, 64, 48, 0.1)
        assert np.array_equal(BCSR.fromdense(a, bs).todense(), a)
        b = BCSV.fromdense(a, bs, group=2)
        b.validate()
        assert np.array_equal(b.todense(), a)


class TestCSVProperties:
    @settings(max_examples=60, deadline=None)
    @given(
        m=st.integers(1, 40),
        n=st.integers(1, 40),
        num_pe=st.integers(1, 9),
        seed=st.integers(0, 10_000),
    )
    def test_csv_preserves_all_nonzeros(self, m, n, num_pe, seed):
        rng = np.random.default_rng(seed)
        a = _rand_dense(rng, m, n, 0.25)
        csv = CSV.fromdense(a, num_pe)
        csv.validate()
        assert csv.nnz == np.count_nonzero(a)
        assert np.array_equal(csv.todense(), a)

    @settings(max_examples=60, deadline=None)
    @given(
        m=st.integers(1, 30),
        num_pe=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    def test_vector_ids_group_by_column_within_rowgroup(self, m, num_pe, seed):
        rng = np.random.default_rng(seed)
        a = _rand_dense(rng, m, m, 0.3)
        csv = CSV.fromdense(a, num_pe)
        vid = csv.vector_id()
        if csv.nnz == 0:
            return
        # Within one vector id: same column and same row-group.
        for v in np.unique(vid):
            sel = vid == v
            assert np.unique(csv.col_ind[sel]).size == 1
            assert np.unique(csv.row_ind[sel] // num_pe).size == 1
        # Ids are non-decreasing and dense.
        assert np.all(np.diff(vid) >= 0)
        assert vid.max() + 1 == csv.num_vectors()

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 1000), num_pe=st.integers(1, 6))
    def test_csv_num_pe_1_is_row_major(self, seed, num_pe):
        """num_pe=1 must coincide with CSR (row-major) ordering."""
        rng = np.random.default_rng(seed)
        a = _rand_dense(rng, 12, 12, 0.4)
        csv = CSV.fromdense(a, 1)
        csr = CSR.fromdense(a)
        coo = csr.to_coo()
        assert np.array_equal(csv.row_ind, coo.row)
        assert np.array_equal(csv.col_ind, coo.col)
        assert np.array_equal(csv.val, coo.val)


class TestSyntheticSuite:
    @pytest.mark.parametrize("name", list(SUITE))
    def test_suite_matrix_specs(self, name):
        """Scaled synthetic matrices keep the published nnz-per-row profile."""
        m = suite_matrix(name, scale=0.01, seed=0)
        spec = SUITE[name]
        target_nnz_per_row = spec.density * spec.cols
        got = m.nnz / m.shape[0]
        assert got == pytest.approx(target_nnz_per_row, rel=0.5)

    def test_pad_to_blocks(self):
        a = np.ones((5, 7), np.float32)
        p = pad_to_blocks(a, (4, 4))
        assert p.shape == (8, 8)
        assert np.array_equal(p[:5, :7], a)
        assert p[5:].sum() == 0 and p[:, 7:].sum() == 0
