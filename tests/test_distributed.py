"""Distribution tests under a multi-device CPU mesh (subprocess: these need
XLA_FLAGS set before jax import, which must not leak into other tests —
the shared helper lives in conftest.py)."""
from conftest import run_forced_devices as _run


class TestMeshAndSharding:
    def test_sharded_train_step_matches_single_device(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs.registry import get_reduced
            from repro.models import transformer as tr
            from repro.launch.mesh import make_auto_mesh, make_host_mesh
            from repro.launch.sharding import default_rules, use_rules, divisible_sharding
            from repro.optim import AdamW
            from repro.runtime.steps import make_train_step
            from repro.data.pipeline import SyntheticLM, shard_batch

            cfg = get_reduced('granite-3-2b')
            params = tr.init_lm(jax.random.PRNGKey(0), cfg)
            opt = AdamW(lr=1e-3)
            opt_state = opt.init(params)
            data = SyntheticLM(cfg, 8, 32)
            batch = data.batch_at(0)

            # single-device reference
            step = jax.jit(make_train_step(cfg, opt))
            p1, o1, m1 = step(params, opt_state,
                              {k: jnp.asarray(v) for k, v in batch.items()})

            # 4x2 mesh (data x model); make_auto_mesh shims axis_types
            # (jax.sharding.AxisType is absent on older jax).
            mesh = make_auto_mesh((4, 2), ('data', 'model'))
            rules = default_rules(mesh, n_kv_heads=cfg.n_kv_heads,
                                  n_experts=cfg.n_experts)
            with use_rules(mesh, rules):
                axes = tr.lm_axes(cfg)
                params_sh = jax.tree.map(
                    lambda x, a: jax.device_put(
                        x, divisible_sharding(x.shape, a, rules, mesh)),
                    params, axes)
                opt_sh = opt.init(params_sh)
                step_sh = jax.jit(make_train_step(cfg, opt))
                p2, o2, m2 = step_sh(params_sh, opt_sh, shard_batch(batch, mesh))
            print('LOSS', float(m1['loss']), float(m2['loss']))
            assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3
            d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
            mx = max(jax.tree.leaves(d))
            print('MAXDIFF', mx)
            assert mx < 5e-3
        """)
        assert "LOSS" in out

    def test_moe_shard_map_matches_unsharded(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.registry import get_reduced
            from repro.models import transformer as tr
            from repro.launch.mesh import make_auto_mesh
            from repro.launch.sharding import default_rules, use_rules, divisible_sharding
            # High capacity: near-tie top-k routing can legitimately flip
            # under sharded reduction ordering; with ample capacity the
            # logits still agree tightly.
            cfg = get_reduced('qwen3-moe-30b-a3b').with_(
                dtype='float32', capacity_factor=64.0)
            params = tr.init_lm(jax.random.PRNGKey(0), cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
            ref_logits, ref_aux = tr.forward(params, cfg, tokens=toks)

            mesh = make_auto_mesh((2, 4), ('data', 'model'))
            rules = default_rules(mesh, n_kv_heads=cfg.n_kv_heads,
                                  n_experts=cfg.n_experts)
            with use_rules(mesh, rules):
                axes = tr.lm_axes(cfg)
                params_sh = jax.tree.map(
                    lambda x, a: jax.device_put(
                        x, divisible_sharding(x.shape, a, rules, mesh)),
                    params, axes)
                f = jax.jit(lambda p, t: tr.forward(p, cfg, tokens=t))
                got_logits, got_aux = f(params_sh, toks)
            err = float(jnp.max(jnp.abs(ref_logits - got_logits)))
            print('ERR', err, float(ref_aux), float(got_aux))
            assert err < 2e-3
            # aux tracks the (flippable) top-1 histogram: loose bound.
            assert abs(float(ref_aux) - float(got_aux)) < 0.1
        """)
        assert "ERR" in out

    def test_elastic_restart_8_to_4_to_1(self):
        """Checkpoint on 8 devices, restore on 4, then on 1."""
        import tempfile
        tmp = tempfile.mkdtemp()
        _run(f"""
            import jax, jax.numpy as jnp
            from repro.checkpoint.manager import CheckpointManager
            from repro.launch.mesh import make_auto_mesh
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = make_auto_mesh((8,), ('data',))
            w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                               NamedSharding(mesh, P('data', None)))
            CheckpointManager({tmp!r}).save(1, {{'w': w}})
        """, devices=8)
        for ndev in (4, 1):
            out = _run(f"""
                import jax, jax.numpy as jnp, numpy as np
                from repro.checkpoint.manager import CheckpointManager
                from repro.launch.mesh import make_auto_mesh
                from jax.sharding import NamedSharding, PartitionSpec as P
                mesh = make_auto_mesh(({ndev},), ('data',))
                like = {{'w': jnp.zeros((8, 8), jnp.float32)}}
                sh = {{'w': NamedSharding(mesh, P('data', None))}}
                out = CheckpointManager({tmp!r}).restore(1, like, shardings=sh)
                assert np.array_equal(np.asarray(out['w']).ravel(),
                                      np.arange(64, dtype=np.float32))
                print('RESHARD_OK', {ndev})
            """, devices=ndev)
            assert "RESHARD_OK" in out

    def test_production_mesh_shapes(self):
        out = _run("""
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            assert dict(zip(m1.axis_names, m1.devices.shape)) == {
                'data': 16, 'model': 16}
            m2 = make_production_mesh(multi_pod=True)
            assert dict(zip(m2.axis_names, m2.devices.shape)) == {
                'pod': 2, 'data': 16, 'model': 16}
            print('MESH_OK')
        """, devices=512)
        assert "MESH_OK" in out
