"""Opt-in GPU XLA_FLAGS preset (repro.launch.xla_flags).

Pure env-dict plumbing — no jax import in the module under test (it must
run before jax initializes to have any effect, see benchmarks/run.py).
"""
from repro.launch import xla_flags as xf


class TestMerge:
    def test_empty_existing_gets_full_preset(self):
        out = xf.gpu_xla_flags("")
        assert out.split() == list(xf.GPU_LATENCY_HIDING_FLAGS)

    def test_user_set_flags_win(self):
        existing = "--xla_gpu_enable_latency_hiding_scheduler=false"
        toks = xf.gpu_xla_flags(existing).split()
        assert toks[0] == existing
        assert "--xla_gpu_enable_latency_hiding_scheduler=true" not in toks
        names = [t.split("=", 1)[0] for t in toks]
        assert len(names) == len(set(names))

    def test_unrelated_flags_preserved(self):
        toks = xf.gpu_xla_flags("--xla_foo=1 --xla_bar").split()
        assert "--xla_foo=1" in toks and "--xla_bar" in toks
        for f in xf.GPU_LATENCY_HIDING_FLAGS:
            assert f in toks

    def test_idempotent(self):
        once = xf.gpu_xla_flags("")
        assert xf.gpu_xla_flags(once) == once


class TestGuard:
    def test_default_off(self):
        env = {}
        assert xf.maybe_apply_gpu_xla_flags(env) is None
        assert env == {}

    def test_falsy_values_off(self):
        for v in ("0", "false", "no", "off", "", " "):
            env = {xf.REPRO_GPU_XLA_FLAGS_ENV: v}
            assert xf.maybe_apply_gpu_xla_flags(env) is None
            assert "XLA_FLAGS" not in env

    def test_enabled_merges_into_env(self):
        env = {xf.REPRO_GPU_XLA_FLAGS_ENV: "1", "XLA_FLAGS": "--xla_foo=1"}
        out = xf.maybe_apply_gpu_xla_flags(env)
        assert out == env["XLA_FLAGS"]
        assert env["XLA_FLAGS"].startswith("--xla_foo=1 ")
        for f in xf.GPU_LATENCY_HIDING_FLAGS:
            assert f in env["XLA_FLAGS"].split()

    def test_apply_unconditional(self):
        env = {}
        out = xf.apply_gpu_xla_flags(env)
        assert env["XLA_FLAGS"] == out == " ".join(
            xf.GPU_LATENCY_HIDING_FLAGS)
