"""Pallas dispatch on every numeric path (batch, pipeline, sharded).

The batch-folded grid (``spgemm_scheduled_batch_impl``) and the per-shard
Pallas programs inside ``shard_map`` must be *bitwise*-equal to the
single-set kernel — the fold iterates the triple dimension innermost so
each element sees its schedule in the exact single-grid order, and each
shard pads its stacked schedule to a dummy panel no gather reads. These
tests pin that contract, plus the dispatch itself: a pallas plan's batch
path must never silently fall back to the jnp reference kernel.

Sharded coverage runs under forced host devices in a subprocess (XLA
device count is fixed at first jax import — see tests/conftest.py).
"""
import numpy as np
import pytest

from repro.data.pipeline import SpGEMMValueStream
from repro.kernels import ref
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse, random_coo
from repro.spgemm import PlanCache, spgemm_plan


def _int_coo(m, n, density, seed):
    """Small-integer float32 values: exact under any accumulation order,
    so cross-path comparisons are bit-for-bit."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo


def _element_plan(seed=0, m=96, k=72, n=80, density=0.06,
                  backend="pallas_interpret"):
    a = _int_coo(m, k, density, seed).sum_duplicates()
    b = _int_coo(k, n, density, seed + 10).sum_duplicates()
    return spgemm_plan(a, b, tile=8, group=2, backend=backend,
                       cache=PlanCache())


def _block_plan(backend="pallas_interpret", size=128, bs=32, seed=3):
    ad = random_block_sparse(size, size, (bs, bs), 0.3, seed=seed)
    bd = random_block_sparse(size, size, (bs, bs), 0.3, seed=seed + 1)
    return spgemm_plan(to_bcsv(ad, (bs, bs), 2), to_bcsr(bd, (bs, bs)),
                       backend=backend, cache=PlanCache())


def _assert_same_csr(x, y):
    assert np.array_equal(x.indptr, y.indptr)
    assert np.array_equal(x.indices, y.indices)
    assert np.array_equal(x.data, y.data)


class TestBatchedPallasDispatch:
    def test_element_batch_matches_looped_execute(self):
        """pallas_interpret execute_batch == a loop of single Pallas
        executes, bitwise (element plan)."""
        plan = _element_plan(seed=1)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        av, bv = stream.values_batch_at(0, batch=5)
        looped = [plan.execute(av[i], bv[i]) for i in range(5)]
        batched = plan.execute_batch(av, bv)
        assert len(batched) == 5
        for w, g in zip(looped, batched):
            _assert_same_csr(w, g)

    def test_block_batch_matches_looped_execute(self):
        """Same bitwise contract on packed-block operands."""
        plan = _block_plan()
        rng = np.random.default_rng(2)
        ab = rng.standard_normal((3,) + plan._a_shape).astype(np.float32)
        bb = rng.standard_normal((3,) + plan._b_shape).astype(np.float32)
        looped = [plan.execute(ab[i], bb[i]) for i in range(3)]
        batched = plan.execute_batch(ab, bb)
        for w, g in zip(looped, batched):
            _assert_same_csr(w, g)

    def test_batch_matches_jnp_backend(self):
        """Both batch folds (Pallas grid, jnp scatter-add) agree bitwise
        on integer values — same plan, backends swapped."""
        pp = _element_plan(seed=3, backend="pallas_interpret")
        jp = _element_plan(seed=3, backend="jnp")
        stream = SpGEMMValueStream(pp.a_pattern, pp.b_pattern, seed=11)
        av, bv = stream.values_batch_at(0, batch=4)
        for w, g in zip(jp.execute_batch(av, bv), pp.execute_batch(av, bv)):
            _assert_same_csr(w, g)

    def test_pallas_batch_does_not_call_jnp_ref(self, monkeypatch):
        """Dispatch guard: the batch path of a pallas plan must not trace
        the jnp reference kernel (fresh plan shapes force a re-trace, so
        a fallback would hit the patched symbol)."""
        plan = _element_plan(seed=5, m=88, k=64, n=104, density=0.07)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=13)
        av, bv = stream.values_batch_at(0, batch=3)

        def boom(*a, **k):
            raise AssertionError(
                "pallas batch path fell back to ref.spgemm_scheduled_ref")

        monkeypatch.setattr(ref, "spgemm_scheduled_ref", boom)
        out = plan.execute_batch(av, bv)
        assert len(out) == 3

    @pytest.mark.parametrize("depth", [1, 2])
    def test_pipeline_batch_stage_matches_execute_batch(self, depth):
        """The pipeline's batched kernel stage runs the same Pallas fold:
        a batched submit == execute_batch, bitwise."""
        plan = _element_plan(seed=7)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=17)
        av, bv = stream.values_batch_at(0, batch=4)
        want = plan.execute_batch(av, bv)
        with plan.pipeline(depth=depth) as pipe:
            got = pipe.submit(av, bv).result()
        assert len(got) == len(want) == 4
        for w, g in zip(want, got):
            _assert_same_csr(w, g)

    def test_pipeline_stream_matches_sequential(self):
        """Single-set pipeline stages on a pallas plan stay bitwise-equal
        to sequential executes."""
        plan = _element_plan(seed=9)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=19)
        seq = [plan.execute(*stream.values_at(s)) for s in range(4)]
        with plan.pipeline(depth=2) as pipe:
            out = list(pipe.stream(stream.values_at(s) for s in range(4)))
        for w, g in zip(seq, out):
            _assert_same_csr(w, g)


# Child-process body for the sharded tests: builds the same integer-valued
# problem, compares a sharded pallas_interpret plan (execute, execute_batch,
# and a depth-2 pipeline stream) against the single-device jnp plan.
_SHARDED_CODE = """
import numpy as np

from repro.data.pipeline import SpGEMMValueStream
from repro.launch.mesh import make_shard_mesh
from repro.sparse.random import random_coo
from repro.spgemm import PlanCache, spgemm_plan

n_shards = {n_shards}

coo = random_coo(144, 112, 0.06, "uniform", seed=4)
rng = np.random.default_rng(1003)
vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
coo.val = np.where(vals == 0, np.float32(1.0), vals)
a = coo.sum_duplicates()
coo2 = random_coo(112, 128, 0.06, "uniform", seed=14)
vals = rng.integers(-4, 5, coo2.nnz).astype(np.float32)
coo2.val = np.where(vals == 0, np.float32(1.0), vals)
b = coo2.sum_duplicates()

single = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                     cache=PlanCache())
sharded = spgemm_plan(a, b, tile=8, group=2, backend="pallas_interpret",
                      cache=PlanCache(), mesh=make_shard_mesh(n_shards))

stream = SpGEMMValueStream(single.a_pattern, single.b_pattern, seed=23)

def same(x, y):
    assert np.array_equal(x.indptr, y.indptr)
    assert np.array_equal(x.indices, y.indices)
    assert np.array_equal(x.data, y.data)

# execute
av, bv = stream.values_at(0)
same(single.execute(av, bv), sharded.execute(av, bv))

# execute_batch vs looped single-device executes
ab, bb = stream.values_batch_at(1, batch=4)
want = [single.execute(ab[i], bb[i]) for i in range(4)]
got = sharded.execute_batch(ab, bb)
for w, g in zip(want, got):
    same(w, g)

# pipeline stream through the sharded pallas stage jits
seq = [single.execute(*stream.values_at(s)) for s in range(3)]
with sharded.pipeline(depth=2) as pipe:
    out = list(pipe.stream(stream.values_at(s) for s in range(3)))
for w, g in zip(seq, out):
    same(w, g)

print("SHARDED_PALLAS_OK", n_shards)
"""


class TestShardedPallasDispatch:
    @pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
    def test_sharded_pallas_matches_single_device(self, forced_devices,
                                                  n_shards):
        out = forced_devices(_SHARDED_CODE.format(n_shards=n_shards),
                             devices=8)
        assert f"SHARDED_PALLAS_OK {n_shards}" in out
