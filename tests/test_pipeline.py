"""Async serving pipeline: stage-split executor + submit/collect API.

The load-bearing invariant is *bitwise equality*: a pipelined stream of N
steps must reproduce N sequential ``execute`` calls exactly — same
``indptr``/``indices``/``data`` — on element, block, batched, and sharded
plans, at every depth. The stage jits run the same ops as the fused cores,
so this is a property of the refactor, not a tolerance.

Sharded coverage runs under 8 forced host devices via the subprocess-safe
``forced_devices`` fixture (see tests/conftest.py).
"""
import numpy as np
import pytest

from repro.data.pipeline import SpGEMMValueStream
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.formats import COO
from repro.sparse.random import random_block_sparse, random_coo
from repro.spgemm import (
    PipelineFullError,
    PlanCache,
    SpGEMMPipeline,
    spgemm_plan,
)


def _element_plan(seed=0, m=96, n=80, k=72, density=0.06, backend="jnp",
                  cache=None):
    a = random_coo(m, k, density, "uniform", seed=seed).sum_duplicates()
    b = random_coo(k, n, density, "uniform", seed=seed + 1).sum_duplicates()
    return spgemm_plan(a, b, tile=8, group=2, backend=backend,
                       cache=cache if cache is not None else PlanCache())


def _block_plan(backend="pallas_interpret"):
    ad = random_block_sparse(128, 128, (32, 32), 0.3, seed=3)
    bd = random_block_sparse(128, 128, (32, 32), 0.3, seed=4)
    return spgemm_plan(to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 32)),
                       backend=backend, cache=PlanCache())


def _assert_same_csr(x, y):
    assert np.array_equal(x.indptr, y.indptr)
    assert np.array_equal(x.indices, y.indices)
    assert np.array_equal(x.data, y.data)


class TestBitwiseEquality:
    @pytest.mark.parametrize("depth", [1, 2, 4])
    def test_element_stream_matches_sequential(self, depth):
        plan = _element_plan()
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
        n = 6
        seq = [plan.execute(*stream.values_at(s)) for s in range(n)]
        with plan.pipeline(depth=depth) as pipe:
            out = list(pipe.stream(stream.values_at(s) for s in range(n)))
        assert len(out) == n
        for c_seq, c_pipe in zip(seq, out):
            _assert_same_csr(c_seq, c_pipe)

    @pytest.mark.parametrize("depth", [1, 2, 4])
    def test_block_plan_matches_sequential(self, depth):
        """Packed-block operands (and the pallas_interpret kernel path)."""
        plan = _block_plan()
        rng = np.random.default_rng(0)
        sets = [
            (
                rng.standard_normal(plan._a_shape).astype(np.float32),
                rng.standard_normal(plan._b_shape).astype(np.float32),
            )
            for _ in range(3)
        ]
        seq = [plan.execute(a, b) for a, b in sets]
        with plan.pipeline(depth=depth) as pipe:
            out = list(pipe.stream(iter(sets)))
        for c_seq, c_pipe in zip(seq, out):
            _assert_same_csr(c_seq, c_pipe)

    @pytest.mark.parametrize("depth", [1, 2])
    def test_batched_submit_matches_execute_batch(self, depth):
        """A submit with a leading batch axis == execute_batch, element
        and block plans."""
        plan = _element_plan(seed=11)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=5)
        av, bv = stream.values_batch_at(0, batch=5)
        want = plan.execute_batch(av, bv)
        with plan.pipeline(depth=depth) as pipe:
            got = pipe.submit(av, bv).result()
        assert len(got) == len(want) == 5
        for w, g in zip(want, got):
            _assert_same_csr(w, g)

        bp = _block_plan(backend="jnp")
        rng = np.random.default_rng(1)
        ab = rng.standard_normal((3,) + bp._a_shape).astype(np.float32)
        bb = rng.standard_normal((3,) + bp._b_shape).astype(np.float32)
        want = bp.execute_batch(ab, bb)
        got = bp.execute_async(ab, bb).result()
        for w, g in zip(want, got):
            _assert_same_csr(w, g)

    def test_noarg_submit_uses_staged_values(self):
        plan = _element_plan(seed=21)
        want = plan.execute()
        got = plan.execute_async().result()
        _assert_same_csr(want, got)

    def test_execute_stream_matches_sequential(self):
        plan = _element_plan(seed=31)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=9)
        n = 5
        seq = [plan.execute(*stream.values_at(s)) for s in range(n)]
        out = list(plan.execute_stream(stream.value_iter(steps=n), depth=2))
        assert len(out) == n
        for c_seq, c_pipe in zip(seq, out):
            _assert_same_csr(c_seq, c_pipe)

    def test_empty_plan_pipeline(self):
        """Disjoint patterns (no products): pipelined results are the
        same empty CSR the synchronous path returns."""
        a = COO(np.array([0], np.int32), np.array([0], np.int32),
                np.ones(1, np.float32), (16, 16))
        b = COO(np.array([8], np.int32), np.array([0], np.int32),
                np.ones(1, np.float32), (16, 16))
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        want = plan.execute(np.ones(1, np.float32), np.ones(1, np.float32))
        got = plan.execute_async(
            np.ones(1, np.float32), np.ones(1, np.float32)).result()
        _assert_same_csr(want, got)
        got_b = plan.execute_async(
            np.ones((2, 1), np.float32), np.ones((2, 1), np.float32)
        ).result()
        assert len(got_b) == 2
        for g in got_b:
            _assert_same_csr(want, g)


class TestPipelineSemantics:
    def test_out_of_order_collect(self):
        plan = _element_plan(seed=41)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        seq = [plan.execute(*stream.values_at(s)) for s in range(3)]
        with plan.pipeline(depth=3) as pipe:
            tickets = [pipe.submit(*stream.values_at(s)) for s in range(3)]
            c2 = pipe.collect(tickets[2])
            c0 = pipe.collect(tickets[0])
            c1 = tickets[1].result()
        _assert_same_csr(seq[0], c0)
        _assert_same_csr(seq[1], c1)
        _assert_same_csr(seq[2], c2)

    def test_depth_exhaustion_and_refill(self):
        plan = _element_plan(seed=51)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        pipe = plan.pipeline(depth=2)
        t0 = pipe.submit(*stream.values_at(0))
        pipe.submit(*stream.values_at(1))
        assert pipe.in_flight == 2
        with pytest.raises(PipelineFullError, match="depth 2 exhausted"):
            pipe.submit(*stream.values_at(2))
        pipe.collect(t0)  # frees a slot
        pipe.submit(*stream.values_at(2))
        assert pipe.in_flight == 2
        list(pipe)  # drain
        assert pipe.in_flight == 0
        assert plan.in_flight == 0

    def test_default_collect_is_oldest(self):
        plan = _element_plan(seed=61)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        seq = [plan.execute(*stream.values_at(s)) for s in range(2)]
        pipe = plan.pipeline(depth=2)
        pipe.submit(*stream.values_at(0))
        pipe.submit(*stream.values_at(1))
        _assert_same_csr(seq[0], pipe.collect())
        _assert_same_csr(seq[1], pipe.collect())
        with pytest.raises(ValueError, match="nothing in flight"):
            pipe.collect()

    def test_double_collect_raises(self):
        plan = _element_plan(seed=71)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        pipe = plan.pipeline(depth=1)
        t = pipe.submit(*stream.values_at(0))
        t.result()
        with pytest.raises(ValueError, match="already collected"):
            t.result()

    def test_foreign_ticket_rejected(self):
        plan = _element_plan(seed=81)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        p1 = plan.pipeline(depth=1)
        p2 = plan.pipeline(depth=1)
        t = p1.submit(*stream.values_at(0))
        with pytest.raises(ValueError, match="different pipeline"):
            p2.collect(t)
        t.result()

    def test_invalid_submit_holds_no_slot(self):
        plan = _element_plan(seed=91)
        pipe = plan.pipeline(depth=1)
        with pytest.raises(ValueError, match="expected a_vals"):
            pipe.submit(np.ones(3, np.float32), np.ones(3, np.float32))
        with pytest.raises(ValueError, match="both a_vals and b_vals"):
            pipe.submit(np.ones(3, np.float32), None)
        assert pipe.in_flight == 0
        assert plan.in_flight == 0

    def test_poisoned_step_propagates_at_collect(self, monkeypatch):
        """A step whose device dispatch fails re-raises at *its* collect;
        neighbors stay collectable and the pipeline stays usable."""
        plan = _element_plan(seed=101)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        seq = [plan.execute(*stream.values_at(s)) for s in range(3)]
        ex = plan._executor
        real = ex.pipe_kernel
        calls = {"n": 0}

        def flaky(staged, *, mode):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("boom at step 1")
            return real(staged, mode=mode)

        monkeypatch.setattr(ex, "pipe_kernel", flaky)
        pipe = plan.pipeline(depth=3)
        tickets = [pipe.submit(*stream.values_at(s)) for s in range(3)]
        _assert_same_csr(seq[0], tickets[0].result())
        with pytest.raises(RuntimeError, match="boom at step 1"):
            tickets[1].result()
        _assert_same_csr(seq[2], tickets[2].result())
        assert plan.in_flight == 0  # the poisoned slot was freed too
        monkeypatch.setattr(ex, "pipe_kernel", real)
        _assert_same_csr(seq[0], pipe.submit(*stream.values_at(0)).result())

    def test_closed_pipeline_rejects_submit(self):
        plan = _element_plan(seed=111)
        pipe = plan.pipeline(depth=1)
        pipe.close()
        with pytest.raises(RuntimeError, match="closed"):
            pipe.submit()


class TestReleaseGuards:
    def test_release_while_in_flight_raises(self):
        plan = _element_plan(seed=121)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        pipe = plan.pipeline(depth=2)
        t = pipe.submit(*stream.values_at(0))
        assert plan.in_flight == 1
        for fn in (plan.release_values, plan.release_device_values,
                   plan.release):
            with pytest.raises(RuntimeError, match="in-flight pipeline"):
                fn()
        t.result()
        assert plan.in_flight == 0
        plan.release_values()  # legal again once drained

    def test_close_unpins_the_plan(self):
        plan = _element_plan(seed=131)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        pipe = plan.pipeline(depth=2)
        pipe.submit(*stream.values_at(0))
        pipe.submit(*stream.values_at(1))
        with pytest.raises(RuntimeError):
            plan.release_values()
        pipe.close()
        assert plan.in_flight == 0
        plan.release_values()

    def test_released_plan_refuses_work(self):
        plan = _element_plan(seed=141)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        plan.release()
        with pytest.raises(RuntimeError, match="released"):
            plan.execute(*stream.values_at(0))
        with pytest.raises(RuntimeError, match="released"):
            plan.execute_batch(*stream.values_batch_at(0, batch=2))
        with pytest.raises(RuntimeError, match="released"):
            plan.pipeline().submit(*stream.values_at(0))

    def test_cache_evict_guard(self):
        cache = PlanCache()
        plan = _element_plan(seed=151, cache=cache)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        (key,) = list(cache._plans)
        t = plan.pipeline(depth=1).submit(*stream.values_at(0))
        with pytest.raises(RuntimeError, match="in-flight pipeline"):
            cache.evict(key)
        assert key in cache  # still resident
        t.result()
        assert cache.evict(key)
        assert key not in cache
        assert not cache.evict(key)  # already gone: False, no error

    def test_lru_eviction_skips_in_flight_plans(self):
        """Automatic capacity eviction never tears down a plan with
        outstanding tickets — it skips to the next LRU candidate."""
        cache = PlanCache(capacity=2)
        p1 = _element_plan(seed=161, cache=cache)
        stream = SpGEMMValueStream(p1.a_pattern, p1.b_pattern, seed=2)
        t = p1.pipeline(depth=1).submit(*stream.values_at(0))
        p2 = _element_plan(seed=162, cache=cache)  # fills capacity
        _element_plan(seed=163, cache=cache)  # would evict p1 (LRU)
        keys = list(cache._plans)
        assert any(cache._plans[k] is p1 for k in keys)  # p1 survived
        assert all(cache._plans[k] is not p2 for k in keys)  # p2 evicted
        t.result()


class TestShardedPipeline:
    @pytest.mark.parametrize("shards", [1, 2, 4, 8])
    def test_sharded_stream_matches_sequential(self, forced_devices,
                                               shards):
        forced_devices(f"""
            import numpy as np
            from repro.data.pipeline import SpGEMMValueStream
            from repro.launch.mesh import make_shard_mesh
            from repro.sparse.formats import COO
            from repro.sparse.random import suite_matrix
            from repro.spgemm import PlanCache, spgemm_plan

            a = suite_matrix("poisson3Da", scale=0.02).to_coo()
            a = a.sum_duplicates()
            b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
            plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                               cache=PlanCache(),
                               mesh=make_shard_mesh({shards}))
            stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern,
                                       seed=3)
            n = 4
            seq = [plan.execute(*stream.values_at(s)) for s in range(n)]
            for depth in (1, 2, 4):
                with plan.pipeline(depth=depth) as pipe:
                    out = list(pipe.stream(
                        stream.values_at(s) for s in range(n)))
                for c_seq, c_pipe in zip(seq, out):
                    assert np.array_equal(c_seq.indptr, c_pipe.indptr)
                    assert np.array_equal(c_seq.indices, c_pipe.indices)
                    assert np.array_equal(c_seq.data, c_pipe.data)
            # batched submit == execute_batch
            av, bv = stream.values_batch_at(0, batch=3)
            want = plan.execute_batch(av, bv)
            got = plan.execute_async(av, bv).result()
            for w, g in zip(want, got):
                assert np.array_equal(w.data, g.data)
            print("ok")
        """)


class TestAbandonment:
    def test_abandoned_ticket_does_not_pin_the_plan(self):
        """Dropping an uncollected execute_async ticket (and its hidden
        pipeline) must release the plan's in-flight count at GC, so
        teardown does not stay blocked forever."""
        import gc

        plan = _element_plan(seed=171)
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=2)
        t = plan.execute_async(*stream.values_at(0))
        assert plan.in_flight == 1
        del t
        gc.collect()
        assert plan.in_flight == 0
        plan.release_values()  # legal: nothing pins the plan anymore
