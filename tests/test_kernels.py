"""Pallas kernel sweeps vs the ref.py oracles (interpret mode on CPU).

Every kernel: shape x dtype sweep with assert_allclose against the pure-jnp
oracle, as required for each Pallas kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import build_spgemm_schedule
from repro.kernels import ops, ref
from repro.kernels.bsr_spmm import bsr_spmm, plan_bsr
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gustavson_spgemm import pad_schedule_arrays, spgemm_scheduled
from repro.kernels.moe_gmm import moe_gmm
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse


class TestGustavsonSpGEMM:
    @pytest.mark.parametrize("shape,blocks,group", [
        ((128, 128, 128), (32, 32, 32), 1),
        ((256, 128, 192), (64, 64, 64), 2),
        ((256, 384, 256), (64, 64, 128), 4),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_vs_oracle_and_dense(self, shape, blocks, group, dtype):
        m, k, n = shape
        bm, bk, bn = blocks
        ad = random_block_sparse(m, k, (bm, bk), 0.35, seed=1).astype(dtype)
        bd = random_block_sparse(k, n, (bk, bn), 0.4, seed=2).astype(dtype)
        a = to_bcsv(np.asarray(ad, np.float32), (bm, bk), group=group)
        b = to_bcsr(np.asarray(bd, np.float32), (bk, bn))
        a.blocks = a.blocks.astype(dtype)
        b.blocks = b.blocks.astype(dtype)
        sch = build_spgemm_schedule(a, b)
        a_slot, b_slot, panel, sub_row, start, _ = pad_schedule_arrays(
            sch.a_slot, sch.b_slot, sch.panel, sch.sub_row, sch.start,
            sch.n_panels)
        panels = spgemm_scheduled(
            jnp.asarray(a.blocks), jnp.asarray(b.blocks),
            jnp.asarray(a_slot), jnp.asarray(b_slot), jnp.asarray(panel),
            jnp.asarray(sub_row), jnp.asarray(start),
            n_panels=sch.n_panels, group=group, interpret=True)
        oracle = ref.spgemm_scheduled_ref(
            jnp.asarray(a.blocks), jnp.asarray(b.blocks),
            sch.a_slot, sch.b_slot, sch.panel, sch.sub_row,
            sch.n_panels, group)
        tol = 1e-5 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(panels), np.asarray(oracle),
                                   rtol=tol, atol=tol)

    def test_end_to_end_spgemm_vs_dense(self):
        ad = random_block_sparse(192, 256, (64, 64), 0.3, seed=3)
        bd = random_block_sparse(256, 192, (64, 64), 0.35, seed=4)
        c = ops.spgemm(to_bcsv(ad, (64, 64), 2), to_bcsr(bd, (64, 64)),
                       backend="pallas_interpret")
        np.testing.assert_allclose(
            c.todense(), ad.astype(np.float64) @ bd.astype(np.float64),
            rtol=1e-4, atol=1e-4)

    def test_jnp_backend_equals_pallas(self):
        ad = random_block_sparse(128, 128, (32, 32), 0.4, seed=5)
        bd = random_block_sparse(128, 128, (32, 64), 0.4, seed=6)
        a, b = to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 64))
        c1 = ops.spgemm(a, b, backend="pallas_interpret").todense()
        c2 = ops.spgemm(a, b, backend="jnp").todense()
        np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


class TestBsrSpMM:
    @pytest.mark.parametrize("m,k,n,bk,bn", [
        (64, 256, 256, 128, 128),
        (200, 384, 512, 128, 128),
        (128, 256, 384, 128, 128),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_vs_dense(self, m, k, n, bk, bn, dtype):
        rng = np.random.default_rng(0)
        x = rng.standard_normal((m, k)).astype(np.float32)
        wd = random_block_sparse(k, n, (bk, bn), 0.5, seed=7)
        w = to_bcsv(wd, (bk, bn), group=1)
        w.blocks = w.blocks.astype(dtype)
        y = ops.sparse_dense_matmul(
            jnp.asarray(x.astype(dtype)), w, backend="pallas_interpret")
        yref = x @ np.asarray(wd, np.float32)
        tol = 1e-3 if dtype == np.float32 else 0.15
        np.testing.assert_allclose(np.asarray(y, np.float32), yref,
                                   rtol=tol, atol=tol)

    def test_empty_column_panels_are_zero(self):
        wd = random_block_sparse(256, 512, (128, 128), 0.5, seed=8)
        wd[:, 128:256] = 0.0  # kill one column panel entirely
        w = to_bcsv(wd, (128, 128), group=1)
        x = np.random.default_rng(1).standard_normal((64, 256)).astype(np.float32)
        y = ops.sparse_dense_matmul(jnp.asarray(x), w,
                                    backend="pallas_interpret")
        assert np.abs(np.asarray(y)[:, 128:256]).max() == 0.0


class TestMoEGMM:
    @pytest.mark.parametrize("t,d,f,e,tm", [
        (256, 128, 256, 2, 128),
        (512, 256, 128, 4, 128),
        (1024, 128, 384, 8, 128),
    ])
    def test_vs_oracle(self, t, d, f, e, tm):
        rng = np.random.default_rng(2)
        x = rng.standard_normal((t, d)).astype(np.float32)
        w = rng.standard_normal((e, d, f)).astype(np.float32)
        te = np.sort(rng.integers(0, e, t // tm)).astype(np.int32)
        y = moe_gmm(jnp.asarray(x), jnp.asarray(w), jnp.asarray(te),
                    tm=tm, bd=128, bf=128, interpret=True)
        yref = ref.moe_gmm_ref(jnp.asarray(x), jnp.asarray(w), te, tm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,d", [(2, 256, 64), (4, 512, 128),
                                        (1, 1024, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_oracle(self, bh, s, d, causal):
        rng = np.random.default_rng(3)
        q, k, v = (rng.standard_normal((bh, s, d)).astype(np.float32)
                   for _ in range(3))
        o = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, bq=128, bk=128, interpret=True)
        oref = ref.flash_attention_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [64, 128, 1024])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(4)
        q, k, v = (rng.standard_normal((2, 512, 64)).astype(np.float32)
                   for _ in range(3))
        o = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, window=window, bq=128, bk=128,
                            interpret=True)
        oref = ref.flash_attention_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                                   rtol=2e-4, atol=2e-4)

    def test_q_offset_chunked_prefill(self):
        """Chunked prefill: second q chunk against the full kv must equal
        the corresponding rows of one-shot attention."""
        rng = np.random.default_rng(5)
        q, k, v = (rng.standard_normal((1, 512, 64)).astype(np.float32)
                   for _ in range(3))
        full = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), causal=True)
        part = flash_attention(
            jnp.asarray(q[:, 256:]), jnp.asarray(k), jnp.asarray(v),
            causal=True, q_offset=256, bq=128, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(part),
                                   np.asarray(full)[:, 256:],
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rng = np.random.default_rng(6)
        q, k, v = (jnp.asarray(rng.standard_normal((2, 256, 64)),
                               jnp.bfloat16) for _ in range(3))
        o = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                            interpret=True)
        oref = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(oref, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_attention_custom_vjp_grads(self):
        rng = np.random.default_rng(7)
        q, k, v = (jnp.asarray(rng.standard_normal((2, 128, 32)),
                               jnp.float32) for _ in range(3))

        def loss_kernel(q, k, v):
            return ops.attention(q, k, v, True, None, 0,
                                 "pallas_interpret").sum()

        def loss_ref(q, k, v):
            return ref.flash_attention_ref(q, k, v, causal=True).sum()

        g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
