"""Device-resident numeric executor tests: jittable output assembly,
vmap-batched execute_batch, and the supporting cache/report satellites."""
import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.core.gustavson import spgemm_gustavson
from repro.data.pipeline import SpGEMMValueStream
from repro.kernels import ref
from repro.sparse.convert import to_bcsr, to_bcsv, to_csr
from repro.sparse.formats import COO, CSR
from repro.sparse.random import random_block_sparse, random_coo
from repro.spgemm import (
    PlanCache,
    SpGEMMPlan,
    schedule_build_count,
    spgemm_plan,
)


def _int_coo(m, n, density, seed):
    """Small-integer float32 values: exact in float32 under any accumulation
    order, so oracle comparisons are bit-for-bit."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo


def _host_assemble(plan, panels: np.ndarray) -> CSR:
    """The pre-executor host assembly (PR 1's SpGEMMPlan._assemble): scan
    each output panel with np.nonzero and scatter into CSR. Kept here as
    the reference the device-side gather assembly must reproduce."""
    sch = plan.schedule
    m, n = plan.assembly.shape
    bm, bn = plan._bm, plan._bn
    rows_l, cols_l, vals_l = [], [], []
    span = sch.group * bm
    for p in range(sch.n_panels):
        g = int(sch.panel_group[p])
        j = int(sch.panel_bcol[p])
        r0 = g * span
        sub = panels[p][: min(span, m - r0)]
        rr, cc = np.nonzero(sub)
        if rr.size == 0:
            continue
        rows_l.append(rr + r0)
        cols_l.append(cc + j * bn)
        vals_l.append(sub[rr, cc])
    if not rows_l:
        return CSR(np.zeros(m + 1, np.int64), np.zeros(0, np.int32),
                   np.zeros(0, np.float32), (m, n))
    coo = COO(
        np.concatenate(rows_l).astype(np.int32),
        np.concatenate(cols_l).astype(np.int32),
        np.concatenate(vals_l), (m, n),
    )
    return CSR.from_coo(coo)


def _kernel_panels(plan) -> np.ndarray:
    """Run only the scheduled kernel (jnp path) on the plan's staged
    blocks, bypassing the executor's fused assembly."""
    sch = plan.schedule
    return np.asarray(ref.spgemm_scheduled_ref(
        plan._a_blocks, plan._b_blocks,
        sch.a_slot, sch.b_slot, sch.panel, sch.sub_row,
        sch.n_panels, sch.group,
    ))


class TestDeviceAssembly:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 500), group=st.integers(1, 3))
    def test_matches_old_host_assemble(self, seed, group):
        """Device gather assembly == the old np.nonzero host assembly on
        random patterns (todense; the structural CSR additionally keeps
        exact-zero elements of nonzero blocks)."""
        rng = np.random.default_rng(seed)
        m, k, n = rng.integers(20, 90, 3)
        a = _int_coo(int(m), int(k), 0.1, seed)
        b = _int_coo(int(k), int(n), 0.12, seed + 7)
        plan = spgemm_plan(a, b, tile=8, group=group, backend="jnp",
                           cache=PlanCache())
        c_dev = plan.execute()
        c_host = _host_assemble(plan, _kernel_panels(plan))
        assert np.array_equal(c_dev.todense(), c_host.todense())
        # Structural pattern: value-independent, includes the host-
        # assembled (value-dependent) support.
        assert c_dev.nnz == plan.assembly.nnz >= c_host.nnz

    def test_execute_numeric_phase_has_no_host_nonzero(self, monkeypatch):
        """Acceptance guard: after warmup, the numeric phase never calls
        np.nonzero on host (assembly runs inside the jitted executor)."""
        a = _int_coo(64, 48, 0.1, 3)
        b = _int_coo(48, 64, 0.1, 4)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        # Warm both executor jits (blocks path and fused values path):
        # tracing itself may touch np.nonzero inside jax.
        plan.execute()
        plan.execute(a.val, b.val)

        def _forbidden(*args, **kwargs):
            raise AssertionError("np.nonzero called in the numeric phase")

        monkeypatch.setattr(np, "nonzero", _forbidden)
        c = plan.execute(a.val * 2.0, b.val)
        monkeypatch.undo()
        ref_c = spgemm_gustavson(
            to_csr(COO(a.row, a.col, a.val * 2.0, a.shape)), to_csr(b))
        assert np.array_equal(c.todense(), ref_c.todense())

    def test_results_share_precomputed_structure(self):
        a = _int_coo(50, 40, 0.15, 11)
        b = _int_coo(40, 50, 0.15, 12)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        c1, c2 = plan.execute(), plan.execute(a.val, b.val)
        assert c1.indptr is plan.assembly.indptr
        assert c1.indices is c2.indices


class TestExecuteBatch:
    @pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
    def test_equals_loop_of_executes(self, backend):
        """execute_batch == a loop of single executes, elementwise and
        bitwise (integer values), on both backends."""
        a = _int_coo(80, 60, 0.1, 21)
        b = _int_coo(60, 70, 0.12, 22)
        plan = spgemm_plan(a, b, tile=16, group=2, backend=backend,
                           cache=PlanCache())
        stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=5,
                                   integer_values=True, batch=5)
        av, bv = stream.values_batch_at(0)
        cs = plan.execute_batch(av, bv)
        assert len(cs) == 5
        for i, c in enumerate(cs):
            single = plan.execute(av[i], bv[i])
            assert np.array_equal(c.todense(), single.todense()), i

    def test_batch_consumes_single_stream_sequence(self):
        a = _int_coo(30, 30, 0.2, 31)
        b = _int_coo(30, 30, 0.2, 32)
        single = SpGEMMValueStream(a, b, seed=9)
        batched = SpGEMMValueStream(a, b, seed=9, batch=3)
        av, bv = batched.values_batch_at(1)  # steps 3, 4, 5
        for i in range(3):
            sa, sb = single.values_at(3 + i)
            assert np.array_equal(av[i], sa) and np.array_equal(bv[i], sb)
        d = batched.batch_at(0)
        assert d["a_vals"].shape == (3, a.nnz)
        with pytest.raises(ValueError):
            single.values_batch_at(0)  # no batch size anywhere

    def test_schedule_builds_flat_across_batched_executes(self):
        a = _int_coo(60, 60, 0.1, 41)
        b = _int_coo(60, 60, 0.1, 42)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        builds = schedule_build_count()
        executes = plan.report.executes
        rng = np.random.default_rng(0)
        for bsz in (1, 4, 9):
            av = rng.integers(-3, 4, (bsz, a.nnz)).astype(np.float32)
            bv = rng.integers(-3, 4, (bsz, b.nnz)).astype(np.float32)
            plan.execute_batch(av, bv)
        assert schedule_build_count() == builds
        assert plan.report.schedule_builds == 1
        assert plan.report.executes == executes + 14

    def test_empty_pattern(self):
        a = COO(np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (32, 16))
        b = _int_coo(16, 24, 0.2, 3)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        cs = plan.execute_batch(np.zeros((3, 0), np.float32),
                                np.tile(b.val, (3, 1)))
        assert len(cs) == 3
        assert all(c.nnz == 0 and c.shape == (32, 24) for c in cs)
        assert plan.execute_batch(np.zeros((0, 0), np.float32),
                                  np.zeros((0, b.nnz), np.float32)) == []

    def test_after_release_values(self):
        """execute_batch never reads staged values: it works after
        release_values(), while no-arg execute raises."""
        a = _int_coo(40, 30, 0.15, 51)
        b = _int_coo(30, 40, 0.15, 52)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        want = plan.execute().todense()
        plan.release_values()
        with pytest.raises(ValueError, match="released"):
            plan.execute()
        cs = plan.execute_batch(a.val[None], b.val[None])
        assert np.array_equal(cs[0].todense(), want)

    def test_block_plan_batch(self):
        """Block plans batch over packed block arrays."""
        ad = random_block_sparse(64, 64, (16, 16), 0.4, seed=61)
        bd = random_block_sparse(64, 64, (16, 16), 0.4, seed=62)
        a, b = to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16))
        plan = spgemm_plan(a, b, backend="jnp", cache=PlanCache())
        av = np.stack([a.blocks, a.blocks * 2.0])
        bv = np.stack([b.blocks, b.blocks])
        cs = plan.execute_batch(av, bv)
        ref64 = ad.astype(np.float64) @ bd.astype(np.float64)
        np.testing.assert_allclose(cs[0].todense(), ref64, rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(cs[1].todense(), 2.0 * ref64, rtol=1e-4,
                                   atol=1e-4)

    def test_shape_validation(self):
        a = _int_coo(40, 30, 0.15, 71)
        b = _int_coo(30, 40, 0.15, 72)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        with pytest.raises(ValueError, match="a_vals"):
            plan.execute_batch(np.zeros((2, a.nnz + 1), np.float32),
                               np.zeros((2, b.nnz), np.float32))
        with pytest.raises(ValueError, match="b_vals"):
            plan.execute_batch(np.zeros((2, a.nnz), np.float32),
                               np.zeros((3, b.nnz), np.float32))


class TestLazyReport:
    def test_from_blocks_report_is_lazy(self):
        ad = random_block_sparse(64, 64, (16, 16), 0.4, seed=81)
        bd = random_block_sparse(64, 64, (16, 16), 0.4, seed=82)
        a, b = to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16))
        plan = SpGEMMPlan.from_blocks(a, b, backend="jnp")
        rep = plan.report
        # Unresolved until read: the uncached shim path pays neither the
        # pattern digest nor the count_nonzero scans.
        assert callable(rep._pattern_key)
        assert callable(rep._nnz_a) and callable(rep._nnz_b)
        plan.execute()  # numeric phase must not force them
        plan.execute(a.blocks, b.blocks)  # nor the shim's value rebind
        assert callable(rep._nnz_a) and callable(rep._pattern_key)
        assert rep.nnz_a == int(np.count_nonzero(a.blocks))
        d = rep.as_dict()
        assert isinstance(d["pattern_key"], str) and len(d["pattern_key"])
        assert d["nnz_b"] == int(np.count_nonzero(b.blocks))

    def test_lazy_nnz_pins_no_memory_past_release(self):
        """Unread nnz thunks read the plan's staged blocks (no operand
        closure): resolving after release_values raises, while the
        pattern digest (index arrays only) still resolves."""
        ad = random_block_sparse(64, 64, (16, 16), 0.4, seed=83)
        bd = random_block_sparse(64, 64, (16, 16), 0.4, seed=84)
        plan = SpGEMMPlan.from_blocks(
            to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16)), backend="jnp")
        plan.release_values()
        with pytest.raises(ValueError, match="released"):
            plan.report.nnz_a
        assert isinstance(plan.report.pattern_key, str)

    def test_element_plan_report_is_concrete(self):
        a = _int_coo(40, 30, 0.15, 91)
        b = _int_coo(30, 40, 0.15, 92)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        assert plan.report.nnz_a == a.nnz and plan.report.nnz_b == b.nnz
        assert isinstance(plan.report.pattern_key, str)


class TestBatchChunkPolicy:
    def _executor(self, seed=0):
        a = _int_coo(48, 48, 0.15, seed)
        b = _int_coo(48, 48, 0.15, seed + 1)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        return plan._executor

    def test_default_policy_is_backend_table(self):
        from repro.spgemm.executor import _CHUNK_POLICY, resolve_chunk_bytes
        import jax
        assert resolve_chunk_bytes() == _CHUNK_POLICY.get(
            jax.default_backend(), _CHUNK_POLICY["cpu"])

    def test_constructor_arg_scales_chunk(self):
        from repro.spgemm.executor import SpGEMMExecutor
        a = _int_coo(48, 48, 0.15, 201)
        b = _int_coo(48, 48, 0.15, 202)
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache())
        ex = plan._executor
        per_set = 4 * ex._per_set_rows * ex._bn
        # Explicit knobs still work (back-compat call signature)...
        assert ex.batch_chunk(small_set_bytes=per_set - 1) == 1
        assert ex.batch_chunk(small_set_bytes=per_set,
                              cache_bytes=3 * per_set) == 3
        # ...and the constructor arg sets the same policy as default.
        tight = SpGEMMExecutor(
            schedule=plan.schedule, assembly=plan.assembly, backend="jnp",
            a_scatter=plan._a_scatter, b_scatter=plan._b_scatter,
            a_shape=plan._a_shape, b_shape=plan._b_shape,
            chunk_bytes=per_set - 1,
        )
        assert tight.batch_chunk() == 1

    def test_env_var_overrides_constructor(self, monkeypatch):
        from repro.spgemm.executor import CHUNK_BYTES_ENV, resolve_chunk_bytes
        monkeypatch.setenv(CHUNK_BYTES_ENV, "1024")
        per_set, cache_bytes = resolve_chunk_bytes(chunk_bytes=1 << 30)
        assert per_set == 1024  # env wins over the constructor arg
        assert cache_bytes >= per_set
        ex = self._executor(203)
        if 4 * ex._per_set_rows * ex._bn > 1024:
            assert ex.batch_chunk() == 1
        monkeypatch.setenv(CHUNK_BYTES_ENV, "0")
        with pytest.raises(ValueError, match="chunk bytes"):
            resolve_chunk_bytes()

    def test_env_var_changes_plan_batching(self, monkeypatch):
        """A tiny budget makes execute_batch run one set per device call
        without changing results."""
        from repro.spgemm.executor import CHUNK_BYTES_ENV
        a = _int_coo(60, 50, 0.12, 211)
        b = _int_coo(50, 60, 0.12, 212)
        want = None
        for env in (None, "1"):
            if env is None:
                monkeypatch.delenv(CHUNK_BYTES_ENV, raising=False)
            else:
                monkeypatch.setenv(CHUNK_BYTES_ENV, env)
            plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                               cache=PlanCache())
            if env is not None:
                assert plan._executor.batch_chunk() == 1
            av = np.stack([a.val, a.val * 2.0])
            bv = np.stack([b.val, b.val])
            got = [c.todense() for c in plan.execute_batch(av, bv)]
            if want is None:
                want = got
            else:
                assert all(np.array_equal(g, w) for g, w in zip(got, want))


class TestCacheStats:
    def test_stats_callable_snapshot(self):
        cache = PlanCache()
        a = _int_coo(40, 40, 0.15, 301)
        b = _int_coo(40, 40, 0.15, 302)
        p = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        s = cache.stats()
        assert s["hits"] == 1 and s["misses"] == 1
        assert s["resident_plans"] == 1
        assert s["resident_bytes"] >= p.host_nbytes() > 0
        assert s["lookups"] == 2 and s["hit_rate"] == 0.5
        # Attribute access (the pre-existing surface) still works.
        assert cache.stats.hits == 1
        cache.clear()
        assert cache.stats()["resident_plans"] == 0

    def test_eviction_updates_residency(self):
        cache = PlanCache(capacity=1)
        for seed in (311, 322):
            a = _int_coo(40, 40, 0.15, seed)
            b = _int_coo(40, 40, 0.15, seed + 1)
            spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        s = cache.stats()
        assert s["evictions"] == 1 and s["resident_plans"] == 1

    def test_report_surfaces_cache_stats(self):
        cache = PlanCache()
        a = _int_coo(40, 40, 0.15, 331)
        b = _int_coo(40, 40, 0.15, 332)
        p = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        d = p.report.as_dict()
        assert d["cache_stats"]["misses"] == 1
        spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        assert p.report.as_dict()["cache_stats"]["hits"] == 1
        # Uncached from_blocks plans carry no cache stats.
        from repro.sparse.convert import to_bcsv as _tv, to_bcsr as _tr
        ad = random_block_sparse(32, 32, (16, 16), 0.5, seed=341)
        bp = SpGEMMPlan.from_blocks(_tv(ad, (16, 16), 2), _tr(ad, (16, 16)),
                                    backend="jnp")
        assert bp.report.as_dict()["cache_stats"] is None


class TestPlanCacheBytes:
    def _plan(self, seed, cache):
        a = _int_coo(64, 64, 0.15, seed)
        b = _int_coo(64, 64, 0.15, seed + 1)
        return spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=cache)

    def test_host_nbytes_positive_and_shrinks_nothing(self):
        plan = self._plan(101, PlanCache())
        n = plan.host_nbytes()
        assert n > 0
        plan.release_values()
        assert 0 < plan.host_nbytes() < n

    def test_max_bytes_evicts_lru(self):
        probe = self._plan(111, PlanCache())
        budget = int(probe.host_nbytes() * 1.5)
        cache = PlanCache(max_bytes=budget)
        p1 = self._plan(111, cache)
        p2 = self._plan(222, cache)  # over budget -> evicts p1
        assert len(cache) == 1
        assert cache.stats.evictions == 1
        assert cache.total_bytes <= budget
        # p2 (most recent) survives even if it alone busts the budget.
        small = PlanCache(max_bytes=1)
        p3 = self._plan(333, small)
        assert len(small) == 1
        p3b = self._plan(333, small)
        assert p3b is p3

    def test_count_cap_still_applies(self):
        cache = PlanCache(capacity=2)
        plans = [self._plan(s, cache) for s in (211, 222, 233)]
        assert len(cache) == 2
        assert cache.stats.evictions == 1
        with pytest.raises(ValueError):
            PlanCache(max_bytes=0)
