"""Per-pattern plan autotuner (repro.spgemm.autotune).

Coverage layers:

* the probe primitives are deterministic under an injected fake clock
  (exactly two timer calls per measurement, interleaved repeat order);
* the roofline ranking helpers order candidates by traffic/flops and the
  model-vs-measured agreement metric behaves at its extremes;
* the two-stage search is steered entirely by the fake timer: the model
  pruning always keeps the requested default config, the measured winner
  (tile/group/chunk) is applied to the returned plan, and the recorded
  values/s come from the scripted durations;
* tuned configs are durable: bitwise ``TunedConfig`` round-trips through
  the ``PlanStore`` sidecar and the plan artifact meta, warm restarts
  (fresh caches and a genuinely fresh process) apply the persisted
  config with **zero** probe executions;
* numerics are untouched: tuned plans are bitwise-equal to untuned plans
  built directly at the tuned (tile, group) on the execute /
  execute_batch / pipeline paths, on paper matrices;
* ``REPRO_SPGEMM_CHUNK_BYTES`` still beats a tuned config, and the
  gateway reports per-pattern config provenance.
"""
import os

import numpy as np
import pytest

from repro.core.perfmodel import (
    CPU_XEON_E5_2637,
    roofline_seconds,
    spgemm_schedule_traffic,
)
from repro.core.tuning import best_ms, interleaved_best_ms
from repro.sparse.formats import COO
from repro.sparse.random import random_coo, suite_matrix
from repro.spgemm import PlanCache, SpGEMMGateway, spgemm_plan
from repro.spgemm.autotune import (
    TunedConfig,
    _default_candidates,
    _ranking_agreement,
    autotune_plan,
    probe_run_count,
)
from repro.spgemm.executor import CHUNK_BYTES_ENV, resolve_chunk_bytes


def _int_coo(m, n, density, seed):
    """Small-integer float32 values — exact in f32, so tuned-vs-untuned
    comparisons can demand bitwise equality."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo


def _mats(seed=7, shape=(96, 96), density=0.06):
    a = _int_coo(shape[0], shape[1], density, seed)
    b = COO(a.col, a.row, a.val, (shape[1], shape[0]))
    return a, b


class FakeTimer:
    """A perf_counter stand-in scripted by per-measurement durations.

    The probe contract is exactly two timer calls per measurement
    (start, stop): every even call pops the next scripted duration and
    advances the clock by it, so measurement k reads ``durations[k]``
    seconds regardless of how long the probed code really ran."""

    def __init__(self, durations):
        self.durations = [float(d) for d in durations]
        self.t = 0.0
        self.calls = 0

    def __call__(self):
        self.calls += 1
        if self.calls % 2 == 0:
            self.t += self.durations.pop(0)
        return self.t


class TestProbePrimitives:
    def test_best_ms_fake_timer(self):
        timer = FakeTimer([0.004, 0.002, 0.003])
        assert best_ms(lambda: 0, 3, timer=timer) == pytest.approx(2.0)
        assert timer.calls == 6  # exactly two per repeat

    def test_interleaved_best_ms_fake_timer(self):
        # Interleaved order: repeat 0 runs fn0 then fn1, repeat 1 again —
        # so the scripted durations land [fn0, fn1, fn0, fn1].
        timer = FakeTimer([0.002, 0.003, 0.001, 0.005])
        got = interleaved_best_ms([lambda: 0, lambda: 0], 2, timer=timer)
        assert got == pytest.approx([1.0, 3.0])
        assert timer.calls == 8

    def test_ranking_agreement_extremes(self):
        assert _ranking_agreement([1.0, 2.0, 3.0], [10.0, 20.0, 30.0]) == 1.0
        assert _ranking_agreement([1.0, 2.0, 3.0], [30.0, 20.0, 10.0]) == 0.0
        # Model ties carry no information: half credit.
        assert _ranking_agreement([1.0, 1.0], [10.0, 20.0]) == 0.5


class TestModelRanking:
    def test_traffic_counts_scale_with_tile(self):
        base = dict(num_triples=100, nnzb_a=40, b_fetches=60, n_panels=10,
                    group=4)
        t8 = spgemm_schedule_traffic(tile=(8, 8, 8), **base)
        t16 = spgemm_schedule_traffic(tile=(16, 16, 16), **base)
        assert t16["flops"] == 8 * t8["flops"]  # 2*triples*bm*bk*bn
        assert t16["bytes"] == 4 * t8["bytes"]  # per-block area x4

    def test_roofline_takes_memory_floor(self):
        dev = CPU_XEON_E5_2637
        flops = dev.peak_flops  # 1s of compute
        tiny = roofline_seconds(flops, 0.0, dev)
        assert tiny == pytest.approx(1.0)
        heavy = roofline_seconds(flops, dev.mem_bandwidth * 10, dev)
        assert heavy == pytest.approx(10.0)  # memory-bound

    def test_default_candidates_include_request(self):
        grid = _default_candidates((16, 16, 16), 2)
        assert ((16, 16, 16), 2) in grid
        assert all(all(8 <= d <= 256 for d in t) for t, _ in grid)
        assert all(g >= 1 for _, g in grid)


class TestSearch:
    """The fake timer steers the whole search deterministically."""

    def test_requested_config_always_survives_pruning(self):
        """model_top_k=1 with a grid where the request ranks last: the
        default must still be probed (it is the winner under a timer that
        makes everything else slow)."""
        a, b = _mats(1)
        cache = PlanCache()
        cands = [((8, 8, 8), 2), ((16, 16, 16), 2), ((32, 32, 32), 2)]
        # Entries = survivors x chunks; model_top_k=1 + forced default ->
        # at most 2 survivors, 1 chunk candidate -> <= 2 measurements per
        # repeat. Scripted durations cover the worst case; leftovers are
        # simply never popped.
        durations = []
        for _ in range(2):  # repeats
            durations += [1.0, 0.001]
        plan = autotune_plan(
            a, b, tile=8, group=2, backend="jnp", cache=cache,
            candidates=cands, chunk_candidates=[None],
            depth_candidates=(2,), model_top_k=1, probe_batch=2,
            repeats=2, timer=FakeTimer(durations),
        )
        cfg = plan.tuned_config
        # If the model's top pick was already (8,8,8), the scripted order
        # flips — accept either, but the requested config must have been
        # measured and the plan's config must be a member of the grid.
        assert (cfg.tile, cfg.group) in cands
        assert cfg.probes > 0
        assert cfg.default_values_per_s > 0  # the default WAS measured

    def test_measured_winner_and_chunk_applied(self):
        """One (tile, group) candidate, two chunk candidates: the faster
        scripted chunk wins and lands on the executor."""
        a, b = _mats(2)
        cache = PlanCache()
        plan = autotune_plan(
            a, b, tile=16, group=2, backend="jnp", cache=cache,
            candidates=[((16, 16, 16), 2)],
            chunk_candidates=[None, 123456],
            depth_candidates=(2,), model_top_k=1, probe_batch=2,
            repeats=1, timer=FakeTimer([0.010, 0.002]),
        )
        cfg = plan.tuned_config
        assert (cfg.tile, cfg.group) == ((16, 16, 16), 2)
        assert cfg.chunk_bytes == 123456
        assert plan._executor._chunk_policy == resolve_chunk_bytes(123456)
        assert plan.report.config_source == "tuned"
        assert plan.report.tuned == cfg.to_meta()
        # values/s computed from the scripted 2 ms winner / 10 ms default.
        assert cfg.values_per_s == pytest.approx(2 / 0.002)
        assert cfg.default_values_per_s == pytest.approx(2 / 0.010)
        assert cfg.speedup == pytest.approx(5.0)

    def test_tuned_depth_steers_pipeline_default(self):
        a, b = _mats(3)
        plan = autotune_plan(
            a, b, tile=16, group=2, backend="jnp", cache=PlanCache(),
            candidates=[((16, 16, 16), 2)], chunk_candidates=[None],
            depth_candidates=(1, 4), model_top_k=1, probe_batch=2,
            repeats=1,
            # chunk stage: 1 measurement; depth stage: depth 1 slow,
            # depth 4 fast.
            timer=FakeTimer([0.002, 0.050, 0.001]),
        )
        assert plan.tuned_config.pipeline_depth == 4
        pipe = plan.pipeline()  # depth=None -> tuned depth
        assert pipe.depth == 4
        pipe.close()

    def test_block_input_restricts_to_chunk_and_depth(self):
        from repro.sparse.convert import to_bcsr, to_bcsv
        from repro.sparse.random import random_block_sparse

        ad = random_block_sparse(64, 64, (16, 16), 0.4, seed=31)
        bd = random_block_sparse(64, 64, (16, 16), 0.4, seed=32)
        ab, bb = to_bcsv(ad, (16, 16), 2), to_bcsr(bd, (16, 16))
        plan = autotune_plan(
            ab, bb, backend="jnp", cache=PlanCache(),
            chunk_candidates=[None], depth_candidates=(2,),
            probe_batch=2, repeats=1, timer=FakeTimer([0.001]),
        )
        cfg = plan.tuned_config
        # Tile/group come from the block formats; only chunk/depth tuned.
        assert cfg.tile == (16, 16, 16) and cfg.group == 2


class TestPersistence:
    CFG = TunedConfig(
        tile=(16, 16, 16), group=2, chunk_bytes=789,
        pipeline_depth=4, values_per_s=1234.5678901234567,
        default_values_per_s=1000.0000000000001, model_rank=1,
        ranking_agreement=2.0 / 3.0, probes=12,
    )

    def test_meta_roundtrip_bitwise(self):
        back = TunedConfig.from_meta(self.CFG.to_meta())
        assert back == self.CFG  # f64 fields bitwise via dataclass eq

    def test_sidecar_roundtrip_bitwise(self, tmp_path):
        key = ("pat", (16, 16, 16), 2, "jnp", None)
        c1 = PlanCache(disk_dir=str(tmp_path))
        c1.tuned_put(key, self.CFG.to_meta())
        assert c1.stats.tuned_stores == 1
        # Fresh cache over the same dir: memory tier empty, disk serves.
        c2 = PlanCache(disk_dir=str(tmp_path))
        meta = c2.tuned_get(key)
        assert meta is not None and c2.stats.tuned_hits == 1
        back = TunedConfig.from_meta(meta, source="persisted")
        assert back == TunedConfig.from_meta(
            self.CFG.to_meta(), source="persisted"
        )
        # values/s floats survive the JSON header bitwise.
        assert back.values_per_s == self.CFG.values_per_s
        assert back.ranking_agreement == self.CFG.ranking_agreement

    def test_tuned_miss_counted(self):
        c = PlanCache()
        assert c.tuned_get(("nope",)) is None
        assert c.stats.tuned_misses == 1

    def test_plan_artifact_carries_tuned_config(self, tmp_path):
        """persist_artifacts/from_artifacts round-trip the tuned config:
        a copied artifact file rehydrates tuned on its own."""
        from repro.spgemm.plan import SpGEMMPlan

        a, b = _mats(4)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        cfg = TunedConfig(
            tile=(16, 16, 16), group=2, chunk_bytes=55555,
            pipeline_depth=3, values_per_s=10.0,
            default_values_per_s=9.0, model_rank=0,
            ranking_agreement=1.0, probes=6,
        )
        plan.apply_tuned_config(cfg)
        arrays, meta = plan.persist_artifacts()
        assert meta["tuned_config"] == cfg.to_meta()
        back = SpGEMMPlan.from_artifacts(
            arrays, meta, backend="jnp",
            a_vals=a.val, b_vals=b.val,
        )
        assert back.tuned_config is not None
        assert back.tuned_config.source == "persisted"
        assert back.tuned_config.chunk_bytes == 55555
        assert back.report.config_source == "persisted"
        assert back._executor._chunk_policy == resolve_chunk_bytes(55555)
        assert back._default_depth() == 3

    def test_warm_restart_zero_probes(self, tmp_path):
        """Fresh cache over the tuned directory: the persisted config is
        applied without a single probe execution."""
        a, b = _mats(5)
        c1 = PlanCache(disk_dir=str(tmp_path))
        tuned = autotune_plan(
            a, b, tile=16, group=2, backend="jnp", cache=c1,
            candidates=[((16, 16, 16), 2), ((8, 8, 8), 2)],
            chunk_candidates=[None], depth_candidates=(2,),
            model_top_k=2, probe_batch=2, repeats=1,
            timer=FakeTimer([0.002, 0.004]),
        )
        cfg = tuned.tuned_config
        before = probe_run_count()
        c2 = PlanCache(disk_dir=str(tmp_path))
        warm = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=c2, autotune=True)
        assert probe_run_count() == before, "warm restart paid probes"
        assert warm.report.config_source == "persisted"
        assert warm.report.schedule_builds == 0
        assert warm.tuned_config == TunedConfig.from_meta(
            cfg.to_meta(), source="persisted"
        )


class TestPrecedence:
    def test_env_override_beats_tuned_config(self, monkeypatch):
        a, b = _mats(6)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        cfg = TunedConfig(
            tile=(16, 16, 16), group=2, chunk_bytes=999999,
            pipeline_depth=2, values_per_s=1.0, default_values_per_s=1.0,
            model_rank=0, ranking_agreement=1.0, probes=2,
        )
        monkeypatch.setenv(CHUNK_BYTES_ENV, str(4096))
        plan.apply_tuned_config(cfg)
        # resolve_chunk_bytes re-reads the env inside set_chunk_bytes:
        # the operator override wins over the tuned value.
        assert plan._executor._chunk_policy[0] == 4096
        assert plan.report.config_source == "env-override"
        assert plan.report.tuned == cfg.to_meta()  # still auditable

    def test_mismatched_config_degrades_to_default(self):
        """A config tuned at a different (tile, group) is *stale*, not
        fatal: it is ignored, recorded as ``config_source="stale-tuned"``,
        and surfaced by the verifier as a ``tuned.stale-config`` warning
        — the plan keeps executing on policy defaults."""
        from repro.analysis.verify import verify_plan

        a, b = _mats(7)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        cfg = TunedConfig(
            tile=(8, 8, 8), group=2, chunk_bytes=None, pipeline_depth=2,
            values_per_s=1.0, default_values_per_s=1.0, model_rank=0,
            ranking_agreement=1.0, probes=0,
        )
        plan.apply_tuned_config(cfg)  # must NOT raise
        assert plan.tuned_config is None
        assert plan.report.tuned is None
        assert plan.report.config_source == "stale-tuned"
        rep = verify_plan(plan)
        assert rep.ok  # a warning, not an error
        stale = [f for f in rep.findings if f.check == "tuned.stale-config"]
        assert len(stale) == 1 and stale[0].severity == "warning"
        # Numerics are untouched by the fallback.
        ref = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                          cache=PlanCache())
        assert np.array_equal(plan.execute().data, ref.execute().data)

    def test_drifted_sidecar_rehydrates_with_fallback(self, tmp_path):
        """Regression: a persisted artifact whose embedded tuned config
        was hand-drifted (tile no longer matching the symbolic facts)
        must rehydrate as a working plan on defaults — the old behavior
        raised out of ``from_artifacts`` and made the artifact
        unloadable."""
        from repro.spgemm.plan import SpGEMMPlan

        a, b = _mats(8)
        a, b = a.sum_duplicates(), b.sum_duplicates()  # canonical order
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache())
        cfg = TunedConfig(
            tile=(16, 16, 16), group=2, chunk_bytes=4096, pipeline_depth=3,
            values_per_s=2.0, default_values_per_s=1.0, model_rank=0,
            ranking_agreement=1.0, probes=4,
        )
        plan.apply_tuned_config(cfg)
        arrays, meta = plan.persist_artifacts()
        # Hand-drift the sidecar record: claims a tile the plan was
        # never built at.
        meta = dict(meta)
        drifted = dict(meta["tuned_config"])
        drifted["tile"] = [8, 8, 8]
        meta["tuned_config"] = drifted
        back = SpGEMMPlan.from_artifacts(
            arrays, meta, backend="jnp", pattern_key=plan.report.pattern_key,
            a_vals=a.val, b_vals=b.val, a_pattern=a, b_pattern=b,
        )
        assert back.tuned_config is None
        assert back.report.config_source == "stale-tuned"
        assert back._stale_tuned is not None
        assert np.array_equal(back.execute().data, plan.execute().data)


class TestBitwise:
    """Tuned plans never change numerics: results are bitwise-equal to an
    untuned plan built directly at the tuned (tile, group)."""

    @pytest.mark.parametrize("name,scale", [
        ("poisson3Da", 0.004), ("2cubes_sphere", 0.002),
    ])
    def test_tuned_bitwise_on_paper_matrices(self, name, scale):
        a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
        rng = np.random.default_rng(17)
        v = rng.integers(-4, 5, a.nnz).astype(np.float32)
        a.val = np.where(v == 0, np.float32(1.0), v)
        b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
        tuned = autotune_plan(
            a, b, tile=16, group=2, backend="jnp", cache=PlanCache(),
            model_top_k=2, probe_batch=2, repeats=1,
            depth_candidates=(2,),
        )
        cfg = tuned.tuned_config
        ref = spgemm_plan(a, b, tile=cfg.tile, group=cfg.group,
                          backend="jnp", cache=PlanCache())
        av = rng.integers(-3, 4, a.nnz).astype(np.float32)
        bv = rng.integers(-3, 4, b.nnz).astype(np.float32)
        c_t, c_r = tuned.execute(av, bv), ref.execute(av, bv)
        assert np.array_equal(c_t.indptr, c_r.indptr)
        assert np.array_equal(c_t.indices, c_r.indices)
        assert np.array_equal(c_t.data, c_r.data)
        # Batched path (the tuned chunk policy reshapes device calls,
        # never values).
        avb = rng.integers(-3, 4, (5, a.nnz)).astype(np.float32)
        bvb = rng.integers(-3, 4, (5, b.nnz)).astype(np.float32)
        for x, y in zip(tuned.execute_batch(avb, bvb),
                        ref.execute_batch(avb, bvb)):
            assert np.array_equal(x.data, y.data)
        # Pipelined path at the tuned depth.
        items = [(avb[i], bvb[i]) for i in range(5)]
        outs_t = list(tuned.execute_stream(iter(items)))
        outs_r = [ref.execute(x, y) for x, y in items]
        for x, y in zip(outs_t, outs_r):
            assert np.array_equal(x.data, y.data)
        # And the tuned result agrees with the dense product of the
        # rebound (av, bv) values — which align with the plan's
        # *canonical* patterns, not the raw input entry order.
        ap, bp = tuned.a_pattern, tuned.b_pattern
        ad = np.zeros(a.shape, np.float32)
        ad[ap.row, ap.col] = av
        bd = np.zeros(b.shape, np.float32)
        bd[bp.row, bp.col] = bv
        np.testing.assert_allclose(
            c_t.todense(), ad @ bd, rtol=1e-6, atol=1e-5)

    def test_sharded_tuned_bitwise(self):
        from repro.launch.mesh import make_shard_mesh

        a, b = _mats(9, shape=(120, 90), density=0.08)
        mesh = make_shard_mesh(1)
        tuned = autotune_plan(
            a, b, tile=8, group=2, backend="jnp", cache=PlanCache(),
            mesh=mesh, candidates=[((8, 8, 8), 2)],
            chunk_candidates=[None, 4096], depth_candidates=(2,),
            probe_batch=2, repeats=1, timer=FakeTimer([0.004, 0.001]),
        )
        assert tuned.tuned_config.chunk_bytes == 4096
        ref = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                          cache=PlanCache(), mesh=mesh)
        rng = np.random.default_rng(23)
        av = rng.integers(-3, 4, a.nnz).astype(np.float32)
        bv = rng.integers(-3, 4, b.nnz).astype(np.float32)
        assert np.array_equal(tuned.execute(av, bv).data,
                              ref.execute(av, bv).data)


class TestGatewayIntegration:
    def test_register_autotune_and_stats_provenance(self):
        a, b = _mats(10)
        gw = SpGEMMGateway(cache=PlanCache(), start=True, depth=2)
        try:
            plan = gw.register(
                "t0/l0", a, b, tile=16, group=2, backend="jnp",
                autotune={
                    "candidates": [((16, 16, 16), 2)],
                    "chunk_candidates": [None],
                    "depth_candidates": (4,),
                    "probe_batch": 2, "repeats": 1,
                    "timer": FakeTimer([0.001]),
                },
            )
            assert plan.tuned_config is not None
            av = np.asarray(a.val, np.float32)
            bv = np.asarray(b.val, np.float32)
            res = gw.submit("t0/l0", av, bv).wait()
            ref = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                              cache=PlanCache()).execute(av, bv)
            assert np.array_equal(res.value.data, ref.data)
            st = gw.stats()["patterns"]["t0/l0"]
            assert st["config_source"] == "tuned"
            assert st["tuned"] == plan.tuned_config.to_meta()
            assert st["pipeline_depth"] == 4  # tuned depth beats gateway's
        finally:
            gw.close()

    def test_untuned_pattern_reports_default(self):
        a, b = _mats(11)
        gw = SpGEMMGateway(cache=PlanCache(), start=False, depth=2)
        gw.register("t1/l0", a, b, tile=16, group=2, backend="jnp")
        st = gw.stats()["patterns"]["t1/l0"]
        assert st["config_source"] == "default"
        assert st["tuned"] is None
        assert st["pipeline_depth"] == 2
        gw.close()


AUTOTUNE_PROCESS = """
import os
import numpy as np
from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.spgemm import spgemm_plan
from repro.spgemm.autotune import probe_run_count

assert os.environ["REPRO_SPGEMM_PLAN_DIR"]
WARM = {warm}
a = suite_matrix("poisson3Da", scale=0.004).to_coo().sum_duplicates()
rng = np.random.default_rng(0)
v = rng.integers(-4, 5, a.nnz).astype(np.float32)
a.val = np.where(v == 0, np.float32(1.0), v)
b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
plan = spgemm_plan(
    a, b, tile=16, group=2, backend="jnp",
    autotune={{"model_top_k": 2, "probe_batch": 2, "repeats": 1,
               "depth_candidates": (2,)}},
)
cfg = plan.tuned_config
assert cfg is not None
if WARM:
    assert probe_run_count() == 0, "warm process paid probes"
    assert plan.report.config_source == "persisted"
    assert cfg.source == "persisted"
else:
    assert probe_run_count() == cfg.probes > 0
    assert plan.report.config_source == "tuned"
import json
print("CFG " + json.dumps(cfg.to_meta(), sort_keys=True))
"""


class TestWarmRestartProcess:
    def test_second_process_zero_probes(self, tmp_path, forced_devices):
        """The acceptance scenario with real processes: process 1 searches
        and persists; process 2 — a fresh interpreter — applies the exact
        same TunedConfig with its probe counter still at zero."""
        os.environ["REPRO_SPGEMM_PLAN_DIR"] = str(tmp_path)
        try:
            cold = forced_devices(
                AUTOTUNE_PROCESS.format(warm=False), devices=1)
            warm = forced_devices(
                AUTOTUNE_PROCESS.format(warm=True), devices=1)
        finally:
            del os.environ["REPRO_SPGEMM_PLAN_DIR"]
        get = lambda out: [ln for ln in out.splitlines()
                           if ln.startswith("CFG ")][0]
        cold_cfg, warm_cfg = get(cold), get(warm)
        # Identical except provenance: the warm process loaded, not probed.
        assert cold_cfg.replace('"probed"', '"persisted"') == warm_cfg
