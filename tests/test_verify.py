"""Static plan verifier: pristine plans pass, injected faults are caught.

Two halves, mirroring the verifier's contract:

* **Soundness** — every plan the builders produce (element, block,
  sharded at 1/2/4/8 shards, tuned, disk-rehydrated) verifies clean, and
  ``spgemm_plan(..., validate="deep")`` returns normally on all of them.
* **Completeness** — for each invariant family, a targeted mutation of a
  pristine plan's symbolic arrays must produce an error finding of the
  expected check class (hypothesis drives the mutation positions), and a
  corrupted-but-digest-valid disk artifact must fail verification inside
  the loader and fall back to a clean symbolic rebuild — never execute.
"""
import dataclasses
import glob
import json
import os

import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.analysis.verify import (
    PlanVerificationError,
    check_assembly,
    check_batch_races,
    check_schedule,
    check_shard_partition,
    verify_plan,
)
from repro.analysis.kernel_lint import lint_kernel_module, lint_plan_kernel_specs
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse, random_coo
from repro.spgemm import PlanCache, spgemm_plan


def _mats(seed=0, m=96, n=80, k=72, density=0.06):
    a = random_coo(m, k, density, "uniform", seed=seed).sum_duplicates()
    b = random_coo(k, n, density, "uniform", seed=seed + 1).sum_duplicates()
    return a, b


def _element_plan(**kw):
    a, b = _mats()
    return spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                       cache=PlanCache(), **kw)


def _block_plan(**kw):
    ad = random_block_sparse(128, 128, (32, 32), 0.3, seed=3)
    bd = random_block_sparse(128, 128, (32, 32), 0.3, seed=4)
    return spgemm_plan(to_bcsv(ad, (32, 32), 2), to_bcsr(bd, (32, 32)),
                       backend="jnp", cache=PlanCache(), **kw)


def _checks(findings):
    return {f.check for f in findings if f.severity == "error"}


class TestPristinePlansVerifyClean:
    def test_element_plan(self):
        plan = _element_plan()
        rep = verify_plan(plan)
        assert rep.ok, rep.summary()
        assert rep.plan_kind == "element" and not rep.sharded
        assert lint_plan_kernel_specs(plan) == []

    def test_block_plan(self):
        plan = _block_plan()
        rep = verify_plan(plan)
        assert rep.ok, rep.summary()
        assert rep.plan_kind == "block"
        assert lint_plan_kernel_specs(plan) == []

    def test_sharded_plan_single_device(self):
        from repro.launch.mesh import make_shard_mesh

        a, b = _mats(2, m=128)
        plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache(), mesh=make_shard_mesh(1))
        rep = verify_plan(plan)
        assert rep.ok, rep.summary()
        assert rep.sharded

    @pytest.mark.parametrize("shards", [2, 4, 8])
    def test_sharded_plans_forced_devices(self, forced_devices, shards):
        out = forced_devices(f"""
from repro.launch.mesh import make_shard_mesh
from repro.sparse.random import random_coo
from repro.spgemm import PlanCache, spgemm_plan
from repro.analysis.verify import verify_plan

a = random_coo(160, 96, 0.05, "uniform", seed=0).sum_duplicates()
b = random_coo(96, 112, 0.05, "uniform", seed=1).sum_duplicates()
plan = spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                   cache=PlanCache(), mesh=make_shard_mesh({shards}),
                   validate="deep")
rep = verify_plan(plan)
assert rep.ok, rep.summary()
assert rep.sharded and plan.n_shards == {shards}
print("SHARDED-VERIFY-OK")
""")
        assert "SHARDED-VERIFY-OK" in out

    def test_tuned_plan(self):
        from repro.spgemm.autotune import TunedConfig

        plan = _element_plan()
        plan.apply_tuned_config(TunedConfig(
            tile=(8, 8, 8), group=2, chunk_bytes=55555, pipeline_depth=3,
            values_per_s=10.0, default_values_per_s=9.0, model_rank=0,
            ranking_agreement=1.0, probes=6,
        ))
        rep = verify_plan(plan)
        assert rep.ok, rep.summary()

    def test_rehydrated_plan(self, tmp_path):
        a, b = _mats(7)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=PlanCache(disk_dir=str(tmp_path)))
        warm = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(disk_dir=str(tmp_path)),
                           validate="deep")
        assert warm.report.load_hits >= 1
        assert verify_plan(warm).ok

    def test_kernel_module_lint_clean(self):
        assert lint_kernel_module() == []

    def test_deep_validate_all_return_paths(self, tmp_path):
        a, b = _mats(9)
        cache = PlanCache(disk_dir=str(tmp_path))
        fresh = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                            cache=cache, validate="deep",
                            pattern_token="t/deep")
        hit = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                          cache=cache, validate="deep",
                          pattern_token="t/deep")
        assert hit is fresh
        blk = _block_plan(validate="deep")
        assert blk.schedule.num_triples > 0
        with pytest.raises(ValueError, match="validate"):
            spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                        cache=PlanCache(), validate="shallow")


class TestScheduleFaultInjection:
    """Each mutation class must be detected by its check family."""

    def _plan(self):
        return _element_plan()

    def _nnzb(self, plan):
        return int(plan._a_shape[0]), int(plan._b_shape[0])

    def _run(self, plan, schedule):
        findings = []
        na, nb = self._nnzb(plan)
        check_schedule(schedule, na, nb, findings)
        return _checks(findings)

    @given(pos=st.integers(min_value=0, max_value=10 ** 9))
    @settings(max_examples=12, deadline=None)
    def test_out_of_bounds_a_slot(self, pos):
        plan = self._plan()
        s = plan.schedule
        na, _ = self._nnzb(plan)
        a_slot = s.a_slot.copy()
        a_slot[pos % len(a_slot)] = na  # one past the last real block
        got = self._run(plan, dataclasses.replace(s, a_slot=a_slot))
        assert "schedule.a-slot-bounds" in got

    @given(pos=st.integers(min_value=0, max_value=10 ** 9))
    @settings(max_examples=12, deadline=None)
    def test_out_of_bounds_panel(self, pos):
        plan = self._plan()
        s = plan.schedule
        panel = s.panel.copy()
        panel[pos % len(panel)] = s.n_panels  # the write-only dummy slot
        mut = dataclasses.replace(s, panel=panel)
        assert "schedule.panel-bounds" in self._run(plan, mut)

    @given(pos=st.integers(min_value=1, max_value=10 ** 9))
    @settings(max_examples=12, deadline=None)
    def test_start_flag_corruption(self, pos):
        plan = self._plan()
        s = plan.schedule
        start = s.start.copy()
        i = pos % len(start)
        start[i] = 1 - start[i]
        got = self._run(plan, dataclasses.replace(s, start=start))
        assert "schedule.start-flags" in got

    def test_split_panel_run(self):
        """A panel revisited in two separate runs (the revisit hazard the
        contiguity rule exists for) is caught."""
        plan = self._plan()
        s = plan.schedule
        if s.num_triples < 3 or s.n_panels < 2:
            pytest.skip("schedule too small to split a run")
        panel = s.panel.copy()
        start = s.start.copy()
        # Re-target the last triple at the first panel: panel 0 now has a
        # second, disjoint run at the end of the schedule.
        panel[-1] = panel[0]
        start[-1] = 1
        got = self._run(
            plan, dataclasses.replace(s, panel=panel, start=start)
        )
        assert "schedule.panel-contiguity" in got or \
            "schedule.panel-coverage" in got

    def test_unsorted_panel_keys(self):
        plan = self._plan()
        s = plan.schedule
        if s.n_panels < 2:
            pytest.skip("need two panels")
        pg = s.panel_group.copy()
        pb = s.panel_bcol.copy()
        pg[[0, -1]] = pg[[-1, 0]]
        pb[[0, -1]] = pb[[-1, 0]]
        got = self._run(
            plan, dataclasses.replace(s, panel_group=pg, panel_bcol=pb)
        )
        assert "schedule.panel-order" in got


class TestAssemblyFaultInjection:
    def _fixture(self):
        plan = _element_plan()
        return plan, plan.schedule, plan.assembly, (plan._bm, plan._bn)

    def _run(self, schedule, assembly, block_shape):
        findings = []
        check_assembly(schedule, assembly, block_shape, findings)
        return _checks(findings)

    @given(pos=st.integers(min_value=0, max_value=10 ** 9))
    @settings(max_examples=12, deadline=None)
    def test_duplicated_gather_index(self, pos):
        _, s, asm, bs = self._fixture()
        gather = np.asarray(asm.gather).copy()
        i = pos % (len(gather) - 1)
        gather[i] = gather[i + 1]
        mut = dataclasses.replace(asm, gather=gather)
        assert "assembly.gather-duplicate" in self._run(s, mut, bs)

    @given(pos=st.integers(min_value=0, max_value=10 ** 9))
    @settings(max_examples=12, deadline=None)
    def test_pad_panel_read(self, pos):
        """A gather index pointing into the dummy pad panel's flat range
        (>= n_panels*group*bm*bn) must be flagged as a pad read."""
        _, s, asm, bs = self._fixture()
        bm, bn = bs
        flat = s.n_panels * s.group * bm * bn
        gather = np.asarray(asm.gather).copy()
        gather[pos % len(gather)] = flat + pos % (s.group * bm * bn)
        mut = dataclasses.replace(asm, gather=gather)
        assert "assembly.pad-panel-read" in self._run(s, mut, bs)

    def test_indptr_corruption(self):
        _, s, asm, bs = self._fixture()
        indptr = np.asarray(asm.indptr).copy()
        indptr[len(indptr) // 2] += 1
        mut = dataclasses.replace(asm, indptr=indptr)
        got = self._run(s, mut, bs)
        assert got & {"assembly.indptr-monotone", "assembly.indptr-total",
                      "assembly.column-order"}

    def test_unsorted_columns(self):
        plan, s, asm, bs = self._fixture()
        indptr = np.asarray(asm.indptr)
        widths = np.diff(indptr)
        rows = np.nonzero(widths >= 2)[0]
        if not len(rows):
            pytest.skip("no row with 2+ nnz")
        lo = int(indptr[rows[0]])
        indices = np.asarray(asm.indices).copy()
        indices[[lo, lo + 1]] = indices[[lo + 1, lo]]
        mut = dataclasses.replace(asm, indices=indices)
        assert "assembly.column-order" in self._run(s, mut, bs)

    def test_batch_race_from_panel_aliasing(self):
        """A panel id beyond the dummy slot collides with the next batch
        element's slot range — the exact write-write race 'parallel'
        semantics would miscompile. check_batch_races must prove it."""
        plan = _element_plan()
        s = plan.schedule
        panel = s.panel.copy()
        panel[0] = s.n_panels + 1  # lands in element b+1's slot 0
        findings = []
        check_batch_races(
            dataclasses.replace(s, panel=panel), findings, bsz=2
        )
        assert _checks(findings) & {"races.batch.padded-panel-bounds",
                                    "races.batch.cross-element"}

    def test_verify_plan_catches_in_place_corruption(self):
        plan = _element_plan()
        gather = np.asarray(plan.assembly.gather).copy()
        gather[0] = gather[1]
        plan.assembly = dataclasses.replace(plan.assembly, gather=gather)
        rep = verify_plan(plan)
        assert not rep.ok
        with pytest.raises(PlanVerificationError):
            rep.raise_if_failed()


class TestShardFaultInjection:
    def _sharded_plan(self):
        from repro.launch.mesh import make_shard_mesh

        a, b = _mats(11, m=160)
        return spgemm_plan(a, b, tile=16, group=2, backend="jnp",
                           cache=PlanCache(), mesh=make_shard_mesh(1))

    def test_overlapping_shard_bounds(self):
        plan = self._sharded_plan()
        shards = plan._shards
        if not shards:
            pytest.skip("empty sharded plan")
        sh = shards[0]
        # Stretch shard 0 one group past its end: with >1 shards the
        # ranges now overlap; with 1 shard the span exceeds n_groups.
        bad = dataclasses.replace(sh, group_hi=sh.group_hi + 1)
        object.__setattr__(plan, "_shards", [bad] + list(shards[1:]))
        findings = []
        check_shard_partition(plan, findings)
        got = _checks(findings)
        assert got & {"shards.contiguity", "shards.coverage",
                      "shards.bounds", "shards.rebase",
                      "shards.triple-span", "shards.panel-span"}


class TestCorruptedArtifactNeverExecutes:
    """validate="deep" + a digest-valid-but-corrupt disk artifact: the
    loader's verification must fail, count a load_failure, and fall back
    to a clean symbolic rebuild."""

    def _corrupt_artifact(self, store_dir):
        """Duplicate one assembly gather index inside the (single) stored
        artifact and re-sign the payload digest, so every integrity
        check in PlanStore.load still passes."""
        from repro.spgemm.persist import _META_KEY, _payload_digest

        [path] = glob.glob(os.path.join(store_dir, "*.plan.npz"))
        with np.load(path, allow_pickle=False) as npz:
            arrays = {n: npz[n].copy() for n in npz.files if n != _META_KEY}
            header = json.loads(bytes(np.asarray(npz[_META_KEY])).decode())
        gather = arrays["asm.gather"]
        assert len(gather) >= 2
        gather[0] = gather[1]
        header["digest"] = _payload_digest(arrays, header["meta"])
        payload = dict(arrays)
        payload[_META_KEY] = np.frombuffer(
            json.dumps(header).encode(), np.uint8
        )
        with open(path, "wb") as f:
            np.savez(f, **payload)

    def test_deep_validate_rejects_and_rebuilds(self, tmp_path):
        a, b = _mats(13)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=PlanCache(disk_dir=str(tmp_path)))
        self._corrupt_artifact(str(tmp_path))
        cache = PlanCache(disk_dir=str(tmp_path))
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache, validate="deep")
        stats = cache.stats()
        assert stats["load_failures"] == 1, \
            "corrupted artifact should fail loader-side verification"
        assert plan.report.schedule_builds == 1, \
            "must fall back to a fresh symbolic build"
        assert verify_plan(plan).ok

    def test_without_deep_validate_corruption_loads(self, tmp_path):
        """Control: the store's digest alone cannot catch a re-signed
        corruption — that is exactly the gap validate='deep' closes."""
        a, b = _mats(13)
        spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                    cache=PlanCache(disk_dir=str(tmp_path)))
        self._corrupt_artifact(str(tmp_path))
        cache = PlanCache(disk_dir=str(tmp_path))
        plan = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache)
        assert cache.stats()["load_failures"] == 0
        assert plan.report.load_hits >= 1
        rep = verify_plan(plan)
        assert not rep.ok and "assembly.gather-duplicate" in _checks(
            rep.findings
        )


class TestStoreAudit:
    def test_orphaned_alias_reported_and_pruned(self, tmp_path):
        from repro.spgemm.persist import PlanStore

        store = PlanStore(str(tmp_path))
        k_live, k_dead = ("live", 1), ("dead", 2)
        arrays = {"x": np.arange(4, dtype=np.int32)}
        store.save(k_live, arrays, {"kind": "t"})
        store.save(k_dead, arrays, {"kind": "t"})
        store.alias_put("tok-live", repr(k_live))
        store.alias_put("tok-dead", repr(k_dead))
        os.unlink(store.path_for(k_dead))

        assert store.alias_get("tok-live") == repr(k_live)
        assert store.alias_get("tok-dead") is None, \
            "an alias whose target file is gone must be a miss"
        report = store.audit()
        assert report["orphaned"] == ["tok-dead"] and report["pruned"]
        assert report["files"] == 1
        clean = store.audit()
        assert clean["orphaned"] == [] and clean["aliases"] == 1

    def test_audit_clean_store(self, tmp_path):
        from repro.spgemm.persist import PlanStore

        store = PlanStore(str(tmp_path))
        report = store.audit()
        assert report == {"files": 0, "bytes": 0, "aliases": 0,
                          "orphaned": [], "pruned": False}
