"""Shared fixtures. NOTE: no in-process XLA_FLAGS here — tests must see
the real single CPU device (the 512-device override is dryrun.py-only).
Multi-device coverage instead goes through :func:`forced_devices`, which
runs test code in a fresh subprocess so the forced host-device count can
be set before jax is imported without leaking into this process."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)


def run_forced_devices(code: str, devices: int = 8) -> str:
    """Run a python snippet under ``--xla_force_host_platform_device_count``.

    Subprocess-safe by construction: XLA reads the flag at backend init,
    so it must be in the environment before the *first* jax import —
    impossible to do reliably in-process once any test has touched jax.
    The child gets its own interpreter, the parent's device topology is
    untouched, and a nonzero exit fails the calling test with the child's
    stderr. Shared by the ``forced_devices`` fixture and
    tests/test_distributed.py.
    """
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={devices}"
    ).strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600, cwd=ROOT,
    )
    assert out.returncode == 0, (
        f"forced-device subprocess failed:\n{out.stderr[-4000:]}"
    )
    return out.stdout


@pytest.fixture(scope="session")
def forced_devices():
    """Fixture handle on :func:`run_forced_devices` (``run(code,
    devices=8) -> stdout``)."""
    return run_forced_devices
