"""End-to-end system behaviour: the paper's full pipeline (load -> convert
-> SpGEMM -> store) and the analytical models (Sec. 4.2.4, 5.3)."""
import os

import numpy as np
import pytest

from repro.core.gustavson import FSpGEMMSimulator, gustavson_flops, spgemm_gustavson
from repro.core.perfmodel import (
    CPU_XEON_E5_2637,
    FPGA_ARRIA10,
    GPU_TITAN_X,
    PAPER_TABLE7_MS,
    PAPER_TABLE8_STUF,
    energy,
    runtime_from_stuf,
    stuf,
)
from repro.core.tuning import ARRIA10_GX, derive_fpga_params, fpga_runtime_model, tpu_tile_params
from repro.kernels import ops
from repro.sparse.convert import to_bcsr, to_bcsv, to_csr, to_csv
from repro.sparse.io import load_csv, read_matrix_market, save_csv, write_matrix_market
from repro.sparse.random import random_coo, suite_matrix


class TestPaperPipeline:
    def test_end_to_end_mtx_to_csv_to_result(self, tmp_path):
        """The host program's full path: raw matrix file -> CSV (stored
        once) -> FPGA-kernel simulation -> result."""
        a = suite_matrix("poisson3Da", scale=0.005, seed=1)
        mtx = str(tmp_path / "a.mtx")
        write_matrix_market(mtx, a)
        loaded = to_csr(read_matrix_market(mtx))
        csvf = str(tmp_path / "a_csv")
        save_csv(csvf, to_csv(loaded, 8))
        csv = load_csv(csvf)
        csv.validate()
        c, stats = FSpGEMMSimulator(8, 16).run(csv, loaded)
        ref = spgemm_gustavson(loaded, loaded)
        np.testing.assert_allclose(c.todense(), ref.todense(),
                                   rtol=2e-4, atol=2e-4)

    def test_block_pipeline_matches_element_pipeline(self):
        """TPU (block) path result == paper-faithful (element) path."""
        a = suite_matrix("scircuit", scale=0.004, seed=2)
        b = a
        ref = spgemm_gustavson(a, b).todense()
        pad = 64
        m, k = a.shape
        mp = -(-m // pad) * pad
        kp = -(-k // pad) * pad
        ad = np.zeros((mp, kp), np.float32)
        ad[:m, :k] = a.todense()
        bd = np.zeros((kp, mp), np.float32)
        bd[:k, :m] = b.todense()
        c = ops.spgemm(to_bcsv(ad, (64, 64), 2), to_bcsr(bd, (64, 64)),
                       backend="jnp")
        np.testing.assert_allclose(c.todense()[:m, :m], ref, rtol=2e-3,
                                   atol=2e-3)


class TestAnalyticalModels:
    def test_fpga_params_reproduce_paper(self):
        """Sec. 4.2.4's published optimum: SW=16, NUM_PE=32 on Arria 10."""
        assert derive_fpga_params(ARRIA10_GX) == (16, 32)

    def test_runtime_model_consistency(self):
        """R = N_ops/(F*2*SW*NUM_PE*U) and U = N_ops/(F*P*R) invert."""
        n_ops = 1.0e9
        r = fpga_runtime_model(n_ops, ARRIA10_GX, stuf=0.5)
        u = stuf(n_ops, FPGA_ARRIA10, r)
        # P differs: the model uses busy DSPs (512*2); STUF normalizes by
        # all 1518 DSPs -> u = 0.5 * (512/1518)
        assert u == pytest.approx(0.5 * 512.0 / 1518.0, rel=1e-6)

    def test_stuf_tables_consistent(self):
        for name, stufs in PAPER_TABLE8_STUF.items():
            a = suite_matrix(name, scale=0.002, seed=0)
            n_ops = gustavson_flops(a, a)
            r = runtime_from_stuf(n_ops, FPGA_ARRIA10, stufs["fspgemm"])
            assert r > 0

    def test_energy_model(self):
        assert energy(2.0, FPGA_ARRIA10) == pytest.approx(37.0)
        assert energy(1.0, CPU_XEON_E5_2637) == pytest.approx(128.0)

    def test_tpu_tile_params_constraints(self):
        bm, bk, bn, g = tpu_tile_params()
        assert bm % 128 == 0 and bk % 128 == 0 and bn % 128 == 0
        from repro.core.tuning import TPU_V5E
        acc = g * bm * bn * 4
        assert acc + 2 * bk * bn * 4 + 2 * bm * bk * 4 <= TPU_V5E.vmem_bytes * 0.7
        assert g >= 1
