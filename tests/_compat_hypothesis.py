"""Hypothesis shim: re-export the real library when installed, otherwise a
tiny deterministic fallback so the suite always *collects and still runs*
the property tests on a fixed sample of each strategy's domain.

Install the real thing (``pip install -r requirements-dev.txt``) for full
randomized coverage; the fallback only implements what these tests use
(``st.integers``, ``@given`` with keyword strategies, ``@settings``).
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import itertools

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        @property
        def samples(self):
            lo, hi = self.lo, self.hi
            span = hi - lo
            pts = {lo, hi, lo + span // 2, lo + 1, hi - 1,
                   lo + span // 3, lo + 2 * span // 3, lo + span // 7}
            return sorted(p for p in pts if lo <= p <= hi)

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=0):
            return _IntStrategy(min_value, max_value)

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        keys = list(strategies)
        pools = [strategies[k].samples for k in keys]

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Diagonal pass covers each pool's full sample set, then a
                # few cross combinations; ~15 deterministic cases total.
                n = max(len(p) for p in pools)
                for i in range(n):
                    combo = {k: pools[j][i % len(pools[j])]
                             for j, k in enumerate(keys)}
                    fn(*args, **combo, **kwargs)
                for vals in itertools.islice(itertools.product(*pools), 8):
                    fn(*args, **dict(zip(keys, vals)), **kwargs)

            # Hide strategy-filled params from pytest's fixture resolution.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in keys
            ])
            return wrapper

        return deco
