"""Gustavson algorithm + FPGA-kernel simulator tests (paper Sec. 2.2, 4.2,
Algorithm 1)."""
import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.core.gustavson import (
    FSpGEMMSimulator,
    gustavson_flops,
    spgemm_gustavson,
    spgemm_inner,
    spgemm_outer,
)
from repro.sparse.convert import to_csc, to_csr, to_csv
from repro.sparse.random import random_coo, suite_matrix


def _pair(seed, m=40, k=32, n=36, da=0.15, db=0.2):
    a = to_csr(random_coo(m, k, da, "uniform", seed=seed))
    b = to_csr(random_coo(k, n, db, "uniform", seed=seed + 1))
    return a, b


def _dense_ref(a, b):
    return a.todense().astype(np.float64) @ b.todense().astype(np.float64)


class TestAlgorithms:
    @pytest.mark.parametrize("seed", range(5))
    def test_gustavson_matches_dense(self, seed):
        a, b = _pair(seed)
        c = spgemm_gustavson(a, b)
        np.testing.assert_allclose(c.todense(), _dense_ref(a, b), rtol=2e-5,
                                   atol=2e-5)

    def test_inner_outer_match_gustavson(self):
        a, b = _pair(7, m=20, k=16, n=18)
        ref = spgemm_gustavson(a, b).todense()
        c_in, st_in = spgemm_inner(a, to_csc(b))
        c_out, st_out = spgemm_outer(to_csc(a), b)
        np.testing.assert_allclose(c_in.todense(), ref, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(c_out.todense(), ref, rtol=2e-5, atol=2e-5)
        # The paper's overhead claims: inner product wastes index-matching
        # work and computes zero outputs; outer product buffers large
        # partial sums.
        assert st_in.index_match_ops > 0
        assert st_in.zero_outputs > 0
        assert st_out.partial_nnz >= c_out.nnz

    def test_gustavson_flops_counts_expanded_products(self):
        a, b = _pair(3)
        f = gustavson_flops(a, b)
        assert f == 2 * int(b.row_nnz()[a.indices].sum())

    def test_empty_inputs(self):
        a = to_csr(np.zeros((5, 4), np.float32))
        b = to_csr(np.zeros((4, 6), np.float32))
        c = spgemm_gustavson(a, b)
        assert c.nnz == 0 and c.shape == (5, 6)


class TestSimulator:
    @pytest.mark.parametrize("num_pe,sw", [(1, 1), (2, 4), (8, 16), (32, 16)])
    def test_simulator_matches_oracle(self, num_pe, sw):
        a, b = _pair(11)
        csv = to_csv(a, num_pe)
        sim = FSpGEMMSimulator(num_pe, sw)
        c, stats = sim.run(csv, b)
        np.testing.assert_allclose(c.todense(), _dense_ref(a, b), rtol=2e-5,
                                   atol=2e-5)
        # One B-row fetch per CSV vector (the Sec. 4.1 buffering claim).
        assert stats.b_row_fetches == csv.num_vectors()
        assert stats.flops == gustavson_flops(a, b)
        assert stats.cycles > 0

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 500), num_pe=st.integers(1, 8),
           sw=st.integers(1, 16))
    def test_simulator_property(self, seed, num_pe, sw):
        a = to_csr(random_coo(17, 13, 0.2, "uniform", seed=seed))
        b = to_csr(random_coo(13, 11, 0.25, "uniform", seed=seed + 1))
        csv = to_csv(a, num_pe)
        c, stats = FSpGEMMSimulator(num_pe, sw).run(csv, b)
        np.testing.assert_allclose(
            c.todense(), _dense_ref(a, b), rtol=2e-4, atol=2e-4)
        # Fetches never exceed the naive one-per-nonzero scheme.
        assert stats.b_row_fetches <= max(a.nnz, 1)

    def test_more_pes_never_fetch_more(self):
        """Monotonicity behind Fig. 6: OMAR improves with NUM_PE."""
        a = suite_matrix("poisson3Da", scale=0.01)
        b = a
        fetches = []
        for num_pe in (1, 2, 4, 8, 16):
            csv = to_csv(a, num_pe)
            fetches.append(csv.num_vectors())
        assert all(f1 >= f2 for f1, f2 in zip(fetches, fetches[1:]))
