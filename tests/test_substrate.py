"""Substrate tests: data pipeline, optimizer, checkpointing (atomic +
verify + elastic), trainer fault tolerance, straggler detection,
heartbeats, gradient compression."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_reduced
from repro.data.pipeline import SyntheticLM, batch_specs
from repro.models import transformer as tr
from repro.optim import AdamW, clip_by_global_norm, constant, warmup_cosine
from repro.optim.compress import ef_compress, ef_decompress, ef_init
from repro.runtime.heartbeat import Heartbeat, check_peers
from repro.runtime.steps import make_train_step
from repro.runtime.straggler import StragglerDetector
from repro.runtime.trainer import Trainer, TrainerConfig


class TestData:
    def test_deterministic_and_step_indexed(self):
        cfg = get_reduced("granite-3-2b")
        d = SyntheticLM(cfg, 4, 32, seed=7)
        b1, b2 = d.batch_at(3), d.batch_at(3)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d.batch_at(4)["tokens"], b1["tokens"])

    def test_labels_are_next_tokens(self):
        cfg = get_reduced("granite-3-2b")
        b = SyntheticLM(cfg, 2, 16).batch_at(0)
        # structured streams: labels shifted by one
        assert b["tokens"].shape == b["labels"].shape

    def test_specs_match_batches(self):
        for arch in ("granite-3-2b", "hubert-xlarge", "paligemma-3b"):
            cfg = get_reduced(arch)
            b = SyntheticLM(cfg, 2, 32).batch_at(0)
            specs = batch_specs(cfg, 2, 32)
            assert set(b) == set(specs)
            for k in b:
                assert tuple(b[k].shape) == tuple(specs[k].shape), k

    def test_prefetch_iterator(self):
        cfg = get_reduced("granite-3-2b")
        it = SyntheticLM(cfg, 2, 16).iter(start_step=5)
        first = next(it)
        assert np.array_equal(first["tokens"],
                              SyntheticLM(cfg, 2, 16).batch_at(5)["tokens"])


class TestOptim:
    def test_adamw_converges_quadratic(self):
        opt = AdamW(lr=0.1)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_master_weights_bf16_params(self):
        opt = AdamW(lr=0.05, master=True)
        params = {"w": jnp.asarray([1.0], jnp.bfloat16)}
        state = opt.init(params)
        assert state["master"]["w"].dtype == jnp.float32
        for _ in range(10):
            params, state = opt.update({"w": jnp.asarray([0.001], jnp.bfloat16)},
                                       state, params)
        # master accumulates sub-bf16 updates that params alone would lose
        assert params["w"].dtype == jnp.bfloat16

    def test_clip(self):
        tree = {"a": jnp.ones(4) * 10.0}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(20.0)
        _, norm2 = clip_by_global_norm(clipped, 1.0)
        assert float(norm2) == pytest.approx(1.0, rel=1e-3)

    def test_schedules(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(0))) == 0.0
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
        assert float(constant(0.3)(jnp.asarray(5))) == pytest.approx(0.3)

    def test_ef_compression_preserves_signal(self):
        """Error feedback: the accumulated dequantized stream converges to
        the true gradient sum."""
        rng = np.random.default_rng(0)
        g_true = {"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
        res = ef_init(g_true)
        acc = jnp.zeros(256)
        for _ in range(50):
            q, s, res = ef_compress(g_true, res)
            acc = acc + ef_decompress(q, s)["w"]
        np.testing.assert_allclose(np.asarray(acc) / 50,
                                   np.asarray(g_true["w"]), atol=2e-3)


class TestCheckpoint:
    def test_atomic_save_restore_verify(self, tmp_path):
        d = str(tmp_path / "ckpt")
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        mgr.save(1, tree)
        mgr.save(2, tree, blocking=False)
        mgr.wait()
        assert mgr.all_steps() == [1, 2]
        out = mgr.restore(2, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.arange(100, dtype=jnp.float32)}
        mgr.save(5, tree)
        # flip bytes in the chunk
        chunk = os.path.join(str(tmp_path), "step_000000005", "chunk_00000.npy")
        with open(chunk, "r+b") as f:
            f.seek(-8, 2)
            f.write(b"corrupt!")
        with pytest.raises(IOError):
            mgr.restore(5, tree)

    def test_interrupted_save_is_invisible(self, tmp_path):
        """A .tmp directory (crash mid-save) must not be listed."""
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, {"x": jnp.zeros(2)})
        os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp"))
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1

    def test_elastic_reshard_on_load(self, tmp_path):
        """Checkpoints restore onto a different sharding layout."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_auto_mesh
        mgr = CheckpointManager(str(tmp_path), keep=1)
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        mgr.save(1, tree)
        mesh = make_auto_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out = mgr.restore(1, tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


class TestTrainerFT:
    def _mk(self, tmp, steps, total=30):
        cfg = get_reduced("granite-3-2b")
        params = tr.init_lm(jax.random.PRNGKey(0), cfg)
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt))
        data = SyntheticLM(cfg, 4, 32)

        def batches():
            s = 0
            while True:
                yield {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
                s += 1

        tc = TrainerConfig(total_steps=total, ckpt_dir=tmp, ckpt_every=10,
                           log_every=10, install_signal_handlers=False,
                           heartbeat=False)
        return Trainer(tc, step_fn, batches(), params, opt_state)

    def test_run_checkpoints_and_resumes(self, tmp_path):
        d = str(tmp_path)
        t1 = self._mk(d, 0, total=20)
        res = t1.run()
        assert res["final_step"] == 20
        # a fresh trainer resumes at 20 and continues to 25
        t2 = self._mk(d, 0, total=25)
        res2 = t2.run()
        assert res2["final_step"] == 25
        assert t2.ckpt.latest_step() == 25

    def test_preemption_checkpoint(self, tmp_path):
        t = self._mk(str(tmp_path), 0, total=1000)
        t._preempted = True  # simulate SIGTERM raced before the loop
        res = t.run()
        assert res["preempted"]
        assert t.ckpt.latest_step() is not None


class TestStragglerAndHeartbeat:
    def test_straggler_fires_on_sustained_slowdown(self):
        det = StragglerDetector(patience=2, warmup=3)
        for i in range(20):
            det.observe(i, 0.10 + 0.001 * (i % 3))
        assert not det.events
        fired = False
        for i in range(20, 26):
            fired |= det.observe(i, 0.50)  # 5x slowdown
        assert fired and det.events

    def test_straggler_ignores_single_spike(self):
        det = StragglerDetector(patience=3, warmup=3)
        for i in range(15):
            det.observe(i, 0.1)
        assert not det.observe(15, 0.9)  # single spike, patience not met
        for i in range(16, 30):
            det.observe(i, 0.1)
        assert not det.events

    def test_heartbeat_files(self, tmp_path):
        hb = Heartbeat(str(tmp_path), host="h0", interval=0.05)
        hb.start()
        import time
        time.sleep(0.2)
        hb.stop()
        peers = check_peers(str(tmp_path), timeout=5.0)
        assert peers["alive"] == ["h0"]
        assert check_peers(str(tmp_path), timeout=0.0)["dead"] == ["h0"]

    def test_heartbeat_restarts_after_stop(self, tmp_path):
        """start() after stop() must beat again: the stop event is reset,
        not silently reused (the old bug left the thread exiting on its
        first wait and the file going stale forever)."""
        import json
        import time

        hb = Heartbeat(str(tmp_path), host="h0", interval=0.02)
        hb.start()
        hb.stop()
        with open(hb.path) as f:
            t_stopped = json.load(f)["time"]
        time.sleep(0.05)
        hb.start()  # second lifecycle
        try:
            deadline = time.time() + 2.0
            while time.time() < deadline:
                with open(hb.path) as f:
                    if json.load(f)["time"] > t_stopped:
                        break
                time.sleep(0.02)
            with open(hb.path) as f:
                assert json.load(f)["time"] > t_stopped, (
                    "restarted heartbeat never beat again"
                )
        finally:
            hb.stop()

    def test_heartbeat_start_while_running_raises(self, tmp_path):
        hb = Heartbeat(str(tmp_path), host="h0", interval=5.0)
        hb.start()
        try:
            with pytest.raises(RuntimeError):
                hb.start()
        finally:
            hb.stop()

    def test_heartbeat_carries_metrics(self, tmp_path):
        import json

        from repro.runtime.heartbeat import MetricsRegistry

        reg = MetricsRegistry()
        reg.counter("served").inc(3)
        reg.gauge("depth").set(2.5)
        reg.summary("lat").record(0.1)
        hb = Heartbeat(str(tmp_path), host="h0", interval=5.0, metrics=reg)
        hb.beat()
        with open(hb.path) as f:
            rec = json.load(f)
        assert rec["metrics"]["served"] == 3
        assert rec["metrics"]["depth"] == 2.5
        assert rec["metrics"]["lat"]["count"] == 1


class TestMetricsRegistry:
    def test_instruments(self):
        from repro.runtime.heartbeat import MetricsRegistry

        reg = MetricsRegistry()
        c = reg.counter("c")
        c.inc()
        c.inc(4)
        assert reg.counter("c") is c and c.value == 5
        g = reg.gauge("g")
        g.set(7)
        assert g.value == 7.0
        s = reg.summary("s", window=8)
        for v in range(100):
            s.record(float(v))
        snap = s.snapshot()
        assert snap["count"] == 100  # lifetime count survives the window
        assert snap["p50"] >= 92.0  # quantiles over the last 8 only
        assert s.percentile(100.0) == 99.0

    def test_type_conflict_raises(self):
        from repro.runtime.heartbeat import MetricsRegistry

        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_snapshot_shapes(self):
        from repro.runtime.heartbeat import MetricsRegistry

        reg = MetricsRegistry()
        reg.counter("a").inc()
        reg.summary("b").record(1.0)
        snap = reg.snapshot()
        assert snap["a"] == 1
        assert snap["b"]["p99"] == 1.0
