"""Host symbolic-phase (static schedule) invariants."""
import numpy as np
import pytest
from _compat_hypothesis import given, settings, st

from repro.core.schedule import build_spgemm_schedule
from repro.sparse.convert import to_bcsr, to_bcsv
from repro.sparse.random import random_block_sparse


def _inputs(seed, group=2, da=0.3, db=0.35):
    ad = random_block_sparse(128, 96, (16, 16), da, seed=seed)
    bd = random_block_sparse(96, 128, (16, 32), db, seed=seed + 1)
    return (to_bcsv(ad, (16, 16), group=group), to_bcsr(bd, (16, 32)),
            ad, bd)


class TestScheduleInvariants:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000), group=st.integers(1, 4))
    def test_panel_runs_are_contiguous(self, seed, group):
        """Pallas output revisiting is only safe when each panel is
        visited in one contiguous run."""
        a, b, _, _ = _inputs(seed, group)
        s = build_spgemm_schedule(a, b)
        seen = set()
        prev = None
        for pnl in s.panel:
            if pnl != prev:
                assert pnl not in seen, "panel revisited non-contiguously"
                seen.add(pnl)
                prev = pnl

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000), group=st.integers(1, 4))
    def test_start_marks_first_triple_of_each_panel(self, seed, group):
        a, b, _, _ = _inputs(seed, group)
        s = build_spgemm_schedule(a, b)
        first_seen = set()
        for t in range(s.num_triples):
            if s.start[t]:
                assert s.panel[t] not in first_seen
                first_seen.add(s.panel[t])
            else:
                assert s.panel[t] in first_seen
        assert len(first_seen) == s.n_panels

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_c_structure_is_symbolic_gustavson(self, seed):
        """C's block support == support of |A| @ |B| at block granularity."""
        a, b, ad, bd = _inputs(seed)
        s = build_spgemm_schedule(a, b)
        bm, bk = a.block_shape
        bn = b.block_shape[1]
        amask = np.abs(ad).reshape(ad.shape[0] // bm, bm, -1, bk).sum((1, 3)) > 0
        bmask = np.abs(bd).reshape(bd.shape[0] // bk, bk, -1, bn).sum((1, 3)) > 0
        cmask = (amask.astype(int) @ bmask.astype(int)) > 0
        got = np.zeros_like(cmask)
        got[s.c_brow, s.c_bcol] = True
        assert np.array_equal(got, cmask)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000), group=st.integers(1, 4))
    def test_a_slots_cover_every_useful_a_block(self, seed, group):
        a, b, _, _ = _inputs(seed, group)
        s = build_spgemm_schedule(a, b)
        # Every triple references valid slots.
        assert (s.a_slot >= 0).all() and (s.a_slot < a.nnzb).all()
        assert (s.b_slot >= 0).all() and (s.b_slot < b.nnzb).all()
        assert (s.sub_row >= 0).all() and (s.sub_row < group).all()

    def test_b_fetch_count_reflects_sharing(self):
        """Within one (group, j) panel, triples with the same k share one
        fetched B block — consecutive b_slot runs."""
        a, b, _, _ = _inputs(3, group=4)
        s = build_spgemm_schedule(a, b)
        assert s.b_fetches() <= s.num_triples
        assert s.block_omar() >= 0.0
