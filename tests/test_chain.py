"""Compacted nnz-exact output (``output="compact"``) and structural plan
composition (``plan_from_structural_pattern`` / ``SpGEMMChain`` /
``execute_chain``).

Coverage layers:

* compact-vs-block agreement is **bitwise** (dense expansion) on every
  dispatch path — element, block-kind, batched, sharded at 1–8 forced
  devices, pipelined — with the compact result holding exactly the
  structural-product nnz (no block-padding zeros);
* edge cases: empty output rows, a single-nnz product inside a padded
  block, the all-empty product;
* compact plans persist and rehydrate through the disk tier with the
  compact map intact, under cache keys distinct from block plans;
* ``verify_plan`` catches hand-corrupted compact gather maps
  (fault-injection via ``dataclasses.replace``);
* chains are bitwise-equal to independent per-stage executes with a host
  round trip between them, while keeping intermediates device-resident.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.verify import verify_plan
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo
from repro.sparse.formats import COO
from repro.sparse.random import random_coo
from repro.spgemm.cache import PlanCache
from repro.spgemm.plan import (
    SpGEMMChain,
    SpGEMMPlan,
    StructuralPattern,
    chain_plans,
    execute_chain,
    plan_from_structural_pattern,
    spgemm_plan,
)


def _int_coo(m, n, density, seed):
    """Small-integer float32 values — exact in f32, so compact-vs-block
    and chain-vs-round-trip comparisons can demand bitwise equality."""
    coo = random_coo(m, n, density, "uniform", seed=seed)
    rng = np.random.default_rng(seed + 999)
    vals = rng.integers(-4, 5, coo.nnz).astype(np.float32)
    coo.val = np.where(vals == 0, np.float32(1.0), vals)
    return coo.sum_duplicates()


def _mats(seed=0, m=96, n=80, k=72, density=0.06):
    a = _int_coo(m, n, density, seed)
    b = _int_coo(n, k, density, seed + 50)
    return a, b


def _pair(seed=0, **kw):
    a, b = _mats(seed, **kw)
    cache = PlanCache()
    blk = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
    cmp_ = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                       output="compact")
    return a, b, blk, cmp_


def _structural_nnz(a: COO, b: COO) -> int:
    da = np.zeros(a.shape, bool)
    da[a.row, a.col] = True
    db = np.zeros(b.shape, bool)
    db[b.row, b.col] = True
    return int(np.count_nonzero(da.astype(np.int64) @ db.astype(np.int64)))


class TestCompactOutput:
    def test_element_bitwise_vs_block(self):
        a, b, blk, cmp_ = _pair(1)
        rb, rc = blk.execute(), cmp_.execute()
        assert np.array_equal(rb.todense(), rc.todense())  # bitwise
        assert rc.data.size == _structural_nnz(a, b)
        assert rc.data.size < rb.data.size  # padding zeros dropped

    def test_compact_is_subset_with_own_csr(self):
        _, _, blk, cmp_ = _pair(2)
        asm, comp = blk.assembly, cmp_.compact
        assert comp.nnz <= asm.nnz
        assert np.isin(np.asarray(comp.gather),
                       np.asarray(asm.gather)).all()
        # Block plan keeps its block-structural CSR untouched.
        assert blk.compact is None and blk.output == "block"
        assert cmp_.assembly.nnz == asm.nnz

    def test_block_kind_plan_degenerates_to_block_map(self):
        """Block-input plans have no element pattern: stored blocks are
        dense by contract, so compact degenerates to the block map and
        results stay identical."""
        a, b = _mats(3)
        a_bcsv, _ = bcsv_from_coo(a, (8, 8), 2)
        b_bcsr, _ = bcsr_from_coo(b, (8, 8))
        cache = PlanCache()
        blk = spgemm_plan(a_bcsv, b_bcsr, backend="jnp", cache=cache)
        cmp_ = spgemm_plan(a_bcsv, b_bcsr, backend="jnp", cache=cache,
                           output="compact")
        assert cmp_.compact is cmp_.assembly
        assert np.array_equal(blk.execute().todense(),
                              cmp_.execute().todense())

    def test_batched_bitwise(self):
        a, b, blk, cmp_ = _pair(4)
        rng = np.random.default_rng(0)
        av = rng.integers(-3, 4, (3, a.nnz)).astype(np.float32)
        bv = rng.integers(-3, 4, (3, b.nnz)).astype(np.float32)
        outs_b = blk.execute_batch(av, bv)
        outs_c = cmp_.execute_batch(av, bv)
        for ob, oc in zip(outs_b, outs_c):
            assert np.array_equal(ob.todense(), oc.todense())
            assert oc.data.size == cmp_.compact.nnz

    def test_pipelined_bitwise(self):
        a, b, blk, cmp_ = _pair(5)
        rng = np.random.default_rng(1)
        sets = [
            (rng.integers(-3, 4, a.nnz).astype(np.float32),
             rng.integers(-3, 4, b.nnz).astype(np.float32))
            for _ in range(4)
        ]
        outs_c = list(cmp_.execute_stream(iter(sets), depth=2))
        for (av, bv), oc in zip(sets, outs_c):
            ob = blk.execute(a_vals=av, b_vals=bv)
            assert np.array_equal(ob.todense(), oc.todense())

    @pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 8])
    def test_sharded_bitwise(self, forced_devices, n_shards):
        forced_devices(f"""
            import numpy as np
            from repro.analysis.verify import verify_plan
            from repro.launch.mesh import make_shard_mesh
            from repro.sparse.random import random_coo
            from repro.spgemm.cache import PlanCache
            from repro.spgemm.plan import spgemm_plan

            a = random_coo(96, 80, 0.06, "uniform", seed=0).sum_duplicates()
            b = random_coo(80, 72, 0.06, "uniform", seed=50).sum_duplicates()
            rng = np.random.default_rng(1)
            a.val = rng.integers(-4, 5, a.nnz).astype(np.float32)
            b.val = rng.integers(-4, 5, b.nnz).astype(np.float32)
            cache = PlanCache()
            mesh = make_shard_mesh({n_shards})
            blk = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                              cache=cache, mesh=mesh)
            cmp_ = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                               cache=cache, mesh=mesh, output="compact")
            rb, rc = blk.execute(), cmp_.execute()
            assert np.array_equal(rb.todense(), rc.todense())
            assert rc.data.size == cmp_.compact.nnz < rb.data.size
            # Single-device reference, same operands.
            ref = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                              cache=PlanCache(), output="compact")
            assert np.array_equal(rc.todense(), ref.execute().todense())
            rep = verify_plan(cmp_)
            assert rep.ok, rep.summary()
            assert "compact" in rep.checks_run
            print("ok", {n_shards})
        """, devices=8)

    def test_empty_rows_and_cols(self):
        """Rows of A with no entries produce empty compact rows (indptr
        plateaus), still bitwise-equal to the block result."""
        a = COO(np.array([2, 2, 17]), np.array([1, 30, 4]),
                np.array([2.0, -1.0, 3.0], np.float32), (24, 40))
        b = _int_coo(40, 32, 0.08, 9)
        cache = PlanCache()
        blk = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        cmp_ = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache, output="compact")
        assert np.array_equal(blk.execute().todense(),
                              cmp_.execute().todense())
        indptr = np.asarray(cmp_.compact.indptr)
        assert indptr.shape == (25,)
        assert indptr[0] == 0 and indptr[2] == 0  # rows 0-1 empty

    def test_single_nnz_in_padded_block(self):
        """One product element inside an 8x8 block: block output stores
        the 64 padded entries, compact stores exactly one."""
        a = COO(np.array([3]), np.array([5]),
                np.array([2.0], np.float32), (16, 16))
        b = COO(np.array([5]), np.array([7]),
                np.array([-3.0], np.float32), (16, 16))
        cache = PlanCache()
        blk = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        cmp_ = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=cache, output="compact")
        rc = cmp_.execute()
        assert rc.data.size == 1
        assert blk.execute().data.size == 64
        dense = rc.todense()
        assert dense[3, 7] == np.float32(-6.0)
        assert np.count_nonzero(dense) == 1

    def test_empty_product(self):
        """Disjoint patterns: the product is structurally empty on both
        output formats."""
        a = COO(np.array([0]), np.array([0]),
                np.array([1.0], np.float32), (16, 16))
        b = COO(np.array([9]), np.array([0]),
                np.array([1.0], np.float32), (16, 16))
        cmp_ = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                           cache=PlanCache(), output="compact")
        out = cmp_.execute()
        assert out.data.size == 0
        assert np.asarray(out.indptr).shape == (17,)

    def test_device_indptr_matches_host(self):
        _, _, blk, cmp_ = _pair(6)
        for plan in (blk, cmp_):
            want = np.asarray(plan._active().indptr)
            got = np.asarray(plan.device_indptr())
            assert np.array_equal(got.astype(np.int64), want.astype(np.int64))

    def test_persist_rehydrate_roundtrip(self, tmp_path):
        a, b = _mats(7)
        c1 = PlanCache(disk_dir=str(tmp_path))
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c1,
                         output="compact")
        r1 = p1.execute()
        # Warm restart: fresh memory tier, same disk.
        c2 = PlanCache(disk_dir=str(tmp_path))
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         output="compact")
        assert c2.stats.loads == 1  # rehydrated, not rebuilt
        assert p2.output == "compact" and p2.compact is not None
        for f in ("gather", "indptr", "indices"):
            assert np.array_equal(np.asarray(getattr(p1.compact, f)),
                                  np.asarray(getattr(p2.compact, f)))
        assert np.array_equal(r1.todense(), p2.execute().todense())
        assert verify_plan(p2).ok

    def test_block_and_compact_keys_are_distinct(self, tmp_path):
        a, b = _mats(8)
        cache = PlanCache(disk_dir=str(tmp_path))
        p_blk = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                            cache=cache)
        p_cmp = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                            cache=cache, output="compact")
        assert p_blk is not p_cmp
        assert cache.stats.misses == 2  # two builds, no cross-serving
        # Requesting the same output again hits.
        again = spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                            cache=cache, output="compact")
        assert again is p_cmp

    def test_autotune_rejects_compact(self):
        a, b = _mats(9)
        with pytest.raises(ValueError, match="autotune"):
            spgemm_plan(a, b, tile=8, group=2, backend="jnp",
                        cache=PlanCache(), output="compact", autotune=True)


class TestCompactVerify:
    def test_clean_plan_passes(self):
        _, _, _, cmp_ = _pair(10)
        rep = verify_plan(cmp_)
        assert rep.ok and "compact" in rep.checks_run

    def test_fault_duplicate_gather(self):
        _, _, _, cmp_ = _pair(11)
        good = cmp_.compact
        g = np.asarray(good.gather).copy()
        g[1] = g[0]  # two C elements read one slot
        cmp_.compact = dataclasses.replace(good, gather=g)
        rep = verify_plan(cmp_)
        assert not rep.ok
        assert any(f.check == "compact.gather-duplicate"
                   for f in rep.errors)

    def test_fault_out_of_subset_gather(self):
        _, _, _, cmp_ = _pair(12)
        good = cmp_.compact
        g = np.asarray(good.gather).copy()
        outside = np.setdiff1d(
            np.arange(int(np.asarray(cmp_.assembly.gather).max()) + 2),
            np.asarray(cmp_.assembly.gather),
        )
        g[0] = outside[0]
        cmp_.compact = dataclasses.replace(good, gather=g)
        rep = verify_plan(cmp_)
        assert not rep.ok
        assert any(f.check == "compact.subset" for f in rep.errors)

    def test_fault_permuted_gather_caught_by_rebuild(self):
        _, _, _, cmp_ = _pair(13)
        good = cmp_.compact
        g = np.flip(np.asarray(good.gather)).copy()
        cmp_.compact = dataclasses.replace(good, gather=g)
        rep = verify_plan(cmp_)
        assert not rep.ok
        assert any(f.check == "compact.rebuild" for f in rep.errors)

    def test_fault_unsorted_columns(self):
        _, _, _, cmp_ = _pair(14)
        good = cmp_.compact
        idx = np.asarray(good.indices).copy()
        r0, r1 = int(good.indptr[0]), None
        # Find a row with >= 2 entries and swap its first two columns.
        counts = np.diff(np.asarray(good.indptr))
        row = int(np.argmax(counts >= 2))
        lo = int(good.indptr[row])
        idx[lo], idx[lo + 1] = idx[lo + 1], idx[lo]
        cmp_.compact = dataclasses.replace(good, indices=idx)
        rep = verify_plan(cmp_)
        assert not rep.ok
        assert any(f.check == "compact.column-order" for f in rep.errors)


class TestChain:
    def _abc(self, seed=20):
        a = _int_coo(64, 56, 0.07, seed)
        b = _int_coo(56, 48, 0.07, seed + 1)
        c = _int_coo(48, 40, 0.07, seed + 2)
        return a, b, c

    def test_then_bitwise_vs_host_round_trip(self):
        a, b, c = self._abc()
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        chain = p1.then(c, cache=cache)
        assert isinstance(chain, SpGEMMChain)
        out = chain.execute()
        # Independent executes with a host round trip in between.
        r1 = p1.execute()
        p2 = chain.plans[1]
        rt = p2.execute(a_vals=np.asarray(r1.data))
        assert np.array_equal(np.asarray(out.data), np.asarray(rt.data))
        assert np.array_equal(out.todense(), rt.todense())

    def test_intermediate_stays_on_device(self):
        import jax

        a, b, c = self._abc(24)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        chain = p1.then(c, cache=cache)
        packed = chain.plans[0]._run_packed(None, None)
        assert isinstance(packed, jax.Array)  # never left the device
        packed2 = chain.plans[1]._run_packed_chained(packed)
        assert isinstance(packed2, jax.Array)

    def test_three_stage_chain(self):
        a, b, c = self._abc(28)
        d = _int_coo(40, 32, 0.07, 31)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        chain = p1.then(c, cache=cache).then(d, cache=cache)
        assert len(chain.plans) == 3
        out = chain.execute()
        ref = (_dense(a) @ _dense(b) @ _dense(c) @ _dense(d))
        np.testing.assert_allclose(out.todense(), ref, rtol=1e-4, atol=1e-4)

    def test_execute_chain_accepts_raw_lists_and_validates(self):
        a, b, c = self._abc(32)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        p2 = plan_from_structural_pattern(
            p1.output_pattern(), c, tile=8, group=2, backend="jnp",
            cache=cache, output="compact",
        )
        out1 = execute_chain([p1, p2])
        out2 = chain_plans([p1, p2]).execute()
        assert np.array_equal(np.asarray(out1.data), np.asarray(out2.data))
        # A plan that was not built from p1's output pattern is rejected.
        stranger = spgemm_plan(
            _int_coo(64, 48, 0.07, 40), c, tile=8, group=2, backend="jnp",
            cache=cache,
        )
        with pytest.raises(ValueError, match="output pattern|A shape"):
            chain_plans([p1, stranger])

    def test_chain_block_output_works_too(self):
        a, b, c = self._abc(36)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache)
        chain = p1.then(c, cache=cache)
        out = chain.execute()
        ref = _dense(a) @ _dense(b) @ _dense(c)
        np.testing.assert_allclose(out.todense(), ref, rtol=1e-4, atol=1e-4)

    def test_chained_plan_cache_hit_and_counter(self):
        a, b, c = self._abc(44)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        pat = p1.output_pattern()
        q1 = plan_from_structural_pattern(pat, c, tile=8, group=2,
                                          backend="jnp", cache=cache,
                                          output="compact")
        q2 = plan_from_structural_pattern(pat, c, tile=8, group=2,
                                          backend="jnp", cache=cache,
                                          output="compact")
        assert q2 is q1  # memory hit under the chain key
        assert cache.stats.chain_lookups == 2
        assert cache.stats()["chain_lookups"] == 2

    def test_chained_plan_persists(self, tmp_path):
        a, b, c = self._abc(48)
        c1 = PlanCache(disk_dir=str(tmp_path))
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c1,
                         output="compact")
        q1 = p1.then(c, cache=c1)
        out1 = q1.execute()
        # Warm restart.
        c2 = PlanCache(disk_dir=str(tmp_path))
        p2 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=c2,
                         output="compact")
        q2 = p2.then(c, cache=c2)
        assert c2.stats.loads == 2  # both stages rehydrated from disk
        out2 = q2.execute()
        assert np.array_equal(np.asarray(out1.data), np.asarray(out2.data))

    def test_empty_intermediate_product(self):
        """A structurally empty intermediate flows zeros through the rest
        of the chain instead of erroring."""
        a = COO(np.array([0]), np.array([0]),
                np.array([1.0], np.float32), (16, 16))
        b = COO(np.array([9]), np.array([0]),
                np.array([1.0], np.float32), (16, 16))
        c = _int_coo(16, 16, 0.2, 52)
        cache = PlanCache()
        p1 = spgemm_plan(a, b, tile=8, group=2, backend="jnp", cache=cache,
                         output="compact")
        chain = p1.then(c, cache=cache)
        out = chain.execute()
        assert out.data.size == 0
        assert np.count_nonzero(out.todense()) == 0

    def test_structural_pattern_round_trip(self):
        _, _, _, cmp_ = _pair(60)
        pat = cmp_.output_pattern()
        assert isinstance(pat, StructuralPattern)
        assert pat.nnz == cmp_.compact.nnz
        coo = pat.to_coo()
        # Canonical by construction: strictly ascending (row, col).
        key = coo.row.astype(np.int64) * pat.shape[1] + coo.col
        assert (np.diff(key) > 0).all()


def _dense(coo: COO) -> np.ndarray:
    out = np.zeros(coo.shape, np.float32)
    np.add.at(out, (coo.row, coo.col), coo.val)
    return out
