"""Lock-order lint: the serving stack's acquisition graph is acyclic.

``instrument_spgemm_locks`` swaps the ``threading`` attribute of the
gateway/pipeline/cache/plan/persist modules for a recording shim, so a
scripted gateway workload built inside the ``with`` block reports every
acquire/release to a :class:`LockOrderMonitor`. The empirical graph must
contain the known cross-layer edges and no cycle; a synthetic inverted
pair must be detected as a cycle.
"""
import threading

import pytest

from repro.analysis.locks import (
    LockOrderError,
    LockOrderMonitor,
    instrument_spgemm_locks,
)


class TestGatewayScenario:
    def test_serving_workload_is_acyclic(self):
        with instrument_spgemm_locks() as mon:
            from repro.data.pipeline import SpGEMMValueStream
            from repro.sparse.random import random_coo
            from repro.spgemm import PlanCache
            from repro.spgemm.gateway import SpGEMMGateway

            a = random_coo(96, 72, 0.06, "uniform", seed=0).sum_duplicates()
            b = random_coo(72, 80, 0.06, "uniform", seed=1).sum_duplicates()
            gw = SpGEMMGateway(cache=PlanCache(), max_pipelines=2, depth=2,
                               max_batch=4)
            try:
                plan = gw.register("lint/p", a, b, tile=8, group=2,
                                   backend="jnp")
                stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern,
                                           seed=7)
                tickets = [gw.submit("lint/p", *stream.values_at(s))
                           for s in range(6)]
                for t in tickets:
                    t.wait(timeout=120)
            finally:
                gw.close()
        sites = mon.sites()
        assert sites, "no instrumented locks were constructed"
        assert any("gateway.py" in s for s in sites)
        # The known cross-layer ordering: gateway -> pipeline -> plan.
        edges = mon.edges()
        flat = {(src, dst) for src, dsts in edges.items() for dst in dsts}
        assert any("pipeline.py" in s and "plan.py" in d for s, d in flat), \
            f"expected the submit path's pipeline->plan edge, got {flat}"
        findings = mon.check()  # must not raise: the graph is acyclic
        assert not [f for f in findings if f.severity == "error"]

    def test_instrumentation_restores_threading(self):
        import repro.spgemm.gateway as gwmod

        before = gwmod.threading
        with instrument_spgemm_locks():
            assert gwmod.threading is not before
        assert gwmod.threading is before
        assert gwmod.threading is threading


class TestCycleDetection:
    def test_inverted_order_is_a_cycle(self):
        """Two threads taking the same pair of lock sites in opposite
        orders — the canonical ABBA deadlock — must be reported."""
        mon = LockOrderMonitor()

        def t1():
            mon._on_acquire("a.py:1")
            mon._on_acquire("b.py:2")
            mon._on_release("b.py:2")
            mon._on_release("a.py:1")

        def t2():
            mon._on_acquire("b.py:2")
            mon._on_acquire("a.py:1")
            mon._on_release("a.py:1")
            mon._on_release("b.py:2")

        for fn in (t1, t2):
            th = threading.Thread(target=fn)
            th.start()
            th.join()
        cycle = mon.find_cycle()
        assert cycle is not None
        assert set(cycle) >= {"a.py:1", "b.py:2"}
        with pytest.raises(LockOrderError, match="lock-order cycle"):
            mon.check()

    def test_three_site_cycle(self):
        mon = LockOrderMonitor()
        chains = [("x:1", "y:2"), ("y:2", "z:3"), ("z:3", "x:1")]

        def take(pair):
            mon._on_acquire(pair[0])
            mon._on_acquire(pair[1])
            mon._on_release(pair[1])
            mon._on_release(pair[0])

        for pair in chains:
            th = threading.Thread(target=take, args=(pair,))
            th.start()
            th.join()
        assert mon.find_cycle() is not None

    def test_same_site_nesting_is_warning_not_error(self):
        mon = LockOrderMonitor()
        mon._on_acquire("p.py:9")
        mon._on_acquire("p.py:9")  # second *instance* of the same site
        mon._on_release("p.py:9")
        mon._on_release("p.py:9")
        findings = mon.check()  # no cycle -> no raise
        assert [f.check for f in findings] == ["locks.self-nesting"]

    def test_acyclic_graph_clean(self):
        mon = LockOrderMonitor()
        mon._on_acquire("a:1")
        mon._on_acquire("b:2")
        mon._on_release("b:2")
        mon._on_release("a:1")
        assert mon.find_cycle() is None
        assert mon.check() == []


class TestInstrumentedLockSemantics:
    def test_condition_wait_releases_hold(self):
        """threading.Condition over the wrapper must report the lock as
        *released* while waiting (otherwise every producer/consumer pair
        would look like a self-deadlock)."""
        mon = LockOrderMonitor()
        from repro.analysis.locks import _InstrumentedLock

        lk = _InstrumentedLock(threading.Lock(), mon, "w.py:1")
        cond = threading.Condition(lk)
        hits = []

        def waiter():
            with cond:
                cond.wait(timeout=30)
                # While re-held after wakeup, record a second site: the
                # edge proves the hold state survived the wait round-trip.
                mon._on_acquire("w.py:2")
                mon._on_release("w.py:2")
                hits.append(True)

        th = threading.Thread(target=waiter)
        th.start()
        for _ in range(1000):
            with cond:
                cond.notify_all()
            if hits:
                break
        th.join(timeout=30)
        assert hits
        assert ("w.py:1", frozenset({"w.py:2"})) in [
            (s, frozenset(d)) for s, d in mon.edges().items()
        ]
        assert mon.find_cycle() is None

    def test_nonblocking_acquire_failure_not_recorded(self):
        mon = LockOrderMonitor()
        from repro.analysis.locks import _InstrumentedLock

        inner = threading.Lock()
        lk = _InstrumentedLock(inner, mon, "n.py:1")
        inner.acquire()  # someone else holds it
        try:
            assert lk.acquire(False) is False
        finally:
            inner.release()
        assert mon._held() == []
        assert lk.acquire(False) is True
        lk.release()
        assert mon.sites() == {"n.py:1"}
