"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, asserting output shapes and finiteness (the brief's requirement), plus
decode-path consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SHAPES, cell_status, get_config, get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tr
from repro.optim import AdamW
from repro.runtime.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b, s):
    return {k: jnp.asarray(v)
            for k, v in SyntheticLM(cfg, b, s, seed=0).batch_at(0).items()}


@pytest.mark.parametrize("arch", list(ARCHS))
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_reduced(arch)
        b, s = 2, 32
        batch = _batch(cfg, b, s)
        logits, aux = tr.forward(params=tr.init_lm(KEY, cfg), cfg=cfg,
                                 tokens=batch.get("tokens"),
                                 feats=batch.get("feats"))
        assert logits.shape == (b, s, cfg.vocab_padded)
        assert bool(jnp.isfinite(logits).all())

    def test_one_train_step_reduces_to_finite_loss(self, arch):
        cfg = get_reduced(arch)
        params = tr.init_lm(KEY, cfg)
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        batch = _batch(cfg, 4, 32)
        params2, opt_state2, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually moved
        moved = jax.tree.reduce(
            lambda acc, pq: acc or bool(jnp.any(pq)), jax.tree.map(
                lambda a, b: jnp.any(a != b), params, params2), False)
        assert moved

    def test_param_counts_match_template(self, arch):
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda: tr.init_lm(KEY, cfg))
        n_template = sum(int(np.prod(x.shape))
                         for x in jax.tree.leaves(sds))
        n_model = cfg.param_counts()["total"]
        # template includes vocab padding + conv/frontend extras; the
        # analytical count must agree within 2%.
        assert abs(n_template - n_model) / n_model < 0.02


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", [
        "granite-3-2b", "mamba2-130m", "qwen3-moe-30b-a3b", "jamba-v0.1-52b",
        "h2o-danube-3-4b",
    ])
    def test_decode_matches_forward(self, arch):
        """Teacher-forced decode must reproduce the forward logits.
        capacity_factor is raised so MoE token-dropping (which legitimately
        differs between a 16-token forward and a 2-token decode step)
        cannot perturb the comparison."""
        cfg = get_reduced(arch).with_(dtype="float32", ssm_chunk=4,
                                      capacity_factor=64.0)
        params = tr.init_lm(KEY, cfg)
        s = 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
        full_logits, _ = tr.forward(params, cfg, tokens=toks)
        cache = tr.init_cache(cfg, 2, max_seq=16)
        step_logits = []
        for t in range(s):
            lg, cache = tr.decode_step(params, cache, cfg, toks[:, t:t + 1])
            step_logits.append(lg[:, 0])
        got = jnp.stack(step_logits, axis=1)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)

    def test_swa_ring_buffer_matches_window_attention(self):
        """The SWA ring-buffer cache must agree with full attention under
        the same window."""
        cfg = get_reduced("h2o-danube-3-4b").with_(dtype="float32", window=8)
        params = tr.init_lm(KEY, cfg)
        s = 20  # > window: the ring buffer wraps
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0, cfg.vocab)
        full_logits, _ = tr.forward(params, cfg, tokens=toks)
        cache = tr.init_cache(cfg, 1, max_seq=cfg.window)
        outs = []
        for t in range(s):
            lg, cache = tr.decode_step(params, cache, cfg, toks[:, t:t + 1])
            outs.append(lg[:, 0])
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


class TestCellRegistry:
    def test_cell_statuses(self):
        from repro.configs.registry import cells
        table = {(a, s): (r, why) for a, s, r, why in cells()}
        assert table[("hubert-xlarge", "decode_32k")][0] is False
        assert table[("hubert-xlarge", "long_500k")][0] is False
        assert table[("command-r-35b", "long_500k")][0] is False
        assert table[("h2o-danube-3-4b", "long_500k")][0] is True  # SWA
        assert table[("mamba2-130m", "long_500k")][0] is True
        assert table[("jamba-v0.1-52b", "long_500k")][0] is True
        n_run = sum(1 for r, _ in table.values() if r)
        assert n_run == 32  # 40 - 2 (hubert decode) - 6 (full-attn 500k)

    def test_exact_brief_configs(self):
        """Spot-check the assigned hyperparameters survived verbatim."""
        c = get_config("command-r-35b")
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (40, 8192, 64, 8, 22528, 256000)
        q = get_config("qwen3-moe-30b-a3b")
        assert (q.n_experts, q.top_k, q.expert_ff, q.vocab) == (
            128, 8, 768, 151936)
        j = get_config("jamba-v0.1-52b")
        assert j.n_layers == 32 and j.n_experts == 16 and j.top_k == 2
        assert sum(1 for b in j.block_pattern if b.mixer == "attn") == 1
        assert len(j.block_pattern) == 8  # 1:7 attn:mamba
        m = get_config("mamba2-130m")
        assert m.ssm_state == 128 and not m.has_attention
