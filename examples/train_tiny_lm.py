"""End-to-end driver: train a small LM for a few hundred steps on CPU with
the full production loop (config -> mesh/sharding -> fault-tolerant
trainer with checkpoints), then sample from it.

    PYTHONPATH=src python examples/train_tiny_lm.py [--arch granite-3-2b]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.launch.train import launch_train
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_tiny_")
    try:
        res = launch_train(
            args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
            ckpt_dir=ckpt, reduced=True, lr=3e-3, log_every=25,
            ckpt_every=100,
        )
        hist = res["history"]
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"\nloss: {first:.3f} -> {last:.3f} over {res['final_step']} steps")
        assert last < first, "training must reduce loss"
        print("training reduced loss ✓")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
