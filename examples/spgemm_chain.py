"""Triangle counting via a device-resident SpGEMM chain.

The classic A²-based count: for an undirected graph with adjacency A,
``tri(v) = (A² ∘ A)[v] / 2`` — the number of triangles through vertex v
is half the number of 2-paths v→x→v' that are closed by an edge. Total
triangles = ``trace-free sum / 6`` == ``sum(A² ∘ A) / 6``.

The chaining layer makes the SpGEMM side one plan composition:
``output="compact"`` keeps A² element-exact (no block-padding zeros),
and the Hadamard mask with A only needs A²'s entries *at A's own
pattern* — which is exactly what ``plan_from_structural_pattern``
computes structurally.

    PYTHONPATH=src python examples/spgemm_chain.py [--matrix poisson3Da]
"""
import argparse

import numpy as np

from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.spgemm import PlanCache, spgemm_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="poisson3Da")
    ap.add_argument("--scale", type=float, default=0.02)
    args = ap.parse_args()

    # 1. An undirected, loop-free 0/1 adjacency with the paper matrix's
    #    sparsity profile.
    m = suite_matrix(args.matrix, scale=args.scale).to_coo().sum_duplicates()
    n = m.shape[0]
    keep = m.row != m.col
    row = np.concatenate([m.row[keep], m.col[keep]])
    col = np.concatenate([m.col[keep], m.row[keep]])
    adj = COO(row, col, np.ones(row.size, np.float32), (n, n))
    adj = adj.sum_duplicates()
    adj.val = np.ones(adj.nnz, np.float32)  # dedupe may have summed
    print(f"graph: {n} vertices, {adj.nnz} directed edges")

    # 2. Plan A @ A with compacted (nnz-exact) output. The compact CSR is
    #    the structural square — no block-padding zeros to mask out.
    cache = PlanCache()
    p = spgemm_plan(adj, adj, tile=16, group=2, backend="jnp", cache=cache,
                    output="compact")
    a2 = p.execute()
    print(f"A²: {a2.data.size} structural entries "
          f"(block output would store {p.assembly.nnz})")

    # 3. Chain demo: A² @ A = A³ without a host round trip — its diagonal
    #    is 2·tri(v) per vertex, so trace(A³)/6 is the triangle count.
    chain = p.then(adj, cache=cache)
    a3 = chain.execute()
    d3 = a3.todense()
    tri_trace = float(np.trace(d3)) / 6.0

    # 4. Same count via the Hadamard route on A² (mask by A's pattern).
    d2 = a2.todense()
    da = np.zeros((n, n), np.float32)
    da[adj.row, adj.col] = 1.0
    tri_hadamard = float((d2 * da).sum()) / 6.0

    # 5. Dense oracle.
    ref = float(np.trace(da @ da @ da)) / 6.0
    print(f"triangles: chain trace(A³)/6 = {tri_trace:.0f}, "
          f"Hadamard sum(A²∘A)/6 = {tri_hadamard:.0f}, oracle = {ref:.0f}")
    assert tri_trace == tri_hadamard == ref
    print("OK")


if __name__ == "__main__":
    main()
