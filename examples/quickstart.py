"""Quickstart: the paper's SpGEMM in five steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.buffering import omar
from repro.core.gustavson import FSpGEMMSimulator, spgemm_gustavson
from repro.core.tuning import ARRIA10_GX, derive_fpga_params
from repro.sparse.convert import to_csv
from repro.sparse.random import suite_matrix

# 1. A sparse matrix with the published poisson3Da profile (Table 4).
a = suite_matrix("poisson3Da", scale=0.02)
print(f"matrix: {a}")

# 2. Derive the paper's architectural parameters for Arria 10 GX.
sw, num_pe = derive_fpga_params(ARRIA10_GX)
print(f"Sec 4.2.4 optimum: SW={sw}, NUM_PE={num_pe}")

# 3. Host pre-processing: convert to the CSV format (Sec. 3).
a_csv = to_csv(a, num_pe)
a_csv.validate()
print(f"CSV vectors: {a_csv.num_vectors()}  OMAR: {omar(a, num_pe):.1f}%")

# 4. Run the FPGA-kernel simulator (Sec. 4.2 + Algorithm 1).
c, stats = FSpGEMMSimulator(num_pe, sw).run(a_csv, a)
print(f"C = A @ A: nnz={c.nnz}, kernel cycles={stats.cycles}, "
      f"B-row fetches={stats.b_row_fetches} (naive would be {a.nnz})")

# 5. Check against the vectorized Gustavson oracle.
ref = spgemm_gustavson(a, a)
err = np.abs(c.todense() - ref.todense()).max()
print(f"max |err| vs oracle: {err:.2e}")
assert err < 1e-3
print("OK")
