"""Multi-tenant SpGEMM serving through the gateway front end.

Many tenants hammer many sparsity patterns concurrently; each pattern's
symbolic plan is built once (PlanCache + ``pattern_token`` fast key) and
the gateway does the serving-side work the per-plan pipeline cannot:

* **micro-batching** — same-pattern requests landing within the batch
  window dispatch as ONE pipeline submission (watch ``batch_fill`` > 1
  under the bursty phase; results stay bitwise-equal to per-request
  ``plan.execute``);
* **fair scheduling** — deficit round-robin by pending value *bytes*
  across patterns over a bounded pool of live pipelines, so the hot
  tenant's backlog cannot starve the cold one;
* **backpressure** — queue depth, in-flight byte budget, and plan-cache
  byte pressure all shed with explicit typed outcomes
  (``GatewayResult.outcome``), never exceptions out of the scheduler and
  never hangs;
* **metrics** — per-pattern queue depth, batch fill, p50/p99 latency,
  throughput, and shed counts in a shared ``MetricsRegistry`` that a
  ``Heartbeat`` exports as JSON lines while the demo runs.

    PYTHONPATH=src python examples/spgemm_gateway.py
"""
import argparse
import json
import tempfile
import threading
import time

import numpy as np

from repro.data.pipeline import SpGEMMValueStream
from repro.runtime.heartbeat import Heartbeat, MetricsRegistry
from repro.sparse.random import random_coo
from repro.spgemm import Outcome, PlanCache, SpGEMMGateway

parser = argparse.ArgumentParser(description="multi-tenant gateway demo")
parser.add_argument("--bursts", type=int, default=6)
parser.add_argument("--burst-size", type=int, default=8)
args = parser.parse_args()


def pattern(seed, m, k, n, density=0.06):
    a = random_coo(m, k, density, "uniform", seed=seed).sum_duplicates()
    b = random_coo(k, n, density, "uniform", seed=seed + 1).sum_duplicates()
    return a, b


def same_csr(x, y):
    return (np.array_equal(x.indptr, y.indptr)
            and np.array_equal(x.indices, y.indices)
            and np.array_equal(x.data, y.data))


# --- gateway + metrics ---------------------------------------------------
# One registry shared by the gateway and the heartbeat: every beat line
# carries the live per-pattern counters.
metrics = MetricsRegistry()
cache = PlanCache()
gw = SpGEMMGateway(cache=cache, metrics=metrics, max_pipelines=2, depth=2,
                   max_batch=8, batch_window=0.002)

# Two tenants, two patterns. register() resolves through the PlanCache
# with the token as the warm-path fast key — a re-register is a cache hit.
plans = {
    "tenant0/attn": gw.register("tenant0/attn", *pattern(0, 96, 72, 80),
                                tile=8, group=2, backend="jnp"),
    "tenant1/mlp": gw.register("tenant1/mlp", *pattern(4, 64, 64, 64, 0.08),
                               tile=8, group=2, backend="jnp"),
}
streams = {
    tok: SpGEMMValueStream(p.a_pattern, p.b_pattern, seed=7 + i)
    for i, (tok, p) in enumerate(plans.items())
}
print(f"registered {len(plans)} patterns; cache: {cache.stats()}")

with tempfile.TemporaryDirectory() as beat_dir:
    hb = Heartbeat(beat_dir, interval=0.2, metrics=metrics)
    hb.start()

    # --- phase 1: bursty concurrent tenants ------------------------------
    # Each tenant thread fires bursts of same-instant requests; arrivals
    # within the 2 ms window coalesce into single pipeline dispatches.
    results = {}
    lock = threading.Lock()

    def tenant(tok):
        for burst in range(args.bursts):
            tickets = []
            for j in range(args.burst_size):
                step = burst * args.burst_size + j
                tickets.append(
                    (step, gw.submit(tok, *streams[tok].values_at(step))))
            for step, t in tickets:
                res = t.wait(timeout=300)
                with lock:
                    results[(tok, step)] = res
            time.sleep(0.002)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=tenant, args=(tok,)) for tok in plans]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    elapsed = time.perf_counter() - t0

    n_ok = sum(1 for r in results.values() if r.outcome is Outcome.OK)
    print(f"\nphase 1: {n_ok}/{len(results)} requests OK in {elapsed:.2f}s "
          f"({n_ok / elapsed:.1f} req/s aggregate)")

    # Verify a sample bitwise against direct plan.execute.
    checked = 0
    for (tok, step), res in sorted(results.items())[:6]:
        ref = plans[tok].execute(*streams[tok].values_at(step))
        assert same_csr(ref, res.value), (tok, step)
        checked += 1
    print(f"bitwise check vs plan.execute: {checked}/{checked} equal")

    stats = gw.stats()
    print("\npattern,completed,dispatches,batch_fill,p50_ms,p99_ms,"
          "throughput_rps,shed")
    for tok, ps in stats["patterns"].items():
        lat = ps["latency_s"]
        print(f"{tok},{ps['completed']},{ps['dispatches']},"
              f"{ps['batch_fill']:.2f},{lat['p50'] * 1e3:.2f},"
              f"{lat['p99'] * 1e3:.2f},{ps['throughput_rps']:.1f},"
              f"{ps['shed_total']}")
        assert ps["batch_fill"] > 1.0, "bursty arrivals should micro-batch"

    hb.stop()
    beats = sorted(p for p in __import__("os").listdir(beat_dir))
    with open(f"{beat_dir}/{beats[-1]}") as f:
        last = json.load(f)
    n_metrics = len(last.get("metrics", {}))
    print(f"\nheartbeat exported {len(beats)} beats; last beat carries "
          f"{n_metrics} metric series (e.g. "
          f"gateway.tenant0/attn.latency_s p99="
          f"{last['metrics']['gateway.tenant0/attn.latency_s']['p99']:.4f}s)")

gw.close()

# --- phase 2: overload sheds, not hangs ----------------------------------
# A byte budget sized for ~2 requests: the rest resolve IMMEDIATELY with
# Outcome.SHED_BYTES; admitted work still completes and verifies.
tok = "tenant0/attn"
plan = plans[tok]
gw2 = SpGEMMGateway(cache=cache, metrics=metrics, max_pipelines=1,
                    max_inflight_bytes=2 * plan.value_nbytes() + 16,
                    start=False)
gw2.register_plan(tok, plan)
tickets = [gw2.submit(tok, *streams[tok].values_at(s)) for s in range(8)]
shed = [t.wait(0) for t in tickets if t.done()]
gw2.start()
done = [t.wait(timeout=300) for t in tickets]
gw2.close()
ok = [r for r in done if r.outcome is Outcome.OK]
print(f"\nphase 2 (budget ~2 requests): submitted {len(tickets)}, "
      f"shed {len(shed)} at admission "
      f"({sorted({r.outcome.value for r in shed})}), {len(ok)} completed")
assert all(r.outcome is Outcome.SHED_BYTES for r in shed)
assert all(
    same_csr(plan.execute(*streams[tok].values_at(s)), r.value)
    for s, r in enumerate(done) if r.outcome is Outcome.OK
)
print("admitted results verified; overload shed typed, nothing hung")
