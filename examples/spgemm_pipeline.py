"""End-to-end plan/execute SpGEMM pipeline on the TPU (block) path.

The paper's host pre-processing "only needs to be performed once"
(Sec. 4.3). ``spgemm_plan`` is that statement as an API: ONE call runs the
sparse-native format conversion (no dense round-trip), the symbolic
block-Gustavson phase (C structure + static triple schedule + the output
assembly map), schedule padding, and device staging; everything after
that is numeric-only. The final sections re-plan the same pattern on a
4-device mesh (``spgemm_plan(..., mesh=...)``) and — with ``--pipeline``
— stream it through the async submit/collect pipeline.

Which numeric entry point to use
--------------------------------
* ``plan.execute(a_vals, b_vals)`` — one result, now. Simplest; each call
  serializes rebind, H2D, kernel, assembly, and D2H. Use it for
  request/response calls and whenever latency of *this one step* is all
  that matters.
* ``plan.execute_batch(a_batch, b_batch)`` — many independent value sets
  that are all available at once. One vmapped device call per
  cache-sized chunk; highest device efficiency, but the whole batch
  lands together (no early results).
* ``plan.pipeline(depth) / execute_async / execute_stream`` — a *stream*
  of value sets arriving over time (the serving shape). ``submit`` only
  dispatches — step s+1's value generation + staging overlaps step s's
  kernel, results materialize at ``collect`` — so throughput approaches
  the kernel rate while each result is still available as soon as it is
  done. ``depth=2`` is the paper's double buffer; results are
  bitwise-equal to sequential ``execute`` calls.

    PYTHONPATH=src python examples/spgemm_pipeline.py [--pipeline]
"""
import os

# Force 4 host devices BEFORE any jax import so the sharded section has a
# real mesh to lay the plan out on (same trick as the dry-run entry point;
# everything before that section still runs single-plan semantics).
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=4"
).strip()

import argparse
import tempfile
import time

import numpy as np

from repro.core.gustavson import spgemm_gustavson
from repro.data.pipeline import SpGEMMValueStream
from repro.sparse.convert import to_csr
from repro.sparse.formats import COO
from repro.sparse.io import read_matrix_market, write_matrix_market
from repro.sparse.random import suite_matrix
from repro.spgemm import default_cache, schedule_build_count, spgemm_plan

TILE = 64
GROUP = 4

_parser = argparse.ArgumentParser(description="plan/execute SpGEMM demo")
_parser.add_argument("--pipeline", action="store_true",
                     help="also run the async streaming (submit/collect) "
                          "serving section")
_parser.add_argument("--steps", type=int, default=16,
                     help="streaming steps for the --pipeline section")
args = _parser.parse_args()

# --- host program: load the raw matrix file ------------------------------
a_small = suite_matrix("scircuit", scale=0.005)
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "scircuit.mtx")
    write_matrix_market(path, a_small)
    a = to_csr(read_matrix_market(path))
print(f"loaded: {a}")

# B = A^T (C = A @ A^T for a change), still element-level sparse.
a_coo = a.to_coo()
b_coo = COO(a_coo.col, a_coo.row, a_coo.val, (a.shape[1], a.shape[0]))

# --- plan: ALL amortizable work happens here, once -----------------------
builds_before = schedule_build_count()
plan = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="pallas_interpret")
rep = plan.report
print(f"plan: {rep.nnzb_a} A blocks, {rep.nnzb_b} B blocks, "
      f"{rep.num_triples} triples, {rep.n_panels} panels, "
      f"B fetches {rep.b_fetches} (block OMAR {rep.block_omar:.1f}%)")

# --- execute: numeric phase only -----------------------------------------
c = plan.execute()
ref = spgemm_gustavson(to_csr(a_coo), to_csr(b_coo))
err = np.abs(c.todense() - ref.todense()).max()
print(f"C: {c}  max|err| vs Gustavson oracle = {err:.2e}")
assert err < 1e-2

# --- serving loop: fresh values, same pattern, zero symbolic work --------
stream = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7)
for step in range(3):
    a_vals, b_vals = stream.values_at(step)
    c_step = plan.execute(a_vals, b_vals)
    ref_step = spgemm_gustavson(
        to_csr(COO(plan.a_pattern.row, plan.a_pattern.col, a_vals, a_coo.shape)),
        to_csr(COO(plan.b_pattern.row, plan.b_pattern.col, b_vals, b_coo.shape)),
    )
    err = np.abs(c_step.todense() - ref_step.todense()).max()
    print(f"step {step}: C nnz={c_step.nnz}  max|err|={err:.2e}")
    assert err < 1e-2
assert schedule_build_count() == builds_before + 1, "symbolic phase re-ran!"

# --- batched serving: vmap over the device-resident numeric phase --------
# The same value stream in batch mode; one execute_batch call runs the whole
# batch (rebind + kernel + assembly) in a single vmapped device program.
BATCH = 4
stream_b = SpGEMMValueStream(plan.a_pattern, plan.b_pattern, seed=7,
                             batch=BATCH)
av, bv = stream_b.values_batch_at(0)
cs = plan.execute_batch(av, bv)
for i, c_i in enumerate(cs):
    c_one = plan.execute(av[i], bv[i])
    err = np.abs(c_i.todense() - c_one.todense()).max()
    assert err < 1e-3, f"batch element {i} diverged: {err:.2e}"
print(f"execute_batch({BATCH}): all elements match single executes")
assert schedule_build_count() == builds_before + 1, "symbolic phase re-ran!"

# --- cache: pattern-equal request returns the identical plan -------------
plan2 = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="pallas_interpret")
assert plan2 is plan, "expected a cache hit"
print(f"plan cache: hits={default_cache().stats.hits} "
      f"executes={rep.executes} schedule_builds={rep.schedule_builds}")

# --- warm restart: the symbolic phase survives the process ----------------
# PlanCache(disk_dir=...) persists the value-independent artifacts (triple
# schedule, scatter indices, assembly map) to disk; a restarted worker
# rehydrates the plan instead of re-running the symbolic phase. In
# production, point REPRO_SPGEMM_PLAN_DIR at a shared directory and the
# process-default cache does this with zero code changes:
#
#     REPRO_SPGEMM_PLAN_DIR=/var/cache/spgemm python serve.py
#
# Here both "processes" are fresh PlanCache instances over one directory.
from repro.spgemm import PlanCache  # noqa: E402

with tempfile.TemporaryDirectory() as plan_dir:
    worker1 = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="jnp",
                          cache=PlanCache(disk_dir=plan_dir))
    c_cold = worker1.execute()
    # ... the worker restarts: new cache, same directory, same pattern ...
    restarted = PlanCache(disk_dir=plan_dir)
    worker2 = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="jnp",
                          cache=restarted)
    assert worker2.report.schedule_builds == 0, "warm start rebuilt!"
    assert worker2.report.load_hits >= 1
    c_warm = worker2.execute()
    assert np.array_equal(c_cold.data, c_warm.data), "warm C diverged"
    s = restarted.stats()
    print(f"warm restart: schedule_builds={worker2.report.schedule_builds} "
          f"load_hits={worker2.report.load_hits} "
          f"disk_files={s['disk_files']} disk_kb={s['disk_bytes'] // 1024}")

# --- sharded serving: the same pattern partitioned over a 4-device mesh ---
# The mesh extends the cache key, so this builds a second (sharded) plan;
# A values are row-sharded, B replicated, C concatenated along the
# precomputed indptr boundaries — results match the single plan exactly.
from repro.launch.mesh import make_shard_mesh  # noqa: E402

mesh = make_shard_mesh(4)
plan_sh = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="jnp",
                      mesh=mesh)
stats = plan_sh.shard_stats()
print(f"sharded plan: {stats['n_shards']} shards, per-shard triples "
      f"{stats['triples']} (imbalance {stats['imbalance']:.2f})")
a_vals, b_vals = stream.values_at(0)
c_sh = plan_sh.execute(a_vals, b_vals)
c_one = plan.execute(a_vals, b_vals)
err = np.abs(c_sh.todense() - c_one.todense()).max()
assert err < 1e-5, f"sharded result diverged: {err:.2e}"
cs_sh = plan_sh.execute_batch(av, bv)
for i, c_i in enumerate(cs_sh):
    err = np.abs(c_i.todense() - cs[i].todense()).max()
    assert err < 1e-5, f"sharded batch element {i} diverged: {err:.2e}"
print(f"sharded execute + execute_batch({BATCH}) match the single-device "
      f"plan  (cache stats: {default_cache().stats()})")

# --- async streaming serving (--pipeline): submit/collect over the plan ---
# The pipeline splits the numeric phase into stage (H2D + rebind) ->
# kernel -> assembly/collect and keeps `depth` steps in flight, so step
# s+1's value generation and staging overlap step s's kernel; results are
# bitwise-equal to sequential execute() calls and come back in order.
if args.pipeline:
    jplan = spgemm_plan(a, b_coo, tile=TILE, group=GROUP, backend="jnp")

    # Explicit submit/collect: two steps in flight, out-of-order collect.
    with jplan.pipeline(depth=2) as pipe:
        t0 = pipe.submit(*stream.values_at(0))
        t1 = pipe.submit(*stream.values_at(1))  # overlaps t0's kernel
        c1 = pipe.collect(t1)  # out-of-order is fine
        c0 = t0.result()
    for s, c_p in ((0, c0), (1, c1)):
        assert np.array_equal(c_p.data,
                              jplan.execute(*stream.values_at(s)).data)
    print("pipeline: submit/collect (out-of-order) matches execute bitwise")

    # Streaming: SpGEMMValueStream.value_iter generates values in a
    # prefetch thread; execute_stream keeps the pipeline full. (The
    # throughput win over synchronous execute appears on host-bound
    # serving shapes — overlap buys nothing once the kernel saturates
    # the device, as on this small dense-ish demo pattern; see the
    # `bench_kernels --pipeline-depth` section for the measured
    # steps/s-vs-sync numbers on the paper matrices.)
    n = max(2, args.steps)
    t_start = time.perf_counter()
    seen = sum(1 for _ in jplan.execute_stream(
        stream.value_iter(steps=n), depth=2))
    pipe_s = time.perf_counter() - t_start
    print(f"pipeline: streamed {seen} steps at depth 2 "
          f"({n / pipe_s:.0f} steps/s), results ordered and bitwise-equal "
          f"to execute")
print("OK")
