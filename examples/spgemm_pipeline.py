"""End-to-end SpGEMM pipeline on the TPU (block) path.

Raw matrix file -> BCSV/BCSR conversion (host pre-processing) -> static
triple schedule (host symbolic phase) -> Pallas block-Gustavson kernel
(interpret mode on CPU) -> CSR result, with the reuse metrics the schedule
realizes.

    PYTHONPATH=src python examples/spgemm_pipeline.py
"""
import os
import tempfile

import numpy as np

from repro.core.schedule import build_spgemm_schedule
from repro.kernels import ops
from repro.sparse.convert import pad_to_blocks, to_bcsr, to_bcsv, to_csr
from repro.sparse.io import read_matrix_market, write_matrix_market
from repro.sparse.random import suite_matrix

BLOCK = 64
GROUP = 4

# --- host program: load the raw matrix file ------------------------------
a_small = suite_matrix("scircuit", scale=0.005)
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "scircuit.mtx")
    write_matrix_market(path, a_small)
    a = to_csr(read_matrix_market(path))
print(f"loaded: {a}")

# --- pre-processing: convert once to the block formats -------------------
ad = pad_to_blocks(a.todense(), (BLOCK, BLOCK))
bd = ad.T.copy()  # C = A @ A^T for a change
a_bcsv = to_bcsv(ad, (BLOCK, BLOCK), group=GROUP)
b_bcsr = to_bcsr(bd, (BLOCK, BLOCK))
print(f"A blocks: {a_bcsv.nnzb}, B blocks: {b_bcsr.nnzb}")

# --- symbolic phase: C structure + CSV-order triple schedule --------------
sched = build_spgemm_schedule(a_bcsv, b_bcsr)
print(f"schedule: {sched.num_triples} triples, {sched.n_panels} panels, "
      f"B fetches {sched.b_fetches()} (block OMAR {sched.block_omar():.1f}%)")

# --- device phase: the Pallas kernel -------------------------------------
c = ops.spgemm(a_bcsv, b_bcsr, backend="pallas_interpret", schedule=sched)
ref = ad.astype(np.float64) @ bd.astype(np.float64)
err = np.abs(c.todense() - ref).max()
print(f"C: {c}  max|err| vs dense = {err:.2e}")
assert err < 1e-2
print("OK")
