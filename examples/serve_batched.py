"""Serve a small model with batched requests through the slot-based
batched decoder (launch/serve.py).

    PYTHONPATH=src python examples/serve_batched.py [--arch granite-3-2b]
"""
import argparse
import time

import numpy as np

from repro.configs.registry import get_reduced
from repro.launch.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    server = BatchedServer(cfg, batch_slots=4, max_seq=128)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        server.submit(Request(i, rng.integers(0, cfg.vocab, plen).tolist(),
                              args.max_new))
    t0 = time.time()
    done = server.run_until_done()
    dt = time.time() - t0
    assert len(done) == args.requests
    assert all(len(r.out) == args.max_new for r in done)
    print(f"served {len(done)} requests / {server.stats['tokens']} tokens "
          f"in {dt:.1f}s ({server.stats['tokens']/dt:.1f} tok/s, "
          f"{server.stats['steps']} batch steps)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.prompt[:4]}... -> {r.out}")
    print("OK")


if __name__ == "__main__":
    main()
