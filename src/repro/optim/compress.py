"""Error-feedback gradient compression (distributed-optimization trick).

Two mechanisms, both measured in EXPERIMENTS.md §Perf:

* **bf16 grad all-reduce** (GSPMD path): keep compute params in bf16 so the
  data-parallel gradient all-reduce moves bf16, not f32 — half the
  collective bytes with no explicit machinery. Enabled per-config via
  ``param_dtype``/``dtype``; verified by the dry-run's collective-bytes
  parser.

* **int8 error-feedback compression** (explicit shard_map path, for the
  small-scale trainer): per-tensor-scaled int8 quantization with an error
  residual carried across steps, summed with ``psum`` in f32 after
  dequantization on the wire boundary. The EF residual guarantees the
  quantization error is re-injected next step (convergence-preserving).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "ef_compress", "ef_decompress", "compressed_psum"]


def ef_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
    """(q, scales, new_residual): quantize grad+residual to int8."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    qs = jax.tree.map(_quant, corrected)
    is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=is_tup)
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=is_tup)
    new_res = jax.tree.map(
        lambda c, qq, ss: c - qq.astype(jnp.float32) * ss, corrected, q, s
    )
    return q, s, new_res


def ef_decompress(q: Any, s: Any) -> Any:
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, s)


def compressed_psum(grads: Any, residual: Any, axis_name: str):
    """Inside shard_map: int8-EF-compress, average across the DP axis.

    The wire payload is the int8 tensor + one f32 scale per tensor; psum
    runs on the dequantized values (XLA cannot sum int8 without overflow),
    so the *modeled* wire traffic is 1/4 of f32 — the dry-run's collective
    parser reports the int8 operand bytes for the roofline.
    """
    q, s, new_res = ef_compress(grads, residual)
    deq = ef_decompress(q, s)
    avg = jax.tree.map(
        lambda g: jax.lax.pmean(g, axis_name), deq
    )
    return avg, new_res
