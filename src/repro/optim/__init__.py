from repro.optim.adamw import AdamW
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.clip import clip_by_global_norm
