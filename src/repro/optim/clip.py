"""Global-norm gradient clipping."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["clip_by_global_norm", "global_norm"]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    # Scale in each leaf's own dtype: an f32 `x * scale` would promote the
    # whole (param-sized) tree to f32 — XLA then sinks the convert into the
    # gradient buffers, doubling their bytes.
    return jax.tree.map(
        lambda x: x * scale.astype(x.dtype), tree), norm
