"""AdamW with optional bf16 compute params + f32 master weights.

Pure-functional: ``init(params) -> state``; ``update(grads, state, params,
step) -> (new_params, new_state)``. With ``master=True`` the training params
may be bf16 (what the forward consumes, and what the gradient all-reduce
moves — half the DP collective bytes); the f32 master copy lives in the
optimizer state and is the ZeRO-1-sharded tensor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW"]


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    master: bool = False  # keep f32 master copy (params may be bf16)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params: Any) -> Dict:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state = {
            "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.master:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params
            )
        return state

    def update(
        self, grads: Any, state: Dict, params: Any
    ) -> Tuple[Any, Dict]:
        step = state["step"] + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        ref = state["master"] if self.master else params

        def upd(g, m, v, p):
            # No standalone f32 cast of g: the converts fuse into the m/v
            # elementwise updates (which are f32-typed via m/v), so no
            # param-sized f32 gradient buffer materializes.
            m = b1 * m + (1 - b1) * g.astype(jnp.float32)
            v = b2 * v + (1 - b2) * (g * g).astype(jnp.float32)
            upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (upd_ + self.weight_decay * p32)
            return m, v, p32

        fused = jax.tree.map(upd, grads, state["m"], state["v"], ref)
        is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
        m = jax.tree.map(lambda t: t[0], fused, is_leaf=is_tup)
        v = jax.tree.map(lambda t: t[1], fused, is_leaf=is_tup)
        new_master = jax.tree.map(lambda t: t[2], fused, is_leaf=is_tup)
        new_state = {"m": m, "v": v, "step": step}
        if self.master:
            new_state["master"] = new_master
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params
        )
        return new_params, new_state
