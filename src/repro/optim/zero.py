"""ZeRO-1 optimizer-state sharding.

Parameters are TP-sharded over ``model`` and replicated over the data axes;
the optimizer moments (and f32 master copy) additionally shard over the
data axes — each DP rank owns 1/DP of every state tensor. With GSPMD this
is one sharding-constraint table: ``zero1_specs`` extends each parameter's
PartitionSpec by placing the data axes on the first dimension the spec
leaves unsharded (preferring the largest dim for even splits).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["zero1_specs", "opt_state_specs"]


def _extend(spec: P, shape, data_axes, mesh: Mesh) -> P:
    axes = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for ax in axes for a in ((ax,) if isinstance(ax, str) else (ax or ()))}
    if any(a in used for a in data_axes):
        return P(*axes)  # already data-sharded (e.g. FSDP params)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    # Choose the largest unsharded dim divisible by the DP degree; fall back
    # to the largest unsharded dim (GSPMD pads uneven shards).
    # Only evenly-divisible dims: these specs feed jit in_shardings, which
    # (unlike with_sharding_constraint) demand exact divisibility.
    div = [i for i, ax in enumerate(axes)
           if ax is None and shape[i] > 1 and shape[i] % dp == 0]
    if not div:
        return P(*axes)
    pick = max(div, key=lambda i: shape[i])
    axes[pick] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*axes)


def zero1_specs(param_specs: Any, param_shapes: Any, mesh: Mesh) -> Any:
    """Per-leaf PartitionSpecs for one optimizer-state copy of the params."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not data_axes:
        return param_specs
    return jax.tree.map(
        lambda s, sh: _extend(s, sh.shape, data_axes, mesh),
        param_specs,
        param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_specs(
    param_specs: Any, param_shapes: Any, mesh: Mesh, master: bool
) -> Dict:
    """Specs for the AdamW state dict {m, v, step[, master]}."""
    z = zero1_specs(param_specs, param_shapes, mesh)
    out = {"m": z, "v": z, "step": P()}
    if master:
        out["master"] = z
    return out
