"""Lock-order deadlock lint for the serving stack.

The gateway/pipeline/cache/plan layers each own locks and call across
layers while holding them (e.g. ``SpGEMMPipeline.submit`` acquires
``plan._lock`` under ``pipeline._lock``). A deadlock needs a *cycle* in
the lock-acquisition order; this module records that order empirically
and fails on cycles:

* :class:`LockOrderMonitor` — the acquisition-graph recorder. Locks are
  identified by their **creation site** (``file:line``), so every
  ``plan._lock`` instance maps to one graph node; an edge ``A -> B``
  means some thread acquired a ``B``-site lock while holding an
  ``A``-site lock.
* :func:`instrument_spgemm_locks` — a context manager that swaps the
  ``threading`` module attribute of ``repro.spgemm``'s gateway,
  pipeline, cache, plan, and persist modules for a recording shim, so
  every lock those modules construct *while instrumented* reports to the
  monitor. Existing locks are untouched — construct the objects under
  test inside the ``with`` block.
* :meth:`LockOrderMonitor.check` — cycle detection over the site graph.
  A cycle between distinct sites is an ``error`` (two threads can
  interleave into a deadlock); two *instances* of the same site nested
  (plan-lock under plan-lock, say) is a ``warning`` — safe only under an
  instance ordering the graph cannot see.

Typical use (the CLI's ``--lock-lint`` and tests/test_lock_order.py)::

    with instrument_spgemm_locks() as mon:
        ... build a gateway, submit, collect, close ...
    mon.check()   # raises LockOrderError on a cycle
"""
from __future__ import annotations

import contextlib
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.verify import Finding

__all__ = [
    "LockOrderError",
    "LockOrderMonitor",
    "instrument_spgemm_locks",
]

# The serving-stack modules whose lock construction gets instrumented.
INSTRUMENTED_MODULES = (
    "repro.spgemm.gateway",
    "repro.spgemm.pipeline",
    "repro.spgemm.cache",
    "repro.spgemm.plan",
    "repro.spgemm.persist",
)


class LockOrderError(AssertionError):
    """The recorded lock-acquisition graph contains a cycle."""


class _InstrumentedLock:
    """A ``threading.Lock``/``RLock`` proxy that reports acquire/release
    to the monitor. Duck-compatible with ``threading.Condition(lock)``
    (which only needs ``acquire``/``release`` and context management)."""

    __slots__ = ("_lock", "_monitor", "site")

    def __init__(self, lock, monitor: "LockOrderMonitor", site: str):
        self._lock = lock
        self._monitor = monitor
        self.site = site

    def acquire(self, *args, **kwargs):
        blocking = args[0] if args else kwargs.get("blocking", True)
        if blocking:
            # Record *intent* before a blocking acquire: a deadlocked
            # acquire would otherwise never be observed at all.
            self._monitor._on_acquire(self.site)
            got = self._lock.acquire(*args, **kwargs)
            if not got:  # timed out
                self._monitor._on_release(self.site)
            return got
        got = self._lock.acquire(*args, **kwargs)
        if got:
            self._monitor._on_acquire(self.site)
        return got

    def release(self):
        self._monitor._on_release(self.site)
        return self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._lock.locked()


class _ThreadingShim:
    """Stands in for a module's ``threading`` attribute: ``Lock()`` /
    ``RLock()`` return instrumented wrappers named by their creation
    site; everything else proxies to the real module."""

    def __init__(self, monitor: "LockOrderMonitor", modname: str):
        self._monitor = monitor
        self._modname = modname

    def _site(self) -> str:
        frame = sys._getframe(2)
        short = self._modname.rsplit(".", 1)[-1]
        return f"{short}.py:{frame.f_lineno}"

    def Lock(self):  # noqa: N802 - mirrors threading.Lock
        return _InstrumentedLock(
            threading.Lock(), self._monitor, self._site()
        )

    def RLock(self):  # noqa: N802 - mirrors threading.RLock
        return _InstrumentedLock(
            threading.RLock(), self._monitor, self._site()
        )

    def Condition(self, lock=None):  # noqa: N802 - mirrors threading
        # threading.Condition works against the wrapper's acquire/release
        # (its _is_owned / _release_save fallbacks), so wait/notify keep
        # reporting hold state correctly through the proxy.
        if lock is None:
            lock = self.Lock()
        return threading.Condition(lock)

    def __getattr__(self, name):
        return getattr(threading, name)


class LockOrderMonitor:
    """Records which lock *sites* are held when each site is acquired."""

    def __init__(self):
        self._graph_lock = threading.Lock()
        # site -> set of sites acquired while it was held (A -> B edges).
        self._edges: Dict[str, Set[str]] = {}
        self._sites: Set[str] = set()
        # Same-site nesting across distinct instances (warning class).
        self._self_nested: Set[str] = set()
        self._tls = threading.local()

    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _on_acquire(self, site: str) -> None:
        held = self._held()
        with self._graph_lock:
            self._sites.add(site)
            for h in held:
                if h == site:
                    self._self_nested.add(site)
                else:
                    self._edges.setdefault(h, set()).add(site)
        held.append(site)

    def _on_release(self, site: str) -> None:
        held = self._held()
        # Remove the innermost matching hold (locks are typically — but
        # not necessarily — released LIFO).
        for i in range(len(held) - 1, -1, -1):
            if held[i] == site:
                del held[i]
                return

    def edges(self) -> Dict[str, Set[str]]:
        with self._graph_lock:
            return {k: set(v) for k, v in self._edges.items()}

    def sites(self) -> Set[str]:
        with self._graph_lock:
            return set(self._sites)

    def find_cycle(self) -> Optional[List[str]]:
        """A site cycle in the acquisition graph, or None."""
        edges = self.edges()
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {s: WHITE for s in set(edges) | {
            t for vs in edges.values() for t in vs
        }}
        parent: Dict[str, Optional[str]] = {}

        def dfs(u: str) -> Optional[Tuple[str, str]]:
            color[u] = GRAY
            for v in sorted(edges.get(u, ())):
                if color[v] == GRAY:
                    return (u, v)
                if color[v] == WHITE:
                    parent[v] = u
                    back = dfs(v)
                    if back is not None:
                        return back
            color[u] = BLACK
            return None

        for s in sorted(color):
            if color[s] == WHITE:
                parent[s] = None
                back = dfs(s)
                if back is not None:
                    u, v = back
                    cycle = [v, u]
                    while cycle[-1] != v and parent.get(cycle[-1]):
                        cycle.append(parent[cycle[-1]])
                    return list(reversed(cycle))
        return None

    def findings(self) -> List[Finding]:
        out: List[Finding] = []
        cycle = self.find_cycle()
        if cycle is not None:
            out.append(Finding(
                check="locks.cycle", severity="error",
                message="lock-order cycle: " + " -> ".join(cycle),
            ))
        with self._graph_lock:
            for site in sorted(self._self_nested):
                out.append(Finding(
                    check="locks.self-nesting", severity="warning",
                    message=f"two instances of {site} nested; safe only "
                            f"under a consistent instance order",
                ))
        return out

    def check(self) -> List[Finding]:
        """Raise :class:`LockOrderError` on a cycle; return findings."""
        found = self.findings()
        for f in found:
            if f.severity == "error":
                raise LockOrderError(f.message)
        return found


@contextlib.contextmanager
def instrument_spgemm_locks(modules: Tuple[str, ...] = INSTRUMENTED_MODULES):
    """Swap the serving modules' ``threading`` attribute for a recording
    shim; yields the :class:`LockOrderMonitor`. Only locks constructed
    inside the ``with`` block are recorded."""
    import importlib

    monitor = LockOrderMonitor()
    saved = []
    try:
        for name in modules:
            mod = importlib.import_module(name)
            saved.append((mod, mod.threading))
            mod.threading = _ThreadingShim(monitor, name)
        yield monitor
    finally:
        for mod, original in saved:
            mod.threading = original
