"""Static plan/schedule verifier: prove plan invariants without executing.

:func:`verify_plan` takes a built :class:`~repro.spgemm.plan.SpGEMMPlan`
(or :class:`~repro.spgemm.plan.ShardedSpGEMMPlan`) and checks, on the host
with numpy only:

1. **Schedule well-formedness** — every triple's slot/panel/sub-row index
   in bounds, start flags exactly marking the first triple of each panel,
   every panel visited in one contiguous run (the precondition for Pallas
   output revisiting), panel and C-block key arrays in the ascending order
   ``build_assembly_map`` requires.
2. **Dummy-pad-panel discipline** — the pad panel the kernel wrappers
   append (``n_panels`` in the single grid, per-element slot
   ``b * (n_panels + 1) + n_panels`` in the batch-folded grid, ``p_max``
   in the stacked shard schedules) is *write-only*: no assembly gather
   index ever reads it.
3. **Assembly coverage** — C's structural CSR is exact: indptr monotone
   and consistent, column indices in range and strictly ascending per
   row, every gather index in range and used **exactly once**, and the
   total nnz equal to the schedule's structural block pattern trimmed to
   the true output shape.
4. **Write-write race freedom** — for the batch-folded grid
   (:func:`~repro.kernels.gustavson_spgemm.spgemm_scheduled_batch_impl`)
   and the per-shard stacked schedules
   (:func:`~repro.core.schedule.stack_shard_schedules`), the scatter
   targets of distinct batch elements / shards are disjoint, and within
   one element each output slot's writers form a single contiguous run of
   grid steps. This is the proof obligation behind declaring the batch
   grid axis ``"parallel"``.
5. **Shard-partition exactness** (sharded plans) — shard group ranges are
   disjoint, contiguous, and cover all groups; triple/panel/A-slot spans
   tile the parent schedule; and re-deriving every shard from the bounds
   vector (:func:`~repro.core.schedule.shards_from_bounds`) reproduces
   the plan's shards **bitwise**, including each shard's rebased local
   schedule and its per-shard assembly slice.
6. **Compact-output exactness** (``output="compact"`` plans) — the
   compacted gather map is a well-formed canonical CSR, a *subset* of the
   block assembly's gather space with every slot read at most once (the
   exactly-once coverage proof carries over: block coverage + subset +
   no duplicates), and bitwise re-derivable from the block assembly and
   the compact pattern via
   :func:`~repro.core.schedule.build_compact_map`.

Plans also surface configuration-provenance warnings here: a persisted
tuned config whose symbolic facts no longer match the plan
(``apply_tuned_config`` fell back to defaults) is reported as a
``tuned.stale-config`` warning rather than silently ignored.

Everything here is value-independent; a verified plan can still compute
wrong numbers only if the kernels themselves are wrong — which is what
the bitwise dispatch tests (and :mod:`repro.analysis.kernel_lint`) cover.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.schedule import (
    AssemblyMap,
    SpGEMMSchedule,
    build_assembly_map,
    build_compact_map,
    shards_from_bounds,
    shards_to_bounds,
)
from repro.kernels.gustavson_spgemm import pad_schedule_arrays

__all__ = [
    "Finding",
    "PlanVerificationError",
    "VerifyReport",
    "check_compact",
    "verify_plan",
]


@dataclasses.dataclass
class Finding:
    """One verifier finding. ``check`` is a dotted id (e.g.
    ``"schedule.panel-bounds"``); ``severity`` is ``"error"`` (invariant
    violated) or ``"warning"`` (suspicious but not provably wrong)."""

    check: str
    severity: str
    message: str

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{self.severity}] {self.check}: {self.message}"


@dataclasses.dataclass
class VerifyReport:
    """The result of one :func:`verify_plan` pass."""

    plan_kind: str  # "element" | "block"
    sharded: bool
    backend: str
    checks_run: List[str]
    findings: List[Finding]
    elapsed_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def raise_if_failed(self) -> "VerifyReport":
        if not self.ok:
            raise PlanVerificationError(self)
        return self

    def summary(self) -> str:
        status = "ok" if self.ok else f"FAILED ({len(self.errors)} error(s))"
        lines = [
            f"verify_plan: {status} — {len(self.checks_run)} checks, "
            f"{self.elapsed_s * 1e3:.1f} ms "
            f"[{self.plan_kind}{', sharded' if self.sharded else ''}, "
            f"{self.backend}]"
        ]
        lines.extend(f"  {f}" for f in self.findings)
        return "\n".join(lines)


class PlanVerificationError(AssertionError):
    """A plan failed static verification. Carries the full report."""

    def __init__(self, report: VerifyReport):
        self.report = report
        super().__init__(report.summary())


def _err(findings: List[Finding], check: str, message: str) -> None:
    findings.append(Finding(check=check, severity="error", message=message))


def _bounds_check(
    findings: List[Finding], check: str, arr: np.ndarray, lo: int, hi: int,
    what: str,
) -> None:
    """Assert ``lo <= arr < hi`` elementwise, reporting the first offender."""
    arr = np.asarray(arr)
    if arr.size == 0:
        return
    bad = (arr < lo) | (arr >= hi)
    if bad.any():
        i = int(np.argmax(bad))
        _err(findings, check,
             f"{what}[{i}] = {int(arr.flat[i])} outside [{lo}, {hi})")


# ---------------------------------------------------------------------------
# Check families. Each takes the raw symbolic artifacts (never the plan's
# executor or any device array) and appends findings.
# ---------------------------------------------------------------------------


def check_schedule(
    schedule: SpGEMMSchedule,
    nnzb_a: int,
    nnzb_b: int,
    findings: List[Finding],
    label: str = "schedule",
) -> None:
    """Family 1: triple-schedule well-formedness."""
    t = schedule.num_triples
    arrays = {
        "a_slot": schedule.a_slot, "b_slot": schedule.b_slot,
        "panel": schedule.panel, "sub_row": schedule.sub_row,
        "start": schedule.start,
    }
    for name, arr in arrays.items():
        if np.asarray(arr).shape != (t,):
            _err(findings, f"{label}.lengths",
                 f"{name} has shape {np.asarray(arr).shape}, expected ({t},)")
            return  # everything downstream indexes by t
    n_panels = schedule.n_panels
    _bounds_check(findings, f"{label}.a-slot-bounds", schedule.a_slot,
                  0, max(nnzb_a, 1), "a_slot")
    _bounds_check(findings, f"{label}.b-slot-bounds", schedule.b_slot,
                  0, max(nnzb_b, 1), "b_slot")
    _bounds_check(findings, f"{label}.panel-bounds", schedule.panel,
                  0, max(n_panels, 1), "panel")
    _bounds_check(findings, f"{label}.sub-row-bounds", schedule.sub_row,
                  0, max(schedule.group, 1), "sub_row")
    start = np.asarray(schedule.start)
    if start.size and not np.isin(start, (0, 1)).all():
        _err(findings, f"{label}.start-domain",
             "start flags must be 0 or 1")
    if t:
        panel = np.asarray(schedule.panel)
        # Contiguous panel runs: each panel id appears in exactly one run.
        # (This is what lets the Pallas out BlockSpec revisit the panel
        # accumulator in VMEM and write it back exactly once.)
        run_first = np.empty(t, dtype=bool)
        run_first[0] = True
        run_first[1:] = panel[1:] != panel[:-1]
        run_panels = panel[run_first]
        uniq, counts = np.unique(run_panels, return_counts=True)
        if (counts > 1).any():
            p = int(uniq[np.argmax(counts > 1)])
            _err(findings, f"{label}.panel-contiguity",
                 f"panel {p} is visited in {int(counts.max())} separate "
                 f"runs; each output panel must be one contiguous run")
        elif uniq.shape[0] != n_panels:
            _err(findings, f"{label}.panel-coverage",
                 f"{uniq.shape[0]} of {n_panels} panels receive triples; "
                 f"build_spgemm_schedule never emits empty panels")
        # start == 1 exactly on the first triple of each panel run.
        if not np.array_equal(start.astype(bool), run_first):
            i = int(np.argmax(start.astype(bool) != run_first))
            _err(findings, f"{label}.start-flags",
                 f"start[{i}] = {int(start[i])} but triple {i} is "
                 f"{'the first' if run_first[i] else 'not the first'} of "
                 f"its panel run")
    # Panel keys ascending (the searchsorted precondition in
    # build_assembly_map) and in range.
    _bounds_check(findings, f"{label}.panel-group-bounds",
                  schedule.panel_group, 0,
                  max(-(-schedule.grid_m // max(schedule.group, 1)), 1),
                  "panel_group")
    _bounds_check(findings, f"{label}.panel-bcol-bounds",
                  schedule.panel_bcol, 0, max(schedule.grid_n, 1),
                  "panel_bcol")
    pkey = (schedule.panel_group.astype(np.int64) * schedule.grid_n
            + schedule.panel_bcol)
    if pkey.size and (np.diff(pkey) <= 0).any():
        _err(findings, f"{label}.panel-order",
             "panel (group, bcol) keys are not strictly ascending")
    # C block pattern sorted and in range.
    _bounds_check(findings, f"{label}.c-brow-bounds", schedule.c_brow,
                  0, max(schedule.grid_m, 1), "c_brow")
    _bounds_check(findings, f"{label}.c-bcol-bounds", schedule.c_bcol,
                  0, max(schedule.grid_n, 1), "c_bcol")
    ckey = (schedule.c_brow.astype(np.int64) * schedule.grid_n
            + schedule.c_bcol)
    if ckey.size and (np.diff(ckey) <= 0).any():
        _err(findings, f"{label}.c-block-order",
             "C block (brow, bcol) keys are not strictly ascending")


def check_assembly(
    schedule: SpGEMMSchedule,
    assembly: AssemblyMap,
    block_shape: Tuple[int, int],
    findings: List[Finding],
    label: str = "assembly",
) -> None:
    """Families 2+3: pad panel never gathered; structural coverage exact."""
    bm, bn = block_shape
    m, n = assembly.shape
    g = schedule.group
    indptr = np.asarray(assembly.indptr)
    indices = np.asarray(assembly.indices)
    gather = np.asarray(assembly.gather)
    nnz = assembly.nnz
    if indptr.shape != (m + 1,):
        _err(findings, f"{label}.indptr-shape",
             f"indptr shape {indptr.shape}, expected ({m + 1},)")
        return
    if indptr.size and int(indptr[0]) != 0:
        _err(findings, f"{label}.indptr-origin",
             f"indptr[0] = {int(indptr[0])}, expected 0")
    if (np.diff(indptr) < 0).any():
        i = int(np.argmax(np.diff(indptr) < 0))
        _err(findings, f"{label}.indptr-monotone",
             f"indptr decreases at row {i}")
    elif int(indptr[-1]) != nnz:
        _err(findings, f"{label}.indptr-total",
             f"indptr[-1] = {int(indptr[-1])} != nnz {nnz}")
    if gather.shape != (nnz,):
        _err(findings, f"{label}.gather-shape",
             f"gather shape {gather.shape}, expected ({nnz},)")
        return
    _bounds_check(findings, f"{label}.indices-bounds", indices, 0,
                  max(n, 1), "indices")
    # Columns strictly ascending within each row (canonical CSR — results
    # share these arrays, so duplicates would silently alias C entries).
    if nnz and (np.diff(indptr) >= 0).all() and int(indptr[-1]) == nnz:
        row_of = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        key = row_of * (int(n) + 1) + indices.astype(np.int64)
        if (np.diff(key) <= 0).any():
            i = int(np.argmax(np.diff(key) <= 0))
            _err(findings, f"{label}.column-order",
                 f"columns not strictly ascending within row "
                 f"{int(row_of[i])} (nnz position {i})")
    # Pad-panel discipline: the flat gather space is the *real* panels
    # only. Any index >= n_panels*g*bm*bn reads the dummy pad panel the
    # kernel wrapper appends (single grid) — or, in the batch-folded grid
    # with per-element stride n_panels+1, another element's panels.
    flat = schedule.n_panels * g * bm * bn
    bad = (gather < 0) | (gather >= max(flat, 1))
    if bad.any():
        i = int(np.argmax(bad))
        _err(findings, f"{label}.pad-panel-read",
             f"gather[{i}] = {int(gather[i])} outside the real panel "
             f"space [0, {flat}): it reads the write-only dummy pad panel")
    elif nnz:
        # Exactly-once: every structural C nnz has a distinct source slot.
        uniq = np.unique(gather)
        if uniq.shape[0] != nnz:
            _err(findings, f"{label}.gather-duplicate",
                 f"{nnz - uniq.shape[0]} duplicated gather index(es): two "
                 f"C entries read the same panel slot")
    # Structural coverage: nnz must equal the schedule's C block pattern
    # trimmed to the true shape (ceil-padded edge blocks overhang).
    rows_in = np.clip(m - schedule.c_brow.astype(np.int64) * bm, 0, bm)
    cols_in = np.clip(n - schedule.c_bcol.astype(np.int64) * bn, 0, bn)
    expect = int((rows_in * cols_in).sum())
    if nnz != expect:
        _err(findings, f"{label}.coverage",
             f"assembly holds {nnz} structural nnz, schedule implies "
             f"{expect}")


def check_batch_races(
    schedule: SpGEMMSchedule,
    findings: List[Finding],
    bsz: int = 2,
    label: str = "races.batch",
) -> None:
    """Family 4 (batch-folded grid): prove single-writer per output slot.

    Reconstructs the padded schedule exactly as
    :func:`~repro.kernels.gustavson_spgemm.pad_schedule_arrays` does and
    evaluates the batch grid's out index map
    ``slot = b * (n_panels + 1) + panel[t]`` over every grid step. The
    batch axis is race-free — and therefore safely declared
    ``"parallel"`` — iff slots of distinct ``b`` never collide, which
    holds exactly when every padded panel id sits in ``[0, n_panels]``.
    The triple axis must stay ``"arbitrary"``: within one element, a
    panel slot *is* revisited, legally, by one contiguous run of steps.
    """
    n_panels = schedule.n_panels
    a_slot, b_slot, panel, sub_row, start, t_pad = pad_schedule_arrays(
        schedule.a_slot, schedule.b_slot, schedule.panel,
        schedule.sub_row, schedule.start, n_panels,
    )
    stride = n_panels + 1
    _bounds_check(findings, f"{label}.padded-panel-bounds", panel, 0,
                  stride, "padded panel")
    if findings and findings[-1].check == f"{label}.padded-panel-bounds":
        return
    # Explicit slot map over the full (bsz, t_pad) grid: distinct batch
    # elements must write disjoint slot sets, and one slot's writers must
    # be contiguous in t (the revisit-run condition the single-writer
    # argument reduces to under sequential-innermost iteration).
    b_of = np.repeat(np.arange(bsz, dtype=np.int64), t_pad)
    t_of = np.tile(np.arange(t_pad, dtype=np.int64), bsz)
    slot = b_of * stride + panel[t_of].astype(np.int64)
    order = np.lexsort((t_of, slot))
    slot_s, b_s, t_s = slot[order], b_of[order], t_of[order]
    same = np.zeros(slot_s.shape[0], dtype=bool)
    same[1:] = slot_s[1:] == slot_s[:-1]
    if same.any():
        cross = same & (b_s != np.roll(b_s, 1))
        if cross.any():
            i = int(np.argmax(cross))
            _err(findings, f"{label}.cross-element",
                 f"output slot {int(slot_s[i])} written by batch elements "
                 f"{int(b_s[i - 1])} and {int(b_s[i])}: the batch axis is "
                 f"NOT race-free")
        gap = same & (t_s != np.roll(t_s, 1) + 1)
        # Pad triples all target one dummy slot per element with start=1
        # (each write begins by zeroing), so non-contiguity there is safe;
        # real panels must still be single contiguous runs.
        real = (slot_s % stride) < n_panels
        if (gap & real).any():
            i = int(np.argmax(gap & real))
            _err(findings, f"{label}.revisit-gap",
                 f"slot {int(slot_s[i])} revisited non-contiguously at "
                 f"grid steps t={int(t_s[i - 1])} and t={int(t_s[i])}")


def check_stacked_shards(
    shards,
    findings: List[Finding],
    label: str = "races.shards",
) -> None:
    """Family 4 (stacked shard schedules): the ``[n_shards, t_max]``
    constants from :func:`~repro.core.schedule.stack_shard_schedules` keep
    each shard's writes inside its own ``p_max + 1``-panel buffer, with
    pads confined to the write-only dummy panel ``p_max``."""
    from repro.core.schedule import stack_shard_schedules

    if not shards:
        return
    t_max = max(1, max(s.num_triples for s in shards))
    p_max = max(1, max(s.n_panels for s in shards))
    _, _, panel, _, start = stack_shard_schedules(shards, t_max, p_max)
    for i, sh in enumerate(shards):
        t = sh.num_triples
        row = panel[i]
        if (row[t:] != p_max).any():
            _err(findings, f"{label}.pad-target",
                 f"shard {i}: pad triples target panel(s) other than the "
                 f"dummy {p_max}")
        if (start[i, t:] != 1).any():
            _err(findings, f"{label}.pad-start",
                 f"shard {i}: pad triples missing start=1 (accumulator "
                 f"would carry garbage)")
        _bounds_check(findings, f"{label}.real-panel-bounds", row[:t], 0,
                      max(sh.n_panels, 1), f"shard {i} panel")
        # Shard-local gathers must never read past the shard's own real
        # panels (the stacked buffer is p_max+1 panels; slots in
        # [n_panels, p_max] are scratch, p_max the shared dummy).


def check_shard_partition(
    plan,
    findings: List[Finding],
    label: str = "shards",
) -> None:
    """Family 5: partition exactness + bitwise reconstruction."""
    shards = plan._shards
    schedule: SpGEMMSchedule = plan.schedule
    if not shards:
        return
    g = schedule.group
    n_groups = -(-schedule.grid_m // g) if schedule.grid_m else 0
    # Disjoint + contiguous + covering group ranges.
    if shards[0].group_lo != 0:
        _err(findings, f"{label}.origin",
             f"first shard starts at group {shards[0].group_lo}, not 0")
    for i in range(len(shards) - 1):
        if shards[i].group_hi != shards[i + 1].group_lo:
            _err(findings, f"{label}.contiguity",
                 f"shard {i} ends at group {shards[i].group_hi} but shard "
                 f"{i + 1} starts at {shards[i + 1].group_lo}: ranges "
                 f"must tile [0, n_groups) disjointly")
    if schedule.num_triples and shards[-1].group_hi != n_groups:
        _err(findings, f"{label}.coverage",
             f"shards cover [0, {shards[-1].group_hi}) but the schedule "
             f"has exactly {n_groups} groups (under- and over-coverage "
             f"are both partition violations)")
    # Triple/panel/A spans tile the parent arrays.
    for name, lo_f, hi_f, total in (
        ("triple", "triple_lo", "triple_hi", schedule.num_triples),
        ("panel", "panel_lo", "panel_hi", schedule.n_panels),
    ):
        pos = 0
        for i, sh in enumerate(shards):
            lo, hi = getattr(sh, lo_f), getattr(sh, hi_f)
            if lo != pos or hi < lo:
                _err(findings, f"{label}.{name}-span",
                     f"shard {i} {name} span [{lo}, {hi}) does not "
                     f"continue at {pos}")
                return
            pos = hi
        if pos != total:
            _err(findings, f"{label}.{name}-span",
                 f"shard {name} spans cover {pos} of {total}")
    # Bitwise reconstruction from the serialized bounds vector — the
    # exact round trip persistence relies on.
    bounds = shards_to_bounds(shards)
    try:
        rebuilt = shards_from_bounds(schedule, bounds)
    except ValueError as e:
        _err(findings, f"{label}.bounds", f"bounds rejected: {e}")
        return
    for i, (sh, rb) in enumerate(zip(shards, rebuilt)):
        for f in ("group_lo", "group_hi", "triple_lo", "triple_hi",
                  "panel_lo", "panel_hi", "a_lo", "a_hi"):
            if getattr(sh, f) != getattr(rb, f):
                _err(findings, f"{label}.rebase",
                     f"shard {i}.{f}: stored {getattr(sh, f)} != "
                     f"rebuilt {getattr(rb, f)}")
        for f in ("a_slot", "b_slot", "panel", "sub_row", "start",
                  "panel_group", "panel_bcol", "c_brow", "c_bcol"):
            a = np.asarray(getattr(sh.schedule, f))
            b = np.asarray(getattr(rb.schedule, f))
            if a.shape != b.shape or a.dtype != b.dtype \
                    or not np.array_equal(a, b):
                _err(findings, f"{label}.rebase",
                     f"shard {i} local schedule field {f!r} differs from "
                     f"its bitwise reconstruction")
                break
    # Per-shard assembly slices concatenate to the plan assembly.
    asms = plan._shard_assemblies
    if asms:
        if sum(a.nnz for a in asms) != plan.assembly.nnz:
            _err(findings, f"{label}.assembly-cover",
                 f"shard assemblies hold "
                 f"{sum(a.nnz for a in asms)} nnz, plan assembly "
                 f"{plan.assembly.nnz}")
        else:
            cat = np.concatenate(
                [np.asarray(a.indices) for a in asms]
            ) if plan.assembly.nnz else np.asarray(plan.assembly.indices)
            if not np.array_equal(cat, np.asarray(plan.assembly.indices)):
                _err(findings, f"{label}.assembly-concat",
                     "concatenated shard CSR columns differ from the "
                     "plan-wide assembly")
        for i, (sh, asm) in enumerate(zip(shards, asms)):
            flat = sh.n_panels * g * plan._bm * plan._bn
            gth = np.asarray(asm.gather)
            if gth.size and (int(gth.max()) >= max(flat, 1)
                             or int(gth.min()) < 0):
                _err(findings, f"{label}.gather-bounds",
                     f"shard {i} gather reads outside its {sh.n_panels} "
                     f"real panels (flat space {flat})")


def check_compact(
    plan,
    findings: List[Finding],
    label: str = "compact",
) -> None:
    """Family 6: the compacted nnz-exact output map.

    The compact map reuses the exactly-once coverage proof of the block
    assembly (family 3): it must be a canonical CSR whose gather is a
    duplicate-free *subset* of the block gather. Combined with the block
    map's pad-panel and exactly-once checks, that proves every compacted
    C element reads exactly one kernel output slot and no slot feeds two
    elements.
    """
    assembly: AssemblyMap = plan.assembly
    compact: AssemblyMap = plan.compact
    m, n = compact.shape
    indptr = np.asarray(compact.indptr)
    indices = np.asarray(compact.indices)
    gather = np.asarray(compact.gather)
    nnz = compact.nnz
    if tuple(compact.shape) != tuple(assembly.shape):
        _err(findings, f"{label}.shape",
             f"compact shape {compact.shape} != assembly {assembly.shape}")
        return
    if indptr.shape != (m + 1,):
        _err(findings, f"{label}.indptr-shape",
             f"indptr shape {indptr.shape}, expected ({m + 1},)")
        return
    if indptr.size and int(indptr[0]) != 0:
        _err(findings, f"{label}.indptr-origin",
             f"indptr[0] = {int(indptr[0])}, expected 0")
    if (np.diff(indptr) < 0).any():
        i = int(np.argmax(np.diff(indptr) < 0))
        _err(findings, f"{label}.indptr-monotone",
             f"indptr decreases at row {i}")
    elif int(indptr[-1]) != nnz:
        _err(findings, f"{label}.indptr-total",
             f"indptr[-1] = {int(indptr[-1])} != nnz {nnz}")
    if gather.shape != (nnz,):
        _err(findings, f"{label}.gather-shape",
             f"gather shape {gather.shape}, expected ({nnz},)")
        return
    _bounds_check(findings, f"{label}.indices-bounds", indices, 0,
                  max(n, 1), "indices")
    if nnz > assembly.nnz:
        _err(findings, f"{label}.size",
             f"compact map holds {nnz} nnz, more than the {assembly.nnz} "
             f"block-structural slots it selects from")
    if nnz and (np.diff(indptr) >= 0).all() and int(indptr[-1]) == nnz:
        row_of = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        key = row_of * (int(n) + 1) + indices.astype(np.int64)
        if (np.diff(key) <= 0).any():
            i = int(np.argmax(np.diff(key) <= 0))
            _err(findings, f"{label}.column-order",
                 f"columns not strictly ascending within row "
                 f"{int(row_of[i])} (nnz position {i})")
    if nnz:
        # Exactly-once, inherited: subset of the block gather space...
        if not np.isin(gather, np.asarray(assembly.gather)).all():
            _err(findings, f"{label}.subset",
                 "compact gather reads slot(s) outside the block "
                 "assembly's gather space")
        # ...with no slot feeding two compacted elements.
        uniq = np.unique(gather)
        if uniq.shape[0] != nnz:
            _err(findings, f"{label}.gather-duplicate",
                 f"{nnz - uniq.shape[0]} duplicated gather index(es): two "
                 f"compacted C entries read the same panel slot")
    # Bitwise re-derivation from the block assembly + the compact pattern
    # itself — the compact analogue of assembly.rebuild.
    if not any(f.severity == "error" and f.check.startswith(label)
               for f in findings):
        rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(indptr))
        try:
            fresh = build_compact_map(assembly, rows, indices)
        except Exception as e:  # noqa: BLE001 - any failure is a finding
            _err(findings, f"{label}.rebuild",
                 f"compact map not re-derivable from the block assembly: "
                 f"{type(e).__name__}: {e}")
            return
        for f in ("gather", "indptr", "indices"):
            a = np.asarray(getattr(compact, f))
            b = np.asarray(getattr(fresh, f))
            if a.shape != b.shape or not np.array_equal(a, b):
                _err(findings, f"{label}.rebuild",
                     f"stored compact {f!r} differs from its re-derived "
                     f"map")
                return
    # Sharded plans slice the compact map per shard; the slices must
    # exactly tile it (the executor's packed-value layout depends on it).
    shard_compacts = getattr(plan, "_shard_compacts", None)
    if shard_compacts:
        if sum(a.nnz for a in shard_compacts) != nnz:
            _err(findings, f"{label}.shard-cover",
                 f"shard compact maps hold "
                 f"{sum(a.nnz for a in shard_compacts)} nnz, plan compact "
                 f"{nnz}")
        elif nnz:
            cat = np.concatenate(
                [np.asarray(a.indices) for a in shard_compacts]
            )
            if not np.array_equal(cat, indices):
                _err(findings, f"{label}.shard-concat",
                     "concatenated shard compact columns differ from the "
                     "plan-wide compact map")


def _rebuild_cross_check(plan, findings: List[Finding]) -> None:
    """Re-derive the assembly map from the plan's own schedule and compare
    bitwise — the strongest corruption detector for persisted artifacts
    (a digest-valid file whose arrays were *consistently* rewritten still
    cannot match an independent re-derivation)."""
    try:
        fresh = build_assembly_map(
            plan.schedule, (plan._bm, plan._bn), (plan._m, plan._n)
        )
    except Exception as e:  # noqa: BLE001 - any failure is a finding
        _err(findings, "assembly.rebuild",
             f"assembly map not re-derivable from the schedule: "
             f"{type(e).__name__}: {e}")
        return
    for f in ("gather", "indptr", "indices"):
        a = np.asarray(getattr(plan.assembly, f))
        b = np.asarray(getattr(fresh, f))
        if a.shape != b.shape or not np.array_equal(a, b):
            _err(findings, "assembly.rebuild",
                 f"stored assembly {f!r} differs from the schedule's "
                 f"re-derived map")
            return
    if tuple(plan.assembly.shape) != tuple(fresh.shape):
        _err(findings, "assembly.rebuild",
             f"stored assembly shape {plan.assembly.shape} != re-derived "
             f"{fresh.shape}")


def verify_plan(
    plan,
    *,
    batch_sizes: Tuple[int, ...] = (2, 3),
    rebuild_check: bool = True,
) -> VerifyReport:
    """Statically verify one plan. Returns a :class:`VerifyReport`;
    ``report.raise_if_failed()`` raises :class:`PlanVerificationError`.

    ``batch_sizes`` are the symbolic batch widths the race check runs at
    (disjointness is stride-structural, so two small sizes suffice).
    ``rebuild_check=False`` skips the full assembly re-derivation (the
    one check whose cost is O(symbolic build); everything else is a few
    linear passes over the schedule arrays).
    """
    t0 = time.perf_counter()
    findings: List[Finding] = []
    checks = [
        "schedule", "assembly", "races.batch",
    ]
    schedule: SpGEMMSchedule = plan.schedule
    nnzb_a = int(plan._a_shape[0]) if len(plan._a_shape) == 3 else 0
    nnzb_b = int(plan._b_shape[0]) if len(plan._b_shape) == 3 else 0
    check_schedule(schedule, nnzb_a, nnzb_b, findings)
    check_assembly(schedule, plan.assembly, (plan._bm, plan._bn), findings)
    for bsz in batch_sizes:
        check_batch_races(schedule, findings, bsz=bsz)
    if getattr(plan, "compact", None) is not None:
        checks.append("compact")
        check_compact(plan, findings)
    if rebuild_check:
        checks.append("assembly.rebuild")
        _rebuild_cross_check(plan, findings)
    # Configuration provenance: a tuned config that no longer matches the
    # plan's symbolic facts was ignored at apply time — surface it.
    stale = getattr(plan, "_stale_tuned", None)
    if stale is not None:
        checks.append("tuned")
        findings.append(Finding(
            check="tuned.stale-config",
            severity="warning",
            message=(
                f"persisted tuned config {stale!r} no longer matches the "
                f"plan's symbolic facts; it was ignored and the plan runs "
                f"with config_source="
                f"{plan.report.config_source!r} (re-run the autotuner to "
                f"refresh the sidecar)"
            ),
        ))
    sharded = hasattr(plan, "_shards") and getattr(plan, "n_shards", 0) > 0
    if sharded:
        checks += ["shards", "races.shards"]
        check_shard_partition(plan, findings)
        check_stacked_shards(plan._shards, findings)
        for i, sh in enumerate(plan._shards):
            if sh.num_triples:
                check_schedule(
                    sh.schedule, sh.a_hi - sh.a_lo, nnzb_b, findings,
                    label=f"shard{i}.schedule",
                )
    element = getattr(plan, "_a_scatter", None) is not None \
        and getattr(plan, "_b_scatter", None) is not None
    return VerifyReport(
        plan_kind="element" if element else "block",
        sharded=bool(sharded),
        backend=getattr(plan, "backend", "?"),
        checks_run=checks,
        findings=findings,
        elapsed_s=time.perf_counter() - t0,
    )
