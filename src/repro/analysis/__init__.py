"""Static analysis over SpGEMM plans, kernels, and concurrency.

The plan/execute stack's correctness rests on invariants that the test
suite only witnesses indirectly (bitwise end-to-end equality). This
package checks them *statically* — no numeric execution, no device work:

* :mod:`repro.analysis.verify` — :func:`~repro.analysis.verify.verify_plan`:
  schedule well-formedness, dummy-pad-panel write-only discipline,
  assembly coverage (every structural C nnz gathered exactly once),
  write-write race freedom of the batch-folded and stacked-shard grids,
  and shard-partition exactness (bitwise reconstruction from bounds).
* :mod:`repro.analysis.kernel_lint` — a lint over the ``pallas_call``
  specs in ``repro.kernels.gustavson_spgemm``: block-shape/grid
  consistency, index maps statically in bounds, fp32 accumulation, and
  ``dimension_semantics`` consistent with the proven race freedom.
* :mod:`repro.analysis.locks` — instrumented lock wrappers recording the
  lock-acquisition graph of the serving stack (gateway/pipeline/cache/
  plan/persist) and failing on cycles (lock-order deadlock lint).
* :mod:`repro.analysis.check` — the CLI:
  ``python -m repro.analysis.check --paper-matrices [--shards N]``.

Opt-in deep validation is wired into the plan API as
``spgemm_plan(..., validate="deep")``: fresh builds are verified before
they are returned, and disk rehydrates are verified *inside* the loader,
so a corrupted-but-digest-valid artifact fails verification (and falls
back to a clean symbolic rebuild) instead of executing.
"""
from repro.analysis.verify import (
    Finding,
    PlanVerificationError,
    VerifyReport,
    verify_plan,
)
from repro.analysis.kernel_lint import lint_kernel_module, lint_plan_kernel_specs
from repro.analysis.locks import LockOrderMonitor, instrument_spgemm_locks

__all__ = [
    "Finding",
    "LockOrderMonitor",
    "PlanVerificationError",
    "VerifyReport",
    "instrument_spgemm_locks",
    "lint_kernel_module",
    "lint_plan_kernel_specs",
    "verify_plan",
]
