"""Static lint over the Pallas kernel specs in
``repro.kernels.gustavson_spgemm``.

Two layers, both execution-free:

* :func:`lint_kernel_module` — an AST pass over the kernel module's
  source: the accumulation dtype must be fp32 everywhere (the
  ``preferred_element_type`` of the MXU dot and both ``out_shape``
  dtypes), and the declared ``dimension_semantics`` must match what the
  verifier proves — the triple axis is ``"arbitrary"`` (panels are
  revisited by contiguous runs of steps, a sequential dependence), the
  batch axis ``"parallel"`` (distinct elements write disjoint
  ``n_panels + 1``-strided slot ranges; see
  :func:`repro.analysis.verify.check_batch_races`).
* :func:`lint_plan_kernel_specs` — given a built plan, evaluate the
  ``BlockSpec`` index maps over **every** grid coordinate with the actual
  prefetch arrays (pure numpy, mirroring the lambdas in
  ``spgemm_scheduled_impl`` / ``spgemm_scheduled_batch_impl``) and check
  each block index stays inside its operand, block shapes tile the
  operand shapes exactly, the grid sizes match the padded schedule, and
  the per-grid-step VMEM working set
  (:func:`repro.core.perfmodel.spgemm_grid_step_vmem`: one A block, one
  B block, one ``group*bm x bn`` output panel, double-buffered) fits the
  :data:`repro.core.perfmodel.TPU_VMEM_BYTES` budget — an oversized
  (tile, group) is a lint finding *before* any compile attempt.

The module lint pins the *source*; the plan lint pins the *instance* —
together they are the static half of the "Pallas on every numeric path"
contract that the bitwise dispatch tests check dynamically.
"""
from __future__ import annotations

import ast
import inspect
from typing import List, Optional, Tuple

import numpy as np

from repro.analysis.verify import Finding, _bounds_check, _err

__all__ = ["lint_kernel_module", "lint_plan_kernel_specs"]

# The proven-safe semantics per grid (see module docstring).
EXPECTED_SEMANTICS = {
    "spgemm_scheduled_impl": ("arbitrary",),
    "spgemm_scheduled_batch_impl": ("parallel", "arbitrary"),
}


def _kernel_module_tree():
    from repro.kernels import gustavson_spgemm

    return ast.parse(inspect.getsource(gustavson_spgemm)), gustavson_spgemm


def _tuple_of_constants(node: ast.AST) -> Optional[Tuple]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not isinstance(elt, ast.Constant):
                return None
            out.append(elt.value)
        return tuple(out)
    return None


def _find_semantics(fn: ast.FunctionDef) -> Optional[Tuple]:
    """The ``dimension_semantics=`` tuple inside one impl function."""
    for node in ast.walk(fn):
        if isinstance(node, ast.keyword) and node.arg == "dimension_semantics":
            return _tuple_of_constants(node.value)
    return None


def _dotted(node: ast.AST) -> str:
    """'jnp.float32' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def lint_kernel_module() -> List[Finding]:
    """AST lint of ``repro.kernels.gustavson_spgemm`` (see module doc)."""
    findings: List[Finding] = []
    tree, _ = _kernel_module_tree()
    fns = {
        n.name: n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)
    }
    # dimension_semantics must match the race-freedom proof.
    for name, expect in EXPECTED_SEMANTICS.items():
        fn = fns.get(name)
        if fn is None:
            _err(findings, "kernel.semantics",
                 f"kernel impl {name} not found in module source")
            continue
        got = _find_semantics(fn)
        if got != expect:
            _err(findings, "kernel.semantics",
                 f"{name} declares dimension_semantics={got!r}, the "
                 f"verifier's race analysis supports exactly {expect!r}")
    # fp32 accumulation: the MXU dot's preferred_element_type ...
    kern = fns.get("_kernel")
    if kern is None:
        _err(findings, "kernel.accum-dtype", "_kernel not found")
    else:
        pref = None
        for node in ast.walk(kern):
            if isinstance(node, ast.keyword) \
                    and node.arg == "preferred_element_type":
                pref = _dotted(node.value)
        if pref != "jnp.float32":
            _err(findings, "kernel.accum-dtype",
                 f"_kernel dot preferred_element_type is {pref!r}, "
                 f"expected jnp.float32")
    # ... and both pallas_call out_shape dtypes.
    for name in EXPECTED_SEMANTICS:
        fn = fns.get(name)
        if fn is None:
            continue
        out_dtype = None
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and _dotted(node.func) == "jax.ShapeDtypeStruct"
                    and len(node.args) >= 2):
                out_dtype = _dotted(node.args[1])
        if out_dtype != "jnp.float32":
            _err(findings, "kernel.accum-dtype",
                 f"{name} out_shape dtype is {out_dtype!r}, expected "
                 f"jnp.float32 (fp32 accumulation)")
    return findings


def _pad_for(plan):
    from repro.kernels.gustavson_spgemm import pad_schedule_arrays

    s = plan.schedule
    return pad_schedule_arrays(
        s.a_slot, s.b_slot, s.panel, s.sub_row, s.start, s.n_panels
    )


def lint_plan_kernel_specs(plan, bsz: int = 2) -> List[Finding]:
    """Evaluate the kernel grids' BlockSpec index maps for ``plan`` over
    every grid coordinate (numpy mirror of the lambdas) and check
    in-boundedness + exact block tiling. ``bsz`` is the symbolic batch
    width for the batch-folded grid."""
    findings: List[Finding] = []
    nnzb_a = int(plan._a_shape[0]) if len(plan._a_shape) == 3 else 0
    nnzb_b = int(plan._b_shape[0]) if len(plan._b_shape) == 3 else 0
    if not plan.schedule.num_triples or not nnzb_a or not nnzb_b:
        return findings  # empty plan: no kernel is ever launched
    bm, bk = int(plan._a_shape[1]), int(plan._a_shape[2])
    bn = int(plan._b_shape[2])
    n_panels = plan.schedule.n_panels
    group = plan._group
    # VMEM budget: the per-grid-step resident set (A block + B block +
    # output panel, double-buffered by the Pallas pipeline) must fit
    # per-core VMEM. An oversized config fails at compile time at best
    # and silently spills at worst — catch it here, statically.
    from repro.core.perfmodel import TPU_VMEM_BYTES, spgemm_grid_step_vmem

    dtype_bytes = int(np.dtype(np.float32).itemsize)
    step_bytes = spgemm_grid_step_vmem(
        tile=(bm, bk, bn), group=group, dtype_bytes=dtype_bytes
    )
    if step_bytes > TPU_VMEM_BYTES:
        _err(findings, "kernel.vmem-working-set",
             f"per-grid-step VMEM working set "
             f"{int(step_bytes)} B (tile=({bm}, {bk}, {bn}), "
             f"group={group}, double-buffered) exceeds the "
             f"{TPU_VMEM_BYTES} B per-core budget; shrink tile or group")
    # Block shapes must tile the packed operand arrays exactly: the specs
    # use (1, bm, bk) / (1, bk, bn) / (1, group*bm, bn) blocks, so the
    # trailing operand dims must equal the block dims (divisibility with
    # quotient 1 — anything else would silently stride into neighbors).
    if tuple(plan._a_shape[1:]) != (bm, bk):
        _err(findings, "kernel.block-shape",
             f"A blocks {plan._a_shape} not tiled by (1, {bm}, {bk})")
    if tuple(plan._b_shape[1:]) != (bk, bn):
        _err(findings, "kernel.block-shape",
             f"B blocks {plan._b_shape} not tiled by (1, {bk}, {bn})")
    a_slot, b_slot, panel, sub_row, start, t_pad = _pad_for(plan)
    t = np.arange(t_pad)
    # Single grid (t_pad,): index maps t -> (a_s[t],·,·) etc., out panel
    # space n_panels + 1 (the appended dummy).
    _bounds_check(findings, "kernel.index-map.single", a_slot[t], 0,
                  nnzb_a, "a index")
    _bounds_check(findings, "kernel.index-map.single", b_slot[t], 0,
                  nnzb_b, "b index")
    _bounds_check(findings, "kernel.index-map.single", panel[t], 0,
                  n_panels + 1, "out panel index")
    _bounds_check(findings, "kernel.index-map.single",
                  sub_row[t] * bm + (bm - 1), 0, group * bm,
                  "panel row window")
    # Batch grid (bsz, t_pad): per-element offsets into the stacked
    # operands and the (n_panels + 1)-strided output.
    stride = n_panels + 1
    b = np.repeat(np.arange(bsz), t_pad)
    tt = np.tile(t, bsz)
    _bounds_check(findings, "kernel.index-map.batch",
                  b * nnzb_a + a_slot[tt], 0, bsz * nnzb_a, "a index")
    _bounds_check(findings, "kernel.index-map.batch",
                  b * nnzb_b + b_slot[tt], 0, bsz * nnzb_b, "b index")
    _bounds_check(findings, "kernel.index-map.batch",
                  b * stride + panel[tt], 0, bsz * stride,
                  "out panel index")
    return findings
