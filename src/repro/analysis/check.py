"""Static-analysis CLI: verify paper-matrix plans without executing them.

    PYTHONPATH=src python -m repro.analysis.check --paper-matrices [--shards N]

For each paper matrix (``repro.configs.paper_matrices``) the driver
builds — in an isolated :class:`~repro.spgemm.cache.PlanCache` — an
element plan, a block plan, an optionally sharded plan, and a
disk-rehydrated plan, and runs :func:`repro.analysis.verify.verify_plan`
plus the kernel-spec lint on each. ``--lock-lint`` additionally runs a
scripted gateway/pipeline workload under the lock-order instrumentation
(:mod:`repro.analysis.locks`) and fails on acquisition-graph cycles.
``--store DIR`` (or ``REPRO_SPGEMM_PLAN_DIR``) audits the on-disk
:class:`~repro.spgemm.persist.PlanStore` — orphaned ``tokens.index.json``
aliases are reported and pruned.

Exit status is nonzero if any verification, lint, or audit fails, so CI
can gate on it directly (the ``spgemm-verify`` job).

``--shards N`` with more shards than visible devices re-executes itself
with ``--xla_force_host_platform_device_count`` when jax has not been
imported yet — the same forced-host-device convention as the sharded
test jobs.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

__all__ = ["main"]


def _ensure_devices(n: int) -> None:
    """Force ``n`` visible host devices.

    jax reads ``XLA_FLAGS`` at backend initialization (lazily, at the
    first device query), so setting the env var here normally suffices
    even though ``repro`` imports jax at module load. If the backend is
    somehow already initialized with fewer devices, re-exec once with
    the flag exported (the flag's presence in the inherited env stops a
    second re-exec)."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )
    import jax

    if len(jax.devices()) < n:
        os.execv(sys.executable,
                 [sys.executable, "-m", "repro.analysis.check",
                  *sys.argv[1:]])


def _operands(name: str, scale: float):
    from repro.sparse.formats import COO
    from repro.sparse.random import suite_matrix

    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
    return a, b


def _verify_one(plan, label: str, failures: list) -> None:
    from repro.analysis.kernel_lint import lint_plan_kernel_specs
    from repro.analysis.verify import verify_plan

    rep = verify_plan(plan)
    lint = lint_plan_kernel_specs(plan)
    bad = [f for f in lint if f.severity == "error"]
    ok = rep.ok and not bad
    print(f"  {label:<28} "
          f"{'ok' if ok else 'FAILED':<7} "
          f"({len(rep.checks_run)} checks, {rep.elapsed_s * 1e3:6.1f} ms, "
          f"t={plan.report.num_triples}, nnz_c={plan.assembly.nnz})")
    for f in rep.findings + lint:
        print(f"    {f}")
    if not ok:
        failures.append(f"{label}: verification failed")


def _check_matrix(name: str, scale: float, shards: int, backend: str,
                  failures: list) -> None:
    import jax

    from repro.spgemm import PlanCache, spgemm_plan
    from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo

    print(f"\n== {name} (scale={scale}) " + "=" * max(1, 40 - len(name)))
    a, b = _operands(name, scale)
    tile, group = 16, 2
    with tempfile.TemporaryDirectory() as tmp:
        cache = PlanCache(disk_dir=tmp)
        plan = spgemm_plan(a, b, tile=tile, group=group, backend=backend,
                           cache=cache, validate="deep")
        _verify_one(plan, "element", failures)
        a_bcsv, _ = bcsv_from_coo(a, (tile, tile), group)
        b_bcsr, _ = bcsr_from_coo(b, (tile, tile))
        bplan = spgemm_plan(a_bcsv, b_bcsr, backend=backend, cache=cache,
                            validate="deep")
        _verify_one(bplan, "block", failures)
        if shards > 1:
            from repro.launch.mesh import make_shard_mesh

            if len(jax.devices()) < shards:
                failures.append(
                    f"{name}: {shards} shards requested but only "
                    f"{len(jax.devices())} devices visible"
                )
            else:
                splan = spgemm_plan(
                    a, b, tile=tile, group=group, backend=backend,
                    cache=cache, mesh=make_shard_mesh(shards),
                    validate="deep",
                )
                _verify_one(splan, f"sharded x{shards}", failures)
        # Warm-restart path: a fresh cache over the same store directory
        # must rehydrate from disk (no symbolic rebuild) and still verify.
        cache2 = PlanCache(disk_dir=tmp)
        rplan = spgemm_plan(a, b, tile=tile, group=group, backend=backend,
                            cache=cache2, validate="deep")
        if rplan.report.load_hits < 1:
            failures.append(f"{name}: rehydrated plan did not load from disk")
        _verify_one(rplan, "rehydrated", failures)


def _lock_lint(failures: list) -> None:
    """Scripted serving workload under lock instrumentation.

    Multi-pattern by design: with a single registered pattern the
    dispatcher only ever interleaves one pipeline's locks with the
    gateway's, so the cross-pattern edges (dispatcher draining pattern
    p0 while the collector retires pattern p1, both touching the shared
    queue/stats locks) never enter the acquisition graph. Three patterns
    submitted concurrently from separate threads — at ``max_pipelines=2``
    so at least one pair *must* contend for a pipeline slot — exercise
    exactly those edges before ``mon.check()`` looks for cycles.
    """
    import threading

    import numpy as np

    from repro.analysis.locks import LockOrderError, instrument_spgemm_locks

    print("\n== lock-order lint " + "=" * 40)
    with instrument_spgemm_locks() as mon:
        # Import inside the instrumented scope is not needed (locks are
        # created at *object* construction) — build the stack fresh here.
        from repro.spgemm.gateway import SpGEMMGateway

        specs = [
            ("lint/p0", _operands("poisson3Da", 0.01)),
            ("lint/p1", _operands("2cubes_sphere", 0.002)),
            ("lint/p2", _operands("scircuit", 0.002)),
        ]
        gw = SpGEMMGateway(max_pipelines=2, depth=2, max_batch=4)
        plans = {
            name: gw.register(name, a, b, tile=16, group=2, backend="jnp")
            for name, (a, b) in specs
        }
        tickets: list = []
        tickets_lock = threading.Lock()

        def drive(name: str, seed: int) -> None:
            wa, wb = plans[name].value_shapes()
            rng = np.random.default_rng(seed)
            for _ in range(4):
                t = gw.submit(
                    name,
                    rng.standard_normal(wa).astype(np.float32),
                    rng.standard_normal(wb).astype(np.float32),
                )
                with tickets_lock:
                    tickets.append(t)

        threads = [
            threading.Thread(target=drive, args=(name, i))
            for i, (name, _) in enumerate(specs)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for t in tickets:
            t.wait(timeout=120)
        gw.close()
    edges = mon.edges()
    n_edges = sum(len(v) for v in edges.values())
    print(f"  {len(mon.sites())} lock sites, {n_edges} ordered edges")
    for src in sorted(edges):
        print(f"    {src} -> {', '.join(sorted(edges[src]))}")
    try:
        warnings = mon.check()
    except LockOrderError as e:
        failures.append(f"lock-order cycle: {e}")
        print(f"  FAILED: {e}")
        return
    for w in warnings:
        print(f"    {w}")
    print("  acyclic: ok")


def _audit_store(root: str, failures: list) -> None:
    from repro.spgemm.persist import PlanStore

    print(f"\n== store audit: {root} " + "=" * 20)
    store = PlanStore(root)
    report = store.audit()
    print(f"  {report['files']} artifact file(s), {report['aliases']} "
          f"alias(es), {len(report['orphaned'])} orphaned "
          f"(pruned={report['pruned']})")
    for tok in report["orphaned"]:
        print(f"    orphaned alias: {tok}")
    # Orphans are pruned, not fatal — a second audit must come back clean.
    if store.audit()["orphaned"]:
        failures.append("store audit: orphaned aliases survived pruning")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--paper-matrices", action="store_true",
                    help="verify plans for every paper matrix")
    ap.add_argument("--matrices", default=None,
                    help="comma-separated matrix subset (default: all)")
    ap.add_argument("--scale", type=float, default=0.01,
                    help="suite_matrix scale (default 0.01: CI-sized)")
    ap.add_argument("--shards", type=int, default=0,
                    help="additionally verify a sharded plan at N shards")
    ap.add_argument("--backend", default="jnp",
                    help="plan backend to build with (default jnp)")
    ap.add_argument("--lock-lint", action="store_true",
                    help="run the gateway/pipeline lock-order lint")
    ap.add_argument("--store", default=None,
                    help="audit this PlanStore directory (default: "
                         "$REPRO_SPGEMM_PLAN_DIR when set)")
    args = ap.parse_args(argv)
    _ensure_devices(args.shards)

    t0 = time.perf_counter()
    failures: list = []
    ran = False
    if args.paper_matrices or args.matrices:
        ran = True
        from repro.analysis.kernel_lint import lint_kernel_module
        from repro.configs.paper_matrices import SUITE

        print("== kernel module lint " + "=" * 38)
        mod_findings = lint_kernel_module()
        for f in mod_findings:
            print(f"  {f}")
            if f.severity == "error":
                failures.append(f"kernel lint: {f.message}")
        if not mod_findings:
            print("  ok (semantics + fp32 accumulation)")
        names = (args.matrices.split(",") if args.matrices
                 else list(SUITE))
        for name in names:
            _check_matrix(name.strip(), args.scale, args.shards,
                          args.backend, failures)
    if args.lock_lint:
        ran = True
        _lock_lint(failures)
    store_dir = args.store or os.environ.get("REPRO_SPGEMM_PLAN_DIR")
    if store_dir and os.path.isdir(store_dir):
        ran = True
        _audit_store(store_dir, failures)
    if not ran:
        ap.error("nothing to do: pass --paper-matrices, --matrices, "
                 "--lock-lint, and/or --store")
    dt = time.perf_counter() - t0
    if failures:
        print(f"\nFAILED ({len(failures)} problem(s), {dt:.1f}s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nall static checks passed ({dt:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
