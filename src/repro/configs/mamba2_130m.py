"""mamba2-130m [ssm]: 24L d_model=768, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality), pure mixer layers (no FF).
The paper's SpGEMM technique is inapplicable to the dense SSD recurrence
(DESIGN.md §Arch-applicability); the arch is implemented without it.
long_500k runs (O(1)-state decode). [arXiv:2405.21060]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    block_pattern=(BlockSpec("ssm", "none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # 24 SSD heads
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        vocab=128, ssm_chunk=16, dtype="float32",
    )
