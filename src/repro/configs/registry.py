"""Architecture registry + the assigned input-shape grid.

``cells()`` enumerates every (arch x shape) combination with its
applicability verdict (DESIGN.md §Arch-applicability):

* encoder-only archs (hubert) have no decode step -> decode shapes skipped;
* ``long_500k`` needs sub-quadratic attention -> runs only for SSM / SWA /
  hybrid archs, skipped (documented) for pure full-attention archs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "get_reduced",
           "cells", "cell_status"]

ARCHS: Dict[str, str] = {
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "command-r-35b": "repro.configs.command_r_35b",
    "yi-9b": "repro.configs.yi_9b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.reduced()


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs, reason) for one (arch, shape) cell."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 500k-token decode needs "
            "sub-quadratic attention (documented skip)"
        )
    return True, "runs"


def cells() -> List[Tuple[str, str, bool, str]]:
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            runs, reason = cell_status(cfg, shape)
            out.append((arch, shape.name, runs, reason))
    return out
