"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (bidirectional attention, w2v2 arch); masked-prediction
training over a 504-entry codebook. The CNN feature extractor is a stub —
``input_specs`` feeds precomputed 512-d conv-feature frames.
[arXiv:2106.07447]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    block_pattern=(BlockSpec("attn", "mlp"),),
    causal=False,  # encoder-only: no decode shapes
    act="gelu",
    mlp_gated=False,
    attn_bias=True,
    tie_embeddings=True,  # codebook table doubles as prediction head
    frontend="audio",
    frontend_dim=512,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        frontend_dim=32, dtype="float32",
    )
