"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma backbone. The SigLIP tower is a stub:
``input_specs`` provides 256 precomputed 1152-d patch embeddings that a
linear connector projects and prepends to the text tokens.
[arXiv:2407.07726]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    d_head=256,  # gemma head_dim
    block_pattern=(BlockSpec("attn", "mlp"),),
    act="gelu",
    mlp_gated=True,  # gemma geglu
    tie_embeddings=True,
    frontend="vision",
    frontend_dim=1152,
    num_patches=256,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab=128, frontend_dim=48, num_patches=8,
        dtype="float32",
    )
