"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert_ff=768
vocab=151936, MoE 128 experts top-8 — the hot path for the paper's
technique: MoE dispatch = block-diagonal SpGEMM via the grouped kernel
(DESIGN.md Sec. 3). [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    block_pattern=(BlockSpec("attn", "moe"),),
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=32,
        d_ff_expert=32, n_experts=8, top_k=2, vocab=128, dtype="float32",
    )
