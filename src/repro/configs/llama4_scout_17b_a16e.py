"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1. The brief's config applies MoE at every
layer (the HF release interleaves dense layers and adds a shared expert —
simplified per the assigned config; noted in DESIGN.md).
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    block_pattern=(BlockSpec("attn", "moe"),),
    n_experts=16,
    top_k=1,
    d_ff_expert=8192,
    tie_embeddings=False,
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=64,
        d_ff_expert=64, n_experts=4, top_k=1, vocab=128, dtype="float32",
    )
