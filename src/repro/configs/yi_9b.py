"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA, untied embeddings. [arXiv:2403.04652]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    block_pattern=(BlockSpec("attn", "mlp"),),
    tie_embeddings=False,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=128, dtype="float32",
    )
