"""The paper's evaluation set (Table 4) as a config, re-exported from
sparse/random.py where the synthetic generators live."""
from repro.core.perfmodel import PAPER_MATRICES
from repro.sparse.random import SUITE, suite_matrix

__all__ = ["PAPER_MATRICES", "SUITE", "suite_matrix"]
