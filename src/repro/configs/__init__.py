"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.registry import ARCHS, get_config, get_reduced, SHAPES
