"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave.

Period-8 block: attention at position 4, Mamba elsewhere; MoE FF on odd
positions (every other layer), dense FF on even. SSD layers use
d_state=16 (Jamba v0.1 uses Mamba-1-style small state). long_500k RUNS:
attention layers' KV is sharded over the kv_seq axis and Mamba layers are
O(1)-state. [arXiv:2403.19887]
"""
from repro.models.config import BlockSpec, ModelConfig

_P = (
    BlockSpec("ssm", "mlp"),
    BlockSpec("ssm", "moe"),
    BlockSpec("ssm", "mlp"),
    BlockSpec("ssm", "moe"),
    BlockSpec("attn", "mlp"),
    BlockSpec("ssm", "moe"),
    BlockSpec("ssm", "mlp"),
    BlockSpec("ssm", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    block_pattern=_P,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,  # 128 SSD heads
    tie_embeddings=False,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=8, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
        d_ff_expert=96, n_experts=4, top_k=2, ssm_state=8, ssm_head_dim=16,
        vocab=128, ssm_chunk=16, dtype="float32",
    )
