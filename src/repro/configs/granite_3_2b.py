"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA, tied embeddings. [hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    block_pattern=(BlockSpec("attn", "mlp"),),
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=131, dtype="float32",
    )
