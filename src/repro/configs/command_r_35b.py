"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    block_pattern=(BlockSpec("attn", "mlp"),),
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=128, dtype="float32",
    )
