"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention (window 4096),
which makes 500k-token decode serveable (window-bounded KV ring buffer).
[arXiv:2401.16818]
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    block_pattern=(BlockSpec("attn", "mlp"),),
    window=4096,  # SWA
    tie_embeddings=False,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=128, window=32, dtype="float32",
    )
