"""Logical-axis sharding rules (MaxText-style).

Model code annotates parameters and activations with *logical* axis names
(``batch``, ``heads``, ``mlp``, ``expert``, ...). A ``ShardingRules`` table
maps logical names to mesh axes for the active mesh; changing the mesh
(tests: 1 CPU device; production: 16x16 or 2x16x16) changes one table, not
the model code.

``shard(x, *names)`` applies ``with_sharding_constraint`` when a rules
context is active and is a no-op otherwise, so all model code runs unchanged
outside pjit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # public API since jax 0.6; the experimental module is the old home
    from jax import shard_map as _shard_map
    _REPLICATION_KW = "check_vma"  # renamed from check_rep with the move
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _REPLICATION_KW = "check_rep"

__all__ = [
    "ShardingRules",
    "default_rules",
    "use_rules",
    "leading_sharding",
    "logical_spec",
    "replicated_sharding",
    "shard",
    "shard_map",
    "named_sharding",
]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map`` (the jax.shard_map / experimental
    rename + the ``check_rep`` -> ``check_vma`` kwarg rename, shimmed like
    ``kernels/_compat.py``). ``check_vma=None`` keeps the jax default."""
    kwargs = {} if check_vma is None else {_REPLICATION_KW: check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    table: Dict[str, Axis]

    def resolve(self, names: Sequence[Optional[str]]) -> P:
        axes = []
        used: set = set()
        for n in names:
            ax = self.table.get(n) if n is not None else None
            # A mesh axis may appear at most once in a PartitionSpec.
            flat = (ax,) if isinstance(ax, str) else (ax or ())
            if any(a in used for a in flat):
                ax = None
            else:
                used.update(flat)
            axes.append(ax)
        return P(*axes)


def default_rules(
    mesh: Mesh,
    *,
    n_heads: int = 0,
    n_kv_heads: int = 0,
    n_experts: int = 0,
    decode: bool = False,
    prefill: bool = False,
    seq_parallel: bool = True,
) -> ShardingRules:
    """The production rules table (DESIGN.md Sec. 5), resolved against the
    mesh's actual axes and the architecture's divisibility.

    * ``batch`` -> all data-parallel axes (pod + data when present);
    * ``heads``/``mlp``/``vocab`` -> ``model`` (tensor parallelism);
    * ``kv_heads`` -> ``model`` only when the head count divides evenly,
      else replicated (standard GQA practice when n_kv < TP degree);
    * ``expert`` -> ``model`` (expert parallelism);
    * ``kv_seq`` -> ``model`` for decode (flash-decoding style sequence
      sharding of the KV cache), unsharded otherwise;
    * ``seq_resid`` -> ``model`` (Megatron-style sequence parallelism of
      the residual stream): the layer-scan carry — the tensor the remat
      policy must keep alive per layer — is 1/TP the size; GSPMD inserts
      the all-gather before QKV/FF projections and the reduce-scatter
      after, exactly the Megatron-SP schedule. Disabled for decode
      (seq = 1).
    """
    axis_names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    model = "model" if "model" in axis_names else None
    model_size = mesh.shape["model"] if model else 1
    kv = model if (model and n_kv_heads and n_kv_heads % model_size == 0) else None
    expert = model if (model and n_experts and n_experts % model_size == 0) else None
    # GQA score blocks: for train, GSPMD factorizes the model axis across
    # the (KV, R) dims of the reshaped q (e.g. 16 = 8x2 for command-r) —
    # measured better than forcing a query-position sharding. For PREFILL
    # the propagation fails in heterogeneous periods (jamba's 1-attn-in-8:
    # replicated 8 GiB f32 [B,KV,R,bq,32k] score blocks), so the blocked-
    # attention body pins the query-position dim ("seq_q") there.
    heads_div = bool(model) and (n_heads == 0 or n_heads % model_size == 0)
    table: Dict[str, Axis] = {
        "batch": data_axes if data_axes else None,
        "seq": None,
        "seq_q": model if prefill else None,
        "seq_resid": model if (seq_parallel and not decode) else None,
        "embed": None,
        "heads": model,
        "kv_heads": kv,
        "head_dim": None,
        "mlp": model,
        "vocab": model,
        "expert": expert,
        "expert_mlp": None if expert else model,
        "kv_seq": model if decode else None,
        "kv_batch": data_axes if data_axes else None,
        "state": None,
        "inner": model,  # SSM inner channels
    }
    return ShardingRules(table)


_ctx = threading.local()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: ShardingRules):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def _active() -> Optional[Tuple[Mesh, ShardingRules]]:
    return getattr(_ctx, "state", None)


def current_mesh() -> Optional[Mesh]:
    state = _active()
    return state[0] if state else None


def mesh_axis(logical: str) -> Axis:
    """The mesh axis a logical name resolves to under the active rules."""
    state = _active()
    if state is None:
        return None
    return state[1].table.get(logical)


def logical_spec(names: Sequence[Optional[str]]) -> P:
    """Resolve logical names to a PartitionSpec under the active rules
    (fully replicated when no context is active)."""
    state = _active()
    if state is None:
        return P()
    return state[1].resolve(names)


def named_sharding(names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    state = _active()
    if state is None:
        return None
    mesh, rules = state
    return NamedSharding(mesh, rules.resolve(names))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without a context)."""
    state = _active()
    if state is None:
        return x
    mesh, rules = state
    spec = rules.resolve(names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def leading_sharding(mesh: Mesh, axis: str, ndim: int = 1) -> NamedSharding:
    """Shard dimension 0 over one mesh axis, replicate the rest — the
    layout of every per-shard stacked array in the sharded SpGEMM executor
    (``[n_shards, ...]`` with the shard dim on ``axis``)."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicate across the mesh (the B-operand layout in the
    sharded SpGEMM executor)."""
    return NamedSharding(mesh, P())


def divisible_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the corresponding dim
    (jit in/out_shardings demand exact divisibility, unlike
    with_sharding_constraint)."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, axes):
        flat = (ax,) if isinstance(ax, str) else (ax or ())
        size = 1
        for a in flat:
            size *= mesh.shape[a]
        out.append(ax if (size and dim % size == 0) else None)
    return P(*out)


def divisible_sharding(
    shape: Sequence[int], names: Sequence[Optional[str]],
    rules: ShardingRules, mesh: Mesh,
) -> NamedSharding:
    """Resolve logical axes to a divisibility-safe NamedSharding."""
    return NamedSharding(mesh, divisible_spec(shape, rules.resolve(names), mesh))
