"""Training launcher.

Production launch (per-host, under the cluster scheduler)::

    python -m repro.launch.train --arch yi-9b --steps 1000 \
        --mesh production [--multi-pod]

Local / CI launch (any device count; the mesh shrinks to what exists)::

    python -m repro.launch.train --arch granite-3-2b --reduced \
        --steps 200 --batch 8 --seq 128

The launcher wires: config -> mesh + sharding rules -> params/optimizer
init (sharded) -> data pipeline -> fault-tolerant Trainer loop.
"""
from __future__ import annotations

import argparse
import os
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, get_reduced
from repro.data.pipeline import SyntheticLM, shard_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import default_rules, divisible_sharding, use_rules
from repro.models import transformer as tr
from repro.optim import AdamW, warmup_cosine
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import Trainer, TrainerConfig


def launch_train(
    arch: str,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str,
    reduced: bool = True,
    mesh_kind: str = "host",
    multi_pod: bool = False,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 10,
    ckpt_every: int = 100,
):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if mesh_kind == "production":
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        mesh = make_host_mesh()
    rules = default_rules(mesh, n_kv_heads=cfg.n_kv_heads,
                          n_experts=cfg.n_experts)
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    if batch % dp != 0:
        batch = max(dp, (batch // dp) * dp)

    opt = AdamW(lr=warmup_cosine(lr, steps // 10 + 1, steps),
                weight_decay=0.01,
                master=(cfg.param_dtype != "float32"))

    with use_rules(mesh, rules):
        params = tr.init_lm(jax.random.PRNGKey(seed), cfg)
        # Lay params out per the rules table.
        param_axes = tr.lm_axes(cfg)
        params = jax.tree.map(
            lambda x, a: jax.device_put(
                x, divisible_sharding(x.shape, a, rules, mesh)),
            params, param_axes,
        )
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

        data = SyntheticLM(cfg, batch=batch, seq=seq, seed=seed)

        def batches():
            step = 0
            while True:
                yield shard_batch(data.batch_at(step), mesh)
                step += 1

        trainer = Trainer(
            TrainerConfig(
                total_steps=steps, ckpt_dir=ckpt_dir,
                ckpt_every=ckpt_every, log_every=log_every,
            ),
            step_fn, batches(), params, opt_state,
            on_metrics=lambda s, m: print(
                f"step {s:5d}  loss {m['loss']:.4f}  "
                f"gnorm {m['grad_norm']:.3f}"
            ),
        )
        return trainer.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    res = launch_train(
        args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
        reduced=args.reduced, mesh_kind=args.mesh, multi_pod=args.multi_pod,
        lr=args.lr,
    )
    print(f"done: {res['final_step']} steps, preempted={res['preempted']}")


if __name__ == "__main__":
    main()
