import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, and extract the roofline raw data.

For every cell this records into ``artifacts/dryrun/<cell>__<mesh>.json``:

* ``memory``      — compiled.memory_analysis() per-device byte numbers
  (proves the cell fits a 16 GB v5e chip);
* ``cost``        — compiled.cost_analysis() FLOPs / bytes accessed;
* ``collectives`` — per-op-kind byte totals parsed from compiled.as_text();
* ``corrected``   — trip-count-corrected totals (DESIGN.md Sec. 6): XLA
  counts a scan body once, so we additionally compile L=1 / L=2 layer
  variants (and, for prefill, two query-block sizes to resolve the inner
  attention scan) and reconstruct full-depth totals;
* ``model_flops`` — 6·N·D (dense) / 6·N_active·D (MoE) for the
  useful-compute ratio.

Cost sub-compiles run on the single-pod mesh only (the roofline table is
single-pod); the multi-pod pass is the full-config compile that proves the
``pod`` axis shards.
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import SHAPES, ARCHS, ShapeSpec, cell_status, get_config
from repro.data.pipeline import batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (
    default_rules,
    divisible_sharding,
    divisible_spec,
    use_rules,
)
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.optim.zero import opt_state_specs, zero1_specs
from repro.runtime.steps import (
    batch_axes,
    cache_axes,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9\[\],{}\s]*?)\)?\s*"
    r"(all-gather-start|all-reduce-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-gather|all-reduce|collective-permute)"
    r"\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum *operand* bytes per collective kind from optimized HLO text."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2).replace("-start", "")
        # Operands: everything inside the top-level parens after the opcode.
        start = line.index(m.group(2)) + len(m.group(2))
        depth = 0
        args = ""
        for ch in line[start:]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        b = _shape_bytes(args)
        rec = out.setdefault(kind, {"bytes": 0.0, "count": 0})
        rec["bytes"] += b
        rec["count"] += 1
    return out


def _flt(d: Dict[str, Any], key: str) -> float:
    v = d.get(key, 0.0)
    return float(v) if v is not None else 0.0


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = _flt(ca, "flops")
    bytes_accessed = _flt(ca, "bytes accessed")
    if bytes_accessed == 0.0:
        bytes_accessed = sum(
            float(v) for k, v in ca.items() if k.startswith("bytes accessed")
        )
    return {"flops": flops, "bytes": bytes_accessed}


_DEF_RE = re.compile(r"%(\S+) = (\w+)\[([0-9,]*)\]")
_CONV_RE = re.compile(
    r"%(\S+) = f32\[([0-9,]*)\]\S*\s+convert\((?:bf16\[[0-9,]*\]\S*\s+)?%([\w.\-]+)"
)


def parse_upcast_bytes(hlo: str) -> float:
    """Bytes of f32 buffers that are plain converts of same-shaped bf16
    tensors. XLA:CPU upcasts bf16 dot operands (weights, caches) to f32 —
    the TPU MXU consumes bf16 natively, so these buffers are a CPU-proxy
    artifact; we report peak memory with and without them.

    Two passes: operand types are not always printed inline, so resolve
    each convert's operand against the definition table.
    """
    deftype = {}
    for m in _DEF_RE.finditer(hlo):
        deftype[m.group(1)] = (m.group(2), m.group(3))
    seen = {}
    for m in _CONV_RE.finditer(hlo):
        out_name, dims, op_name = m.groups()
        src = deftype.get(op_name)
        if src is None or src[0] != "bf16" or src[1] != dims:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n * 4 >= 1 << 20:  # ignore sub-MiB converts
            seen[out_name] = n * 4
    return float(sum(seen.values()))


def extract_memory(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[k] = float(getattr(ma, k, 0) or 0)
    out["peak_bytes"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    try:
        out["bf16_upcast_bytes"] = parse_upcast_bytes(compiled.as_text())
    except Exception:
        out["bf16_upcast_bytes"] = 0.0
    out["peak_bytes_tpu_adjusted"] = max(
        0.0, out["peak_bytes"] - out["bf16_upcast_bytes"])
    return out


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def cell_config(arch: str, shape: ShapeSpec, *, n_layers: Optional[int] = None,
                attn_block_q: Optional[int] = None,
                scan_unroll: bool = False) -> ModelConfig:
    cfg = get_config(arch)
    over: Dict[str, Any] = dict(
        dtype="bfloat16", param_dtype="bfloat16", remat="full",
        kernel_backend="jnp",
    )
    if shape.kind in ("prefill", "train"):
        # Blocked attention for all full-sequence plans: the dense-score
        # buffer at 4k train is ~4 GiB f32 per device per layer (fwd+bwd
        # copies exceed HBM); the q-block scan bounds live memory and the
        # bq1/bq2 compile pair resolves its trip count for the roofline.
        over["attn_impl"] = "blocked"
        over["attn_block_q"] = attn_block_q or 1024
    else:
        over["attn_impl"] = "dense"
    if n_layers is not None:
        # Keep the pattern period valid: round up to a whole period.
        period = cfg.period
        over["n_layers"] = max(n_layers, 1) * period
    over["scan_unroll"] = scan_unroll
    cfg = cfg.with_(**over)
    return cfg


def _specs_to_shardings(sds_tree, axes_tree, rules, mesh):
    """Per-leaf NamedShardings with divisibility enforcement.

    Maps over ``axes_tree`` (tuple leaves) so the logical-axis tuples are
    not traversed as pytrees; ``sds_tree`` must be structure-compatible.
    """
    return jax.tree.map(
        lambda axes, sds: divisible_sharding(sds.shape, axes, rules, mesh),
        axes_tree,
        sds_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def lower_cell(
    arch: str,
    shape: ShapeSpec,
    mesh,
    *,
    n_periods: Optional[int] = None,
    attn_block_q: Optional[int] = None,
    microbatches: Optional[int] = None,
    scan_unroll: bool = False,
    compile_only_cost: bool = False,
) -> Tuple[Dict[str, Any], Any]:
    """Lower + compile one cell variant; returns (record, compiled)."""
    cfg = cell_config(arch, shape, n_layers=n_periods,
                      attn_block_q=attn_block_q, scan_unroll=scan_unroll)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    rules = default_rules(
        mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        n_experts=cfg.n_experts, decode=(shape.kind == "decode"),
        prefill=(shape.kind == "prefill"),
    )
    if shape.global_batch % dp != 0:
        # e.g. long_500k B=1: replicate the batch axes.
        table = dict(rules.table)
        table["batch"] = None
        table["kv_batch"] = None
        rules = type(rules)(table)

    with use_rules(mesh, rules):
        param_axes = tr.lm_axes(cfg)
        param_sds = jax.eval_shape(lambda: tr.init_lm(jax.random.PRNGKey(0), cfg))
        param_specs = jax.tree.map(
            lambda a, sds: divisible_spec(sds.shape, rules.resolve(a), mesh),
            param_axes, param_sds,
            is_leaf=lambda x: isinstance(x, tuple))
        # FSDP-style weight sharding for the big archs: TP-only leaves
        # >2 GiB of bf16 params resident per device (train additionally
        # pays a same-sized stacked-gradient buffer). Extending the param
        # sharding over the data axes makes GSPMD all-gather each layer's
        # weights inside the layer loop and (train) reduce-scatter its
        # gradients immediately. Gate on the FULL architecture so cost
        # sub-compiles (reduced L) keep the production sharding strategy.
        fsdp = (get_config(arch).param_counts()["total"] * 2
                / max(mesh.shape.get("model", 1), 1)) > 2e9
        if fsdp:
            param_specs = zero1_specs(param_specs, param_sds, mesh)
        param_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), param_specs,
            is_leaf=lambda x: isinstance(x, P))

        t0 = time.time()
        if shape.kind == "train":
            opt = AdamW(lr=1e-4, weight_decay=0.01, master=True)
            opt_sds = jax.eval_shape(opt.init, param_sds)
            opt_specs = opt_state_specs(param_specs, param_sds, mesh,
                                        master=True)
            # m/v/master follow the zero-1 extended specs; step is scalar.
            opt_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), opt_specs,
                is_leaf=lambda x: isinstance(x, P))
            b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
            b_sh = _specs_to_shardings(b_sds, batch_axes(cfg, "train"),
                                       rules, mesh)
            # Gradient accumulation for the big archs: the 4k x 16-seq
            # per-device activation volume (logits region, per-layer
            # residuals) does not fit 16 GiB in one shot. The f32 grad
            # accumulator is pinned to the ZeRO (m-state) layout.
            total_params = get_config(arch).param_counts()["total"]
            u = microbatches or (
                16 if total_params > 90e9 else
                8 if total_params > 40e9 else
                4 if (cfg.d_model >= 4096 or cfg.n_experts >= 64
                      or cfg.d_model * cfg.n_layers >= 80_000) else 1)
            # A microbatch must still cover every DP shard (multi-pod
            # doubles DP, so u caps at batch/DP there).
            u = max(1, min(u, shape.global_batch // max(dp, 1)))
            grad_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), opt_specs["m"],
                is_leaf=lambda x: isinstance(x, P))
            step = make_train_step(cfg, opt, microbatches=u,
                                   grad_shardings=grad_sh)
            # Donate params+opt (without donation the updated copies double
            # the resident bytes) and PIN the output shardings: without
            # out_shardings GSPMD gathered the ZeRO shards inside the
            # optimizer region (full-size f32 m/v/master while-carries).
            metrics_sh = None  # let XLA choose for the small metrics dict
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, b_sh),
                out_shardings=(param_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(param_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            b_sds = batch_specs(cfg, shape.global_batch, shape.seq_len)
            b_sds.pop("labels", None)
            b_sds.pop("mask", None)
            b_ax = batch_axes(cfg, "prefill")
            b_sh = _specs_to_shardings(b_sds, b_ax, rules, mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(param_sh, b_sh))
            lowered = jitted.lower(param_sds, b_sds)
        else:  # decode
            cache_sds = jax.eval_shape(
                lambda: tr.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_ax = cache_axes(cfg)
            c_sh = _specs_to_shardings(cache_sds, c_ax, rules, mesh)
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tok_sh = divisible_sharding(
                tok_sds.shape, ("batch", None), rules, mesh)
            step = make_decode_step(cfg)
            # Donate the cache: the updated cache otherwise doubles.
            jitted = jax.jit(step, in_shardings=(param_sh, c_sh, tok_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_sds, cache_sds, tok_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))),
        "microbatches": u if shape.kind == "train" else 1,
        "n_layers": cfg.n_layers,
        "attn_block_q": cfg.attn_block_q if shape.kind == "prefill" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost": extract_cost(compiled),
    }
    if not compile_only_cost:
        rec["memory"] = extract_memory(compiled)
        rec["collectives"] = parse_collectives(compiled.as_text())
    else:
        rec["collectives"] = parse_collectives(compiled.as_text())
    return rec, compiled


def _coll_total(coll: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in coll.values())


def lower_optimizer_only(arch: str, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Lower just the optimizer update (same shardings as the train cell) —
    its cost is counted once per step, not once per microbatch."""
    cfg = cell_config(arch, shape)
    rules = default_rules(mesh, n_kv_heads=cfg.n_kv_heads,
                          n_experts=cfg.n_experts)
    with use_rules(mesh, rules):
        param_axes = tr.lm_axes(cfg)
        param_sds = jax.eval_shape(lambda: tr.init_lm(jax.random.PRNGKey(0), cfg))
        param_specs = jax.tree.map(
            lambda a, sds: divisible_spec(sds.shape, rules.resolve(a), mesh),
            param_axes, param_sds, is_leaf=lambda x: isinstance(x, tuple))
        if (get_config(arch).param_counts()["total"] * 2
                / max(mesh.shape.get("model", 1), 1)) > 2e9:
            param_specs = zero1_specs(param_specs, param_sds, mesh)
        param_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), param_specs,
            is_leaf=lambda x: isinstance(x, P))
        opt = AdamW(lr=1e-4, weight_decay=0.01, master=True)
        opt_sds = jax.eval_shape(opt.init, param_sds)
        opt_specs = opt_state_specs(param_specs, param_sds, mesh, master=True)
        opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs,
                              is_leaf=lambda x: isinstance(x, P))
        grad_sds = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_sds)
        grad_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               opt_specs["m"],
                               is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(opt.update,
                         in_shardings=(grad_sh, opt_sh, param_sh),
                         out_shardings=(param_sh, opt_sh))
        compiled = jitted.lower(grad_sds, opt_sds, param_sds).compile()
    return {
        "cost": extract_cost(compiled),
        "collectives": parse_collectives(compiled.as_text()),
    }


def corrected_costs(
    arch: str, shape: ShapeSpec, mesh, full_rec: Dict[str, Any]
) -> Dict[str, Any]:
    """Trip-count-corrected FLOPs / bytes / collective bytes (Sec. 6).

    XLA's cost analysis counts every ``while`` body ONCE regardless of the
    trip count (verified empirically: cost(L=2) == cost(L=8)). Differences
    between looped compiles are therefore noise; the correction instead
    uses **unrolled-layer sub-compiles** at microbatch scale:

      m(L, bq) = b + L * (p0 + gamma*bq)       (layer loop unrolled; the
                                                q-block scan body counted
                                                once; optimizer excluded)

    Two L values give the per-period cost; two bq values split it into
    the q-scan body slope gamma (whose true multiplier is S/bq trips of a
    gamma*bq body = gamma*S) and the rest p0. The reconstruction is

      total = opt + u * (b + n_periods * (p0 + gamma*S))

    with the optimizer lowered separately (counted per step, not per
    microbatch). Decode uses the same scheme without optimizer/u.
    """
    cfg = get_config(arch)
    n_periods = cfg.n_layers // cfg.period
    u = full_rec.get("microbatches", 1)
    # Sub-compiles run at microbatch scale with no ubatch loop.
    sub_shape = ShapeSpec(shape.name, shape.seq_len,
                          max(shape.global_batch // u, 1), shape.kind)

    def costs(rec):
        return np.array([
            rec["cost"]["flops"], rec["cost"]["bytes"],
            _coll_total(rec["collectives"]),
        ])

    if shape.kind == "train" and u >= 1:
        opt_rec = lower_optimizer_only(arch, shape, mesh)
        opt_cost = costs(opt_rec)
    else:
        opt_rec = None
        opt_cost = np.zeros(3)

    def sub(n_p, bq=None):
        r, _ = lower_cell(arch, sub_shape, mesh, n_periods=n_p,
                          attn_block_q=bq, microbatches=1,
                          scan_unroll=True, compile_only_cost=True)
        return r

    use_bq = cfg.has_attention and shape.kind in ("train", "prefill")
    if not use_bq:
        r1, r2 = sub(1), sub(2)
        p = costs(r2) - costs(r1)
        b = costs(r1) - p - (opt_cost if shape.kind == "train" else 0)
        per_period = p
        subs = [r1, r2]
        method = f"unrolled L1/L2 x u={u}" + (" + opt" if opt_rec else "")
    else:
        bq1, bq2 = 1024, 512
        r1a, r2a = sub(1, bq1), sub(2, bq1)
        r1b = sub(1, bq2)
        pa = costs(r2a) - costs(r1a)  # p0 + gamma*bq1
        gamma = (costs(r1a) - costs(r1b)) / float(bq1 - bq2)
        gamma = np.maximum(gamma, 0.0)
        p0 = pa - gamma * bq1
        per_period = p0 + gamma * shape.seq_len
        b = costs(r1a) - pa - (opt_cost if shape.kind == "train" else 0)
        subs = [r1a, r2a, r1b]
        method = (f"unrolled L1/L2 x bq1/bq2 x u={u}"
                  + (" + opt" if opt_rec else ""))
    b = np.maximum(b, 0.0)
    per_period = np.maximum(per_period, 0.0)
    u_eff = u if shape.kind == "train" else 1
    total = opt_cost + u_eff * (b + n_periods * per_period)

    out = {
        "method": method,
        "flops": float(total[0]),
        "bytes": float(total[1]),
        "collective_bytes": float(total[2]),
        "per_period": {
            "flops": float(per_period[0]),
            "bytes": float(per_period[1]),
            "collective_bytes": float(per_period[2]),
        },
        "sub_compiles": [
            {k: r.get(k) for k in ("n_layers", "attn_block_q", "cost",
                                   "compile_s")}
            for r in subs
        ],
        "collectives": full_rec["collectives"],
    }
    if opt_rec is not None:
        out["optimizer"] = opt_rec
    return out


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, float]:
    n = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    fwd = 2.0 * n["active"] * tokens
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd + bwd(2x)
    return {
        "model_flops": mult * fwd,
        "tokens": tokens,
        "params_total": n["total"],
        "params_active": n["active"],
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, with_correction: bool = True) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    runs, reason = cell_status(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    if not runs:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] {cell_id}: SKIP ({reason})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec, compiled = lower_cell(arch, shape, mesh)
    rec["status"] = "ok"
    rec["model"] = model_flops(cfg, shape)
    if with_correction and not multi_pod:
        rec["corrected"] = corrected_costs(arch, shape, mesh, rec)
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    mem = rec["memory"]["peak_bytes"] / 2**30
    adj = rec["memory"]["peak_bytes_tpu_adjusted"] / 2**30
    print(
        f"[dryrun] {cell_id}: OK peak={mem:.2f}GiB/device "
        f"(tpu-adj {adj:.2f}) flops={rec['cost']['flops']:.3e} "
        f"coll={sum(v['bytes'] for v in rec['collectives'].values()):.3e}B "
        f"({rec['total_s']}s)"
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, help="shape name (or all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-correction", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch and args.arch != "all" else list(ARCHS)
    shapes = [args.shape] if args.shape and args.shape != "all" else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, args.out,
                             with_correction=not args.no_correction)
                except Exception as e:  # keep sweeping; record the failure
                    import traceback
                    mesh_name = "pod2x16x16" if mp else "pod16x16"
                    cell_id = f"{arch}__{shape}__{mesh_name}"
                    failures.append(cell_id)
                    os.makedirs(args.out, exist_ok=True)
                    with open(os.path.join(args.out, cell_id + ".json"), "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh_name, "status": "error",
                                   "error": f"{type(e).__name__}: {e}"}, f)
                    print(f"[dryrun] {cell_id}: ERROR {type(e).__name__}: "
                          f"{str(e)[:300]}")
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} cell(s) failed: {failures}")
    else:
        print("[dryrun] all cells passed")


if __name__ == "__main__":
    main()
