"""Launcher layer: production mesh, logical-axis sharding rules, dry-run,
train and serve entry points."""
