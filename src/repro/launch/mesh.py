"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state. The dry-run entry
point sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import so 512 placeholder CPU devices exist; tests and benchmarks
see the real single device.

Topology: 16x16 = 256 chips per pod (v5e pod slice); multi-pod prepends a
``pod`` axis (2 pods = 512 chips). ``pod`` is hierarchical data parallelism
(DCN-connected), ``data`` is in-pod data parallelism, ``model`` is tensor /
expert parallelism on the fastest ICI dimension.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

__all__ = [
    "AXIS_TYPES_SUPPORTED",
    "make_auto_mesh",
    "make_production_mesh",
    "make_host_mesh",
    "make_shard_mesh",
]

# jax grew explicit-sharding axis types (jax.sharding.AxisType +
# jax.make_mesh(axis_types=...)) well after 0.4.x; run with whichever this
# jax provides — same pattern as kernels/_compat.py's CompilerParams shim.
_AxisType = getattr(jax.sharding, "AxisType", None)
AXIS_TYPES_SUPPORTED = (
    _AxisType is not None
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_auto_mesh(
    shape: Sequence[int],
    axes: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
) -> Mesh:
    """``jax.make_mesh`` with every axis pinned to ``AxisType.Auto`` when
    this jax supports axis types, and the plain call otherwise.

    On new jax, ``Auto`` is the pre-explicit-sharding behavior, so both
    branches build the same mesh semantics; callers never touch
    ``jax.sharding.AxisType`` directly (absent on older jax)."""
    axes = tuple(axes)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AXIS_TYPES_SUPPORTED:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax"
        )
    return make_auto_mesh(shape, axes, devices=devices[:n])


def make_shard_mesh(n_shards: Optional[int] = None, axis: str = "shard") -> Mesh:
    """1-D mesh over the first ``n_shards`` devices (default: all) — the
    mesh shape the sharded SpGEMM plan partitions its panel schedule over.

    This is the one sanctioned way to get an SpGEMM device mesh: plans key
    their cache entries on the mesh's axis/devices, so building meshes here
    (rather than from ad-hoc device lists) keeps pattern-equal callers on
    the same cache entry.
    """
    devices = jax.devices()
    if n_shards is None:
        n_shards = len(devices)
    if n_shards < 1 or n_shards > len(devices):
        raise ValueError(
            f"n_shards={n_shards} out of range for {len(devices)} devices"
        )
    return make_auto_mesh((n_shards,), (axis,), devices=devices[:n_shards])


def make_host_mesh(
    shape: Optional[Sequence[int]] = None,
    axes: Sequence[str] = ("data", "model"),
) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return make_auto_mesh(shape, axes)
