"""Serving launcher: batched autoregressive decoding.

A minimal production-shaped server loop: requests accumulate into a fixed
decode batch (continuous batching simplified to slot-based), prefill runs
via the decode path (token-at-a-time over the prompt — fine at host scale;
the 32k-prefill dry-run cells exercise the blocked-prefill plan), and every
step decodes one token for every active slot.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_reduced
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import default_rules, use_rules
from repro.models import transformer as tr
from repro.runtime.steps import make_decode_step

__all__ = ["BatchedServer", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Slot-local prompt cursor, advanced one token per decode step while
    # the request occupies a slot.
    cursor: int = 0


class BatchedServer:
    """Slot-based batched decoder over the decode_step pjit program."""

    def __init__(self, cfg, batch_slots: int = 8, max_seq: int = 512,
                 seed: int = 0, greedy: bool = True):
        self.cfg = cfg
        self.slots = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.mesh = make_host_mesh()
        self.rules = default_rules(self.mesh, n_kv_heads=cfg.n_kv_heads,
                                   n_experts=cfg.n_experts, decode=True)
        with use_rules(self.mesh, self.rules):
            self.params = tr.init_lm(jax.random.PRNGKey(seed), cfg)
            self.step = jax.jit(make_decode_step(cfg))
        # One shared position counter requires slot-synchronized decoding;
        # per-request state tracks each slot's progress.
        self.cache = tr.init_cache(cfg, batch_slots, max_seq)
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        # FIFO admission queue; deque so slot assignment pops O(1) instead
        # of list.pop(0)'s O(n) under deep backlogs.
        self.pending: Deque[Request] = deque()
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.stats = {"steps": 0, "tokens": 0}

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _assign_slots(self) -> None:
        free = [s for s in range(self.slots) if s not in self.slot_of.values()]
        while free and self.pending:
            req = self.pending.popleft()
            slot = free.pop(0)
            self.active[req.rid] = req
            self.slot_of[req.rid] = slot
            req.cursor = 0

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        """Decode until all submitted requests complete."""
        finished: List[Request] = []
        with use_rules(self.mesh, self.rules):
            for _ in range(max_steps):
                self._assign_slots()
                if not self.active:
                    break
                # Feed each slot its next input token (prompt or generated).
                for rid, req in self.active.items():
                    s = self.slot_of[rid]
                    if req.cursor < len(req.prompt):
                        self.tokens[s, 0] = req.prompt[req.cursor]
                    # else keep the last generated token already in place
                logits, self.cache = self.step(
                    self.params, self.cache, jnp.asarray(self.tokens)
                )
                nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                self.stats["steps"] += 1
                done_now = []
                for rid, req in self.active.items():
                    s = self.slot_of[rid]
                    cur = req.cursor
                    req.cursor = cur + 1
                    if cur >= len(req.prompt) - 1:
                        # This step produced a generated token for the slot.
                        req.out.append(int(nxt[s]))
                        self.tokens[s, 0] = int(nxt[s])
                        self.stats["tokens"] += 1
                        if len(req.out) >= req.max_new:
                            req.done = True
                            done_now.append(rid)
                for rid in done_now:
                    finished.append(self.active.pop(rid))
                    del self.slot_of[rid]
        return finished


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_reduced(args.arch)
    server = BatchedServer(cfg, batch_slots=4, max_seq=256)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(i, rng.integers(0, cfg.vocab, 8).tolist(),
                              args.max_new))
    t0 = time.time()
    done = server.run_until_done()
    dt = time.time() - t0
    print(f"served {len(done)} requests, {server.stats['tokens']} tokens "
          f"in {dt:.1f}s ({server.stats['tokens'] / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4]}... -> {r.out[:8]}")


if __name__ == "__main__":
    main()
