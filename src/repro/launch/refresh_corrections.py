import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Recompute the `corrected` block of every single-pod dry-run artifact
with the unrolled-sub-compile methodology (full compiles stay valid)."""
import glob
import json
import sys
import traceback

from repro.launch.dryrun import corrected_costs
from repro.launch.mesh import make_production_mesh
from repro.configs.registry import SHAPES


def main():
    mesh = make_production_mesh()
    paths = sorted(glob.glob("artifacts/dryrun/*__pod16x16.json"))
    for p in paths:
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        try:
            cor = corrected_costs(rec["arch"], SHAPES[rec["shape"]], mesh, rec)
            rec["corrected"] = cor
            with open(p, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[refresh] {rec['arch']} {rec['shape']}: "
                  f"flops={cor['flops']:.3e} bytes={cor['bytes']:.3e} "
                  f"coll={cor['collective_bytes']:.3e}", flush=True)
        except Exception as e:
            print(f"[refresh] {rec['arch']} {rec['shape']}: FAIL "
                  f"{type(e).__name__}: {str(e)[:200]}", flush=True)
            traceback.print_exc()
    print("[refresh] done", flush=True)


if __name__ == "__main__":
    main()
