"""Opt-in GPU ``XLA_FLAGS`` presets for latency hiding and collective
pipelining.

The flag set follows the MaxText-style GPU recipe: turn on the latency
hiding scheduler and the high-priority async stream so collectives overlap
compute, raise the combine thresholds so all-reduce/all-gather/
reduce-scatter batches amortize launch latency, and pipeline those
collectives through while-loop double buffering. Run-specific knobs from
the same recipe (``--xla_dump_to``, triton fusion toggles, rematerialization
overrides) are deliberately left out — they change numerics or debuggability
per model and do not belong in a blanket preset.

Because ``XLA_FLAGS`` is read once at backend initialization, this module
must run **before anything imports jax** — it therefore imports neither jax
nor any repro module that does. ``benchmarks.run`` calls
:func:`maybe_apply_gpu_xla_flags` first thing, gated on the
``REPRO_GPU_XLA_FLAGS`` environment variable:

* unset / ``0`` / ``false`` — no-op (the default: CPU/TPU runs and GPU
  users who tune their own flags are unaffected);
* anything else truthy (``1``) — merge the preset into ``XLA_FLAGS``,
  with flags the user already set taking precedence.
"""
from __future__ import annotations

import os
from typing import Mapping, MutableMapping, Optional, Sequence

__all__ = [
    "GPU_LATENCY_HIDING_FLAGS",
    "REPRO_GPU_XLA_FLAGS_ENV",
    "apply_gpu_xla_flags",
    "gpu_xla_flags",
    "maybe_apply_gpu_xla_flags",
]

REPRO_GPU_XLA_FLAGS_ENV = "REPRO_GPU_XLA_FLAGS"

# Latency-hiding / pipelining subset of the MaxText A100 recipe
# (SNIPPETS.md snippet 3).  Ordered: scheduler, streams, combine
# thresholds, pipelined collectives, double buffering, combine-by-dim.
GPU_LATENCY_HIDING_FLAGS: Sequence[str] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_all_reduce_combine_threshold_bytes=134217728",
    "--xla_gpu_all_gather_combine_threshold_bytes=1073741824",
    "--xla_gpu_reduce_scatter_combine_threshold_bytes=33554432",
    "--xla_gpu_enable_pipelined_all_gather=true",
    "--xla_gpu_enable_pipelined_reduce_scatter=true",
    "--xla_gpu_enable_pipelined_all_reduce=true",
    "--xla_gpu_enable_while_loop_double_buffering=true",
    "--xla_gpu_enable_all_gather_combine_by_dim=false",
    "--xla_gpu_enable_reduce_scatter_combine_by_dim=false",
)


def _flag_name(flag: str) -> str:
    """``--xla_foo=bar`` -> ``--xla_foo`` (identity for valueless flags)."""
    return flag.split("=", 1)[0]


def gpu_xla_flags(existing: str = "") -> str:
    """Merge the preset into an existing ``XLA_FLAGS`` string.

    Flags already present in ``existing`` win: a user who exported
    ``--xla_gpu_enable_latency_hiding_scheduler=false`` keeps that choice
    and only the flags they did not mention are appended.
    """
    existing = existing.strip()
    seen = {_flag_name(tok) for tok in existing.split()}
    added = [f for f in GPU_LATENCY_HIDING_FLAGS if _flag_name(f) not in seen]
    return " ".join(([existing] if existing else []) + added)


def apply_gpu_xla_flags(env: Optional[MutableMapping[str, str]] = None) -> str:
    """Unconditionally merge the preset into ``env['XLA_FLAGS']``.

    Returns the resulting flag string. Must run before jax is imported to
    have any effect.
    """
    if env is None:
        env = os.environ
    merged = gpu_xla_flags(env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = merged
    return merged


def _truthy(val: Optional[str]) -> bool:
    return (val or "").strip().lower() not in ("", "0", "false", "no", "off")


def maybe_apply_gpu_xla_flags(
        env: Optional[MutableMapping[str, str]] = None) -> Optional[str]:
    """Apply the preset iff ``REPRO_GPU_XLA_FLAGS`` is set truthy in ``env``.

    Returns the merged flag string when applied, ``None`` when the guard is
    off. This is the entry point ``benchmarks.run`` calls before importing
    anything jax-flavored.
    """
    if env is None:
        env = os.environ
    if not _truthy(env.get(REPRO_GPU_XLA_FLAGS_ENV)):
        return None
    return apply_gpu_xla_flags(env)
