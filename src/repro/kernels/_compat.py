"""Pallas API compatibility shims shared by the kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; run with
# whichever this jax provides.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
