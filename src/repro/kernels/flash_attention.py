"""Flash attention Pallas kernel (prefill hot-spot).

Online-softmax tiled attention: grid (batch*heads, q_blocks, kv_blocks) with
running (max, denom, acc) in VMEM scratch — the kv axis is the innermost
"arbitrary" dimension so the scratch carries across kv steps and the output
block is written exactly once per q block (on the last kv step).

Supports causal masking, a sliding window (SWA, h2o-danube / jamba), and a
``q_offset`` so chunked prefill can continue against an existing KV cache.
Oracle: kernels/ref.py::flash_attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(
    q_ref,  # [1, bq, d]
    k_ref,  # [1, bk, d]
    v_ref,  # [1, bk, d]
    o_ref,  # [1, bq, d]
    m_ref,  # [bq, 128] running max
    l_ref,  # [bq, 128] running denom
    acc_ref,  # [bq, d] running numerator
    *,
    bq: int,
    bk: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]

    i = pl.program_id(1)
    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]  # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)  # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)  # rescale of old accumulation
    p = jnp.exp(s - m_new)  # [bq, bk]
    p = jnp.where(mask, p, 0.0)
    l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        denom = l_ref[:, :1]
        safe = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "scale", "bq", "bk", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Skv, D]
    v: jax.Array,  # [BH, Skv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    scale_val = float(scale) if scale is not None else 1.0 / float(np.sqrt(d))
    grid = (bh, sq // bq, skv // bk)
    kern = functools.partial(
        _kernel,
        bq=bq,
        bk=bk,
        causal=causal,
        window=window,
        q_offset=q_offset,
        scale=scale_val,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(q, k, v)
