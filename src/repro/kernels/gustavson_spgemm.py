"""Block-Gustavson SpGEMM Pallas kernel (the paper's FPGA kernel on TPU).

Hardware adaptation (DESIGN.md Sec. 2): the FPGA's NUM_PE parallel PEs with
a shared B-row buffer become a *static triple schedule* executed by a Pallas
grid. Each grid step t performs one (bm x bk) @ (bk x bn) MXU matmul:

    panels[panel[t]][sub_row[t]*bm : (sub_row[t]+1)*bm, :] += A[a_slot[t]] @ B[b_slot[t]]

The schedule (core/schedule.py) is in BCSV vector-major order, so

* the packed A-blocks array is streamed **sequentially** from HBM — the CSV
  format's "regular access pattern" (paper Sec. 3);
* consecutive triples sharing ``b_slot`` hit the Pallas revisit-elision: the
  B block stays in VMEM and is **not** re-fetched — the paper's Sec. 4.1
  buffering scheme, with OMAR (Eq. 1) counting exactly the elided copies;
* each output panel (the G·bm x bn accumulator = the union of the G PEs'
  double buffers) is visited in one contiguous run, so it lives in VMEM for
  the whole run and is written back to HBM once.

Scalar prefetch (PrefetchScalarGridSpec) plays the role of the load kernel's
scheduling side-channel (A_DS of Table 1): slot/panel/sub-row indices are
resident in SMEM before the grid body runs.

**Batched variant** (:func:`spgemm_scheduled_batch_impl`): a value batch is
folded into the grid as a leading dimension — grid ``(bsz, t_pad)``, with
the shared triple schedule replicated per batch element through the
BlockSpec index maps (element ``b`` reads A slot ``b * nnzb_a + a_slot[t]``
and writes panel ``b * (n_panels + 1) + panel[t]``). The grid iterates the
triple dimension innermost, so each element executes its full schedule
consecutively: per-element accumulation order — and therefore the result —
is bitwise-identical to running the single-set kernel once per element, and
the schedule arrays themselves are staged on device once regardless of
batch size.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = [
    "compact_csr_indptr_impl",
    "compact_row_counts_impl",
    "pad_schedule_arrays",
    "spgemm_scheduled",
    "spgemm_scheduled_batch",
    "spgemm_scheduled_batch_impl",
    "spgemm_scheduled_impl",
]


def _kernel(
    # scalar prefetch (SMEM)
    a_slot_ref,
    b_slot_ref,
    panel_ref,
    sub_row_ref,
    start_ref,
    # VMEM blocks
    a_ref,  # [1, bm, bk]
    b_ref,  # [1, bk, bn]
    o_ref,  # [1, G*bm, bn]
    *,
    bm: int,
    t_dim: int = 0,
):
    # ``t_dim`` is the grid dimension that walks the triple schedule: 0 for
    # the single-set grid ``(t_pad,)``, 1 for the batch-folded grid
    # ``(bsz, t_pad)`` (the schedule is shared across batch elements, so
    # only the triple index selects into the prefetched SMEM arrays).
    t = pl.program_id(t_dim)
    # Zero the whole panel on its first triple (paper: PE buffers reset on
    # row change / RESET token).
    @pl.when(start_ref[t] == 1)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(
        a_ref[0].astype(jnp.float32),
        b_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    row0 = sub_row_ref[t] * bm
    cur = o_ref[0, pl.dslice(row0, bm), :]
    o_ref[0, pl.dslice(row0, bm), :] = cur + prod.astype(o_ref.dtype)


def pad_schedule_arrays(
    a_slot: np.ndarray,
    b_slot: np.ndarray,
    panel: np.ndarray,
    sub_row: np.ndarray,
    start: np.ndarray,
    n_panels: int,
    pad_to: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad the triple schedule to a fixed length with dummy-panel triples.

    Padding triples write to panel ``n_panels`` (an extra scratch panel the
    wrapper strips), with start=1 so they never accumulate garbage.
    """
    t = int(a_slot.shape[0])
    t_pad = pad_to if pad_to is not None else max(1, t)
    if t_pad < t:
        raise ValueError(f"pad_to={t_pad} < schedule length {t}")
    pad = t_pad - t

    def _p(x, fill):
        return np.concatenate([x, np.full(pad, fill, x.dtype)]) if pad else x

    return (
        _p(a_slot, 0),
        _p(b_slot, 0),
        _p(panel, n_panels),
        _p(sub_row, 0),
        _p(start, 1),
        t_pad,
    )


def spgemm_scheduled_impl(
    a_blocks: jax.Array,  # [nnzb_a, bm, bk] packed BCSV blocks (stream order)
    b_blocks: jax.Array,  # [nnzb_b, bk, bn] packed BCSR blocks
    a_slot: jax.Array,  # [T] int32
    b_slot: jax.Array,  # [T] int32
    panel: jax.Array,  # [T] int32 (dummy = n_panels)
    sub_row: jax.Array,  # [T] int32 in [0, group)
    start: jax.Array,  # [T] int32 {0,1}
    *,
    n_panels: int,
    group: int,
    interpret: bool = True,
) -> jax.Array:
    """Unjitted body of :func:`spgemm_scheduled`.

    Exposed so callers that fuse further device work around the kernel
    (``repro.spgemm.executor`` chains it with value rebind and output
    assembly) can place the whole pipeline under one ``jax.jit`` without
    nesting jits. Returns panels [n_panels, group*bm, bn] float32 (dummy
    panel stripped).
    """
    t_pad = a_slot.shape[0]
    bm, bk = a_blocks.shape[1], a_blocks.shape[2]
    bn = b_blocks.shape[2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(t_pad,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda t, a_s, b_s, p, sr, st: (a_s[t], 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda t, a_s, b_s, p, sr, st: (b_s[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, group * bm, bn), lambda t, a_s, b_s, p, sr, st: (p[t], 0, 0)
        ),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_panels + 1, group * bm, bn), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(a_slot, b_slot, panel, sub_row, start, a_blocks, b_blocks)
    return out[:n_panels]


spgemm_scheduled = jax.jit(
    spgemm_scheduled_impl,
    static_argnames=("n_panels", "group", "interpret"),
)
spgemm_scheduled.__doc__ = (
    "Run the scheduled block-Gustavson SpGEMM (jitted entry point).\n\n"
    "Returns panels [n_panels, group*bm, bn] float32 (dummy panel "
    "stripped). See :func:`spgemm_scheduled_impl` for the unjitted body."
)


def spgemm_scheduled_batch_impl(
    a_blocks: jax.Array,  # [bsz * nnzb_a, bm, bk] stacked packed BCSV blocks
    b_blocks: jax.Array,  # [bsz * nnzb_b, bk, bn] stacked packed BCSR blocks
    a_slot: jax.Array,  # [T_pad] int32, shared across the batch
    b_slot: jax.Array,  # [T_pad] int32
    panel: jax.Array,  # [T_pad] int32 (dummy = n_panels)
    sub_row: jax.Array,  # [T_pad] int32 in [0, group)
    start: jax.Array,  # [T_pad] int32 {0,1}
    *,
    bsz: int,
    n_panels: int,
    group: int,
    interpret: bool = True,
) -> jax.Array:
    """Batch-folded scheduled kernel: one Pallas grid for a value batch.

    The batch is the leading grid dimension — grid step ``(b, t)`` runs
    triple ``t`` of element ``b`` against that element's slice of the
    stacked block arrays (``[bsz * slots, ...]``, the layout the executor's
    batched rebind already produces). Triples iterate innermost, so each
    element's panels are visited in the same contiguous runs as the
    single-set grid: B-block revisit-elision and single panel write-back
    still apply per element, and results are bitwise-equal to ``bsz``
    single-set calls.

    Each element owns ``n_panels + 1`` output panels (its own dummy slot for
    the padding triples, mirroring :func:`spgemm_scheduled_impl`). Returns
    ``[bsz, n_panels, group*bm, bn]`` float32 with the dummies stripped.
    """
    t_pad = a_slot.shape[0]
    a_slots = a_blocks.shape[0] // bsz
    b_slots = b_blocks.shape[0] // bsz
    bm, bk = a_blocks.shape[1], a_blocks.shape[2]
    bn = b_blocks.shape[2]
    stride = n_panels + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(bsz, t_pad),
        in_specs=[
            pl.BlockSpec(
                (1, bm, bk),
                lambda b, t, a_s, b_s, p, sr, st: (b * a_slots + a_s[t], 0, 0),
            ),
            pl.BlockSpec(
                (1, bk, bn),
                lambda b, t, a_s, b_s, p, sr, st: (b * b_slots + b_s[t], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, group * bm, bn),
            lambda b, t, a_s, b_s, p, sr, st: (b * stride + p[t], 0, 0),
        ),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bm=bm, t_dim=1),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (bsz * stride, group * bm, bn), jnp.float32
        ),
        interpret=interpret,
        # The batch axis is race-free, so it may be declared "parallel":
        # element b only ever writes output slots b*stride + panel[t] with
        # panel[t] in [0, n_panels], i.e. inside its private half-open
        # range [b*stride, (b+1)*stride) — no slot is shared across b
        # (proven statically per plan by
        # repro.analysis.verify.check_batch_races). The triple axis stays
        # "arbitrary": panels are revisited across contiguous runs of t,
        # a sequential accumulate dependence.
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(a_slot, b_slot, panel, sub_row, start, a_blocks, b_blocks)
    return out.reshape(bsz, stride, group * bm, bn)[:, :n_panels]


def compact_row_counts_impl(row_ids: jax.Array, *, m: int) -> jax.Array:
    """Device-side per-row nnz counts of a compacted C.

    ``row_ids`` is the static per-nnz row id stream of the compact
    assembly map (CSR order). One segment-sum over a ones vector — the
    device half of the compaction bookkeeping; the host precomputed the
    same counts at plan time, so the two must agree elementwise (a test
    invariant, not a runtime check). Returns ``[m]`` int32.
    """
    return jax.ops.segment_sum(
        jnp.ones(row_ids.shape, jnp.int32), row_ids, num_segments=m
    )


def compact_csr_indptr_impl(row_ids: jax.Array, *, m: int) -> jax.Array:
    """Device-resident CSR ``indptr`` for the compacted output.

    Segment-sum counts + ``jnp.cumsum`` prefix — the device-side
    compaction stage. Paired with the compact gather (which is fused into
    the assemble step as one static gather), this yields a full CSR
    replica of C on device with zero host round trips, which is what lets
    chained plans (``repro.spgemm.plan.execute_chain``) hand C straight to
    the next stage. Returns ``[m + 1]`` int32 (int32 covers every plan the
    executor accepts: gather indices themselves are int32 until the flat
    panel space exceeds 2**31).
    """
    counts = compact_row_counts_impl(row_ids, m=m)
    indptr = jnp.zeros(m + 1, jnp.int32)
    return indptr.at[1:].set(jnp.cumsum(counts))


spgemm_scheduled_batch = jax.jit(
    spgemm_scheduled_batch_impl,
    static_argnames=("bsz", "n_panels", "group", "interpret"),
)
spgemm_scheduled_batch.__doc__ = (
    "Run the batch-folded scheduled SpGEMM (jitted entry point).\n\n"
    "Returns panels [bsz, n_panels, group*bm, bn] float32. See\n"
    ":func:`spgemm_scheduled_batch_impl` for the unjitted body."
)
