"""Grouped (expert-parallel) matmul Pallas kernel for MoE dispatch.

DESIGN.md Sec. 3: sorting tokens by expert *is* the paper's CSV vector-major
pre-processing — the expert axis is the "vector" axis, and the per-expert
weight tile plays the role of the buffered B row shared by all tokens of the
group (Sec. 4.1 buffering scheme). The host (ops.py) sorts token indices by
expert and pads each group to a tile multiple so every token tile belongs to
exactly one expert; ``tile_expert`` is the scalar-prefetched schedule.

Grid = (token_tiles, f_tiles, d_tiles); the expert weight block
W[tile_expert[i], k-block, j-block] is revisited across consecutive token
tiles of the same expert (VMEM reuse = OMAR at expert granularity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["moe_gmm"]


def _kernel(tile_expert_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tm", "bd", "bf", "out_dtype", "interpret")
)
def moe_gmm(
    x: jax.Array,  # [T, D] tokens sorted by expert, T % tm == 0
    w: jax.Array,  # [E, D, F] expert weights
    tile_expert: jax.Array,  # [T // tm] int32 expert of each token tile
    *,
    tm: int = 128,
    bd: int = 128,
    bf: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    t, d = x.shape
    e, d2, f = w.shape
    assert d == d2 and t % tm == 0 and d % bd == 0 and f % bf == 0
    grid = (t // tm, f // bf, d // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, bd), lambda i, j, k, te: (i, k)),
            pl.BlockSpec((1, bd, bf), lambda i, j, k, te: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((tm, bf), lambda i, j, k, te: (i, j)),
        scratch_shapes=[pltpu.VMEM((tm, bf), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), out_dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(tile_expert, x, w)
