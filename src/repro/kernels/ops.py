"""Public jit'd wrappers around the Pallas kernels + jnp fallbacks.

Backend policy (DESIGN.md Sec. 2): Pallas kernels target TPU; this container
is CPU-only, so ``backend="auto"`` selects

* ``"pallas"`` (interpret=False) on a real TPU backend,
* ``"jnp"`` (the ref.py oracle path, pure XLA) elsewhere — used by the
  multi-pod dry-run so collected HLO FLOPs/bytes reflect honest dense math.

Tests force ``backend="pallas_interpret"`` to execute the kernel bodies in
interpret mode on CPU and allclose them against the oracles.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import SpGEMMSchedule, build_spgemm_schedule
from repro.kernels import ref
from repro.kernels.bsr_spmm import bsr_spmm, plan_bsr
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gustavson_spgemm import pad_schedule_arrays, spgemm_scheduled
from repro.kernels.moe_gmm import moe_gmm
from repro.sparse.formats import BCSR, BCSV, COO, CSR

__all__ = [
    "resolve_backend",
    "spgemm",
    "sparse_dense_matmul",
    "grouped_matmul",
    "attention",
]


def resolve_backend(backend: str = "auto") -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "pallas_interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


# ---------------------------------------------------------------------------
# Sparse x sparse: the paper's SpGEMM, end to end
# ---------------------------------------------------------------------------

def spgemm(
    a: BCSV,
    b: BCSR,
    *,
    backend: str = "auto",
    schedule: Optional[SpGEMMSchedule] = None,
) -> CSR:
    """C = A @ B for block-sparse A (BCSV) and B (BCSR).

    Host symbolic phase (the paper's pre-processing, Sec. 4.3) builds the
    static triple schedule; the device phase runs the scheduled kernel; the
    host scatters the output panels into C's block structure.
    """
    backend = resolve_backend(backend)
    sch = schedule if schedule is not None else build_spgemm_schedule(a, b)
    bm, bk = a.block_shape
    bn = b.block_shape[1]
    group = a.group
    if sch.num_triples == 0:
        m, n = a.shape[0], b.shape[1]
        return CSR(np.zeros(m + 1, np.int64), np.zeros(0, np.int32),
                   np.zeros(0, np.float32), (m, n))

    if backend in ("pallas", "pallas_interpret"):
        a_slot, b_slot, panel, sub_row, start, _ = pad_schedule_arrays(
            sch.a_slot, sch.b_slot, sch.panel, sch.sub_row, sch.start,
            sch.n_panels,
        )
        panels = spgemm_scheduled(
            jnp.asarray(a.blocks),
            jnp.asarray(b.blocks),
            jnp.asarray(a_slot),
            jnp.asarray(b_slot),
            jnp.asarray(panel),
            jnp.asarray(sub_row),
            jnp.asarray(start),
            n_panels=sch.n_panels,
            group=group,
            interpret=(backend == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        )
    else:
        panels = ref.spgemm_scheduled_ref(
            jnp.asarray(a.blocks), jnp.asarray(b.blocks),
            sch.a_slot, sch.b_slot, sch.panel, sch.sub_row,
            sch.n_panels, group,
        )
    panels = np.asarray(panels)

    # Host scatter: panels -> C dense blocks -> CSR (paper's store kernel +
    # host read-back).
    m, n = a.shape[0], b.shape[1]
    out = np.zeros((m, n), np.float32)
    for p in range(sch.n_panels):
        g = int(sch.panel_group[p])
        j = int(sch.panel_bcol[p])
        r0 = g * group * bm
        rows = min(group * bm, m - r0)
        out[r0 : r0 + rows, j * bn : (j + 1) * bn] = panels[p][:rows]
    return CSR.from_coo(COO.fromdense(out))


# ---------------------------------------------------------------------------
# Sparse weights x dense activations (SparseLinear forward)
# ---------------------------------------------------------------------------

def sparse_dense_matmul(
    x: jax.Array,  # [M, K]
    w: BCSV,  # [K, N] block-sparse weight
    *,
    backend: str = "auto",
    tm: int = 128,
) -> jax.Array:
    """y = x @ W with W block-sparse (zero column panels handled)."""
    backend = resolve_backend(backend)
    bk, bn = w.block_shape
    k, n = w.shape
    assert x.shape[1] == k
    # W is stored row-group-major (BCSV over K); the SpMM kernel wants
    # column-panel-major with every N panel covered.
    order, brow, bcol, flags = plan_bsr(w.brow, w.bcol)
    blocks = w.blocks[order]
    # Pad a zero block for every absent column panel.
    present = np.zeros(n // bn, bool)
    present[bcol] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size:
        blocks = np.concatenate(
            [blocks, np.zeros((missing.size, bk, bn), blocks.dtype)]
        )
        brow = np.concatenate([brow, np.zeros(missing.size, np.int32)])
        bcol = np.concatenate([bcol, missing])
        flags = np.concatenate([flags, np.full(missing.size, 3, np.int32)])
        order2 = np.lexsort((brow, bcol))
        blocks, brow, bcol, flags = (
            blocks[order2], brow[order2], bcol[order2], flags[order2]
        )

    m = x.shape[0]
    pad_m = (-m) % tm
    xp = jnp.pad(x, ((0, pad_m), (0, 0))) if pad_m else x

    if backend in ("pallas", "pallas_interpret"):
        y = bsr_spmm(
            xp,
            jnp.asarray(blocks),
            jnp.asarray(brow),
            jnp.asarray(bcol),
            jnp.asarray(flags),
            n=n,
            tm=tm,
            interpret=(backend == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        )
    else:
        y = ref.bsr_spmm_ref(xp, jnp.asarray(blocks), brow, bcol, n)
    return y[:m] if pad_m else y


# ---------------------------------------------------------------------------
# Grouped matmul (MoE dispatch)
# ---------------------------------------------------------------------------

def grouped_matmul(
    x: jax.Array,  # [T, D] tokens sorted by expert (padded per expert)
    w: jax.Array,  # [E, D, F]
    tile_expert: jax.Array,  # [T // tm]
    *,
    tm: int = 128,
    backend: str = "auto",
) -> jax.Array:
    backend = resolve_backend(backend)
    if backend in ("pallas", "pallas_interpret"):
        d, f = w.shape[1], w.shape[2]
        return moe_gmm(
            x, w, tile_expert,
            tm=tm,
            bd=min(512, d) if d % min(512, d) == 0 else d,
            bf=min(512, f) if f % min(512, f) == 0 else f,
            interpret=(backend == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        )
    return ref.moe_gmm_ref(x, w, np.asarray(tile_expert), tm)


# ---------------------------------------------------------------------------
# Attention (prefill hot-spot) with a recompute-based VJP
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6),
)
def attention(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    backend: str = "auto",
) -> jax.Array:
    be = resolve_backend(backend)
    if be in ("pallas", "pallas_interpret"):
        return flash_attention(
            q, k, v,
            causal=causal, window=window, q_offset=q_offset,
            interpret=(be == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        ).astype(q.dtype)
    return ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset
    ).astype(q.dtype)


def _attention_fwd(q, k, v, causal, window, q_offset, backend):
    out = attention(q, k, v, causal, window, q_offset, backend)
    return out, (q, k, v)


def _attention_bwd(causal, window, q_offset, backend, res, g):
    q, k, v = res
    # Recompute-based backward through the oracle (flash-bwd kernel is a
    # TPU-side optimization; semantics identical).
    def f(q_, k_, v_):
        return ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, q_offset=q_offset
        ).astype(q_.dtype)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
