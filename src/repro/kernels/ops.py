"""Public jit'd wrappers around the Pallas kernels + jnp fallbacks.

Backend policy (DESIGN.md Sec. 2): Pallas kernels target TPU; this container
is CPU-only, so ``backend="auto"`` selects

* ``"pallas"`` (interpret=False) on a real TPU backend,
* ``"jnp"`` (the ref.py oracle path, pure XLA) elsewhere — used by the
  multi-pod dry-run so collected HLO FLOPs/bytes reflect honest dense math.

Tests force ``backend="pallas_interpret"`` to execute the kernel bodies in
interpret mode on CPU and allclose them against the oracles.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import SpGEMMSchedule
from repro.kernels import ref
from repro.kernels.bsr_spmm import bsr_spmm, plan_bsr
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.sparse.formats import BCSR, BCSV, CSR
from repro.spgemm.cache import PlanCache
from repro.spgemm.plan import SpGEMMPlan, resolve_backend, spgemm_plan

__all__ = [
    "resolve_backend",
    "spgemm",
    "sparse_dense_matmul",
    "grouped_matmul",
    "attention",
]


# ---------------------------------------------------------------------------
# Sparse x sparse: compatibility shim over the plan/execute API
# ---------------------------------------------------------------------------

def spgemm(
    a: BCSV,
    b: BCSR,
    *,
    backend: str = "auto",
    schedule: Optional[SpGEMMSchedule] = None,
    cache: Optional[PlanCache] = None,
) -> CSR:
    """C = A @ B for block-sparse A (BCSV) and B (BCSR).

    Thin compatibility shim over :mod:`repro.spgemm`: builds — or fetches
    from the plan cache (process-level by default; pass ``cache`` to
    isolate) — an :class:`SpGEMMPlan` for this sparsity pattern and runs
    its numeric phase with the given values. Callers that reuse one
    pattern should hold a plan directly (``repro.spgemm.spgemm_plan``)
    instead of round-tripping through here.

    The returned CSR has C's *structural* pattern (every element of every
    structurally nonzero C block): elements that compute to exact zero are
    stored explicitly, so the pattern is value-independent — the contract
    that keeps output assembly inside the plan's jitted executor.
    """
    if schedule is not None:
        # Caller already ran the symbolic phase; honor it without caching.
        plan = SpGEMMPlan.from_blocks(a, b, backend=backend, schedule=schedule)
        return plan.execute()
    plan = spgemm_plan(a, b, backend=backend, cache=cache)
    try:
        # Passing values explicitly makes the rebind + launch atomic even
        # when the cached plan is shared across threads.
        return plan.execute(a.blocks, b.blocks)
    finally:
        # One-shot semantics: free the device copies (the scarce resource)
        # but keep host values staged — the plan is shared with any direct
        # spgemm_plan holder of this pattern, whose no-arg execute() must
        # keep working. Host-side this pins only references to the
        # caller's own block arrays, bounded by the cache capacity.
        plan.release_device_values()


# ---------------------------------------------------------------------------
# Sparse weights x dense activations (SparseLinear forward)
# ---------------------------------------------------------------------------

def sparse_dense_matmul(
    x: jax.Array,  # [M, K]
    w: BCSV,  # [K, N] block-sparse weight
    *,
    backend: str = "auto",
    tm: int = 128,
) -> jax.Array:
    """y = x @ W with W block-sparse (zero column panels handled)."""
    backend = resolve_backend(backend)
    bk, bn = w.block_shape
    k, n = w.shape
    assert x.shape[1] == k
    # W is stored row-group-major (BCSV over K); the SpMM kernel wants
    # column-panel-major with every N panel covered.
    order, brow, bcol, flags = plan_bsr(w.brow, w.bcol)
    blocks = w.blocks[order]
    # Pad a zero block for every absent column panel.
    present = np.zeros(n // bn, bool)
    present[bcol] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size:
        blocks = np.concatenate(
            [blocks, np.zeros((missing.size, bk, bn), blocks.dtype)]
        )
        brow = np.concatenate([brow, np.zeros(missing.size, np.int32)])
        bcol = np.concatenate([bcol, missing])
        flags = np.concatenate([flags, np.full(missing.size, 3, np.int32)])
        order2 = np.lexsort((brow, bcol))
        blocks, brow, bcol, flags = (
            blocks[order2], brow[order2], bcol[order2], flags[order2]
        )

    m = x.shape[0]
    pad_m = (-m) % tm
    xp = jnp.pad(x, ((0, pad_m), (0, 0))) if pad_m else x

    if backend in ("pallas", "pallas_interpret"):
        y = bsr_spmm(
            xp,
            jnp.asarray(blocks),
            jnp.asarray(brow),
            jnp.asarray(bcol),
            jnp.asarray(flags),
            n=n,
            tm=tm,
            interpret=(backend == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        )
    else:
        y = ref.bsr_spmm_ref(xp, jnp.asarray(blocks), brow, bcol, n)
    return y[:m] if pad_m else y


# ---------------------------------------------------------------------------
# Grouped matmul (MoE dispatch)
# ---------------------------------------------------------------------------

def grouped_matmul(
    x: jax.Array,  # [T, D] tokens sorted by expert (padded per expert)
    w: jax.Array,  # [E, D, F]
    tile_expert: jax.Array,  # [T // tm]
    *,
    tm: int = 128,
    backend: str = "auto",
) -> jax.Array:
    backend = resolve_backend(backend)
    if backend in ("pallas", "pallas_interpret"):
        d, f = w.shape[1], w.shape[2]
        return moe_gmm(
            x, w, tile_expert,
            tm=tm,
            bd=min(512, d) if d % min(512, d) == 0 else d,
            bf=min(512, f) if f % min(512, f) == 0 else f,
            interpret=(backend == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        )
    return ref.moe_gmm_ref(x, w, np.asarray(tile_expert), tm)


# ---------------------------------------------------------------------------
# Attention (prefill hot-spot) with a recompute-based VJP
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6),
)
def attention(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    backend: str = "auto",
) -> jax.Array:
    be = resolve_backend(backend)
    if be in ("pallas", "pallas_interpret"):
        return flash_attention(
            q, k, v,
            causal=causal, window=window, q_offset=q_offset,
            interpret=(be == "pallas_interpret"
                       or jax.default_backend() != "tpu"),
        ).astype(q.dtype)
    return ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset
    ).astype(q.dtype)


def _attention_fwd(q, k, v, causal, window, q_offset, backend):
    out = attention(q, k, v, causal, window, q_offset, backend)
    return out, (q, k, v)


def _attention_bwd(causal, window, q_offset, backend, res, g):
    q, k, v = res
    # Recompute-based backward through the oracle (flash-bwd kernel is a
    # TPU-side optimization; semantics identical).
    def f(q_, k_, v_):
        return ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, q_offset=q_offset
        ).astype(q_.dtype)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
