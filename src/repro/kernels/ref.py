"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes the same mathematical result as its kernel twin with
no Pallas machinery — used by tests/test_kernels.py (shape/dtype sweeps with
``assert_allclose``) and as the portable fallback path on non-TPU backends
(``ops.py`` dispatches on ``jax.default_backend()``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "spgemm_scheduled_ref",
    "bsr_spmm_ref",
    "moe_gmm_ref",
    "flash_attention_ref",
]


def spgemm_scheduled_ref(
    a_blocks: jax.Array,  # [nnzb_a, bm, bk]
    b_blocks: jax.Array,  # [nnzb_b, bk, bn]
    a_slot: jax.Array,  # [T] (numpy or device array)
    b_slot: jax.Array,  # [T]
    panel: jax.Array,  # [T]
    sub_row: jax.Array,  # [T]
    n_panels: int,
    group: int,
) -> jax.Array:
    """Execute the SpGEMM triple schedule densely: for each triple t,
    ``panels[panel[t], sub_row[t]*bm : ..., :] += A[a_slot[t]] @ B[b_slot[t]]``.

    Pure jnp on traced arrays — safe to wrap in ``jax.jit`` and to ``vmap``
    over the block operands with a constant schedule (the batched executor
    path in ``repro.spgemm.executor``). Returns panels
    [n_panels, group*bm, bn] in float32.
    """
    bm = a_blocks.shape[1]
    bn = b_blocks.shape[2]
    prod = jnp.einsum(
        "tij,tjk->tik",
        a_blocks[jnp.asarray(a_slot)].astype(jnp.float32),
        b_blocks[jnp.asarray(b_slot)].astype(jnp.float32),
    )  # [T, bm, bn]
    # Scatter-add each product at its flat panel-row offset: panels laid out
    # as [n_panels * group * bm, bn], triple t starts at row
    # panel[t]*group*bm + sub_row[t]*bm.
    row0 = jnp.asarray(panel, jnp.int32) * (group * bm) \
        + jnp.asarray(sub_row, jnp.int32) * bm
    rows = row0[:, None] + jnp.arange(bm, dtype=jnp.int32)[None, :]  # [T, bm]
    flat = jnp.zeros((n_panels * group * bm, bn), jnp.float32)
    flat = flat.at[rows].add(prod)
    return flat.reshape(n_panels, group * bm, bn)


def bsr_spmm_ref(
    x: jax.Array,  # [M, K] dense activations
    w_blocks: jax.Array,  # [nnzb, bk, bn]
    w_brow: np.ndarray,  # [nnzb] K-block index
    w_bcol: np.ndarray,  # [nnzb] N-block index
    n: int,
) -> jax.Array:
    """y = x @ W with W block-sparse; densify W then one matmul (oracle)."""
    bk, bn = w_blocks.shape[1], w_blocks.shape[2]
    k = x.shape[1]
    w = jnp.zeros((k // bk, n // bn, bk, bn), w_blocks.dtype)
    w = w.at[jnp.asarray(w_brow), jnp.asarray(w_bcol)].set(w_blocks)
    w = w.transpose(0, 2, 1, 3).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def moe_gmm_ref(
    x: jax.Array,  # [T, D] tokens sorted (grouped) by expert
    w: jax.Array,  # [E, D, F]
    tile_expert: np.ndarray,  # [T // tm] expert id of each token tile
    tm: int,
) -> jax.Array:
    """Grouped matmul oracle: each tm-token tile matmuls its expert's W."""
    t, d = x.shape
    xt = x.reshape(t // tm, tm, d).astype(jnp.float32)
    wt = w[jnp.asarray(tile_expert)].astype(jnp.float32)  # [nt, D, F]
    return jnp.einsum("tid,tdf->tif", xt, wt).reshape(t, w.shape[2])


def flash_attention_ref(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Skv, D]
    v: jax.Array,  # [BH, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Plain softmax attention (the oracle for the flash kernel).

    ``q_offset`` positions the query block inside the kv sequence (prefill
    continuation / decode). ``window`` is a sliding-window bound (SWA):
    key j is visible to query i iff  i + q_offset - window < j <= i + q_offset
    (when causal).
    """
    d = q.shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * s
    sq, skv = q.shape[1], k.shape[1]
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen with windows) produce NaN in softmax;
    # zero them like the kernel does.
    probs = jnp.where(jnp.any(mask, axis=-1)[None, :, None], probs, 0.0)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))
