"""Pallas TPU kernels for the FSpGEMM hot-spots + jnp oracles.

Kernels (each with explicit BlockSpec VMEM tiling, validated in
interpret mode against ref.py):

* ``gustavson_spgemm`` — the paper's FPGA kernel adapted to TPU: static
  triple-scheduled block-Gustavson SpGEMM with CSV-order streaming.
* ``bsr_spmm`` — block-sparse weights x dense activations (SparseLinear).
* ``moe_gmm`` — grouped matmul over expert-sorted tokens (MoE dispatch).
* ``flash_attention`` — online-softmax tiled attention (prefill).
"""
from repro.kernels import ref

# ``ops`` is imported lazily: it shims spgemm onto repro.spgemm, which in
# turn imports the leaf kernel modules from this package — an eager import
# here would close that cycle.


def __getattr__(name):
    if name == "ops":
        import importlib

        return importlib.import_module("repro.kernels.ops")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
