"""Pallas TPU kernels for the FSpGEMM hot-spots + jnp oracles.

Kernels (each with explicit BlockSpec VMEM tiling, validated in
interpret mode against ref.py):

* ``gustavson_spgemm`` — the paper's FPGA kernel adapted to TPU: static
  triple-scheduled block-Gustavson SpGEMM with CSV-order streaming.
* ``bsr_spmm`` — block-sparse weights x dense activations (SparseLinear).
* ``moe_gmm`` — grouped matmul over expert-sorted tokens (MoE dispatch).
* ``flash_attention`` — online-softmax tiled attention (prefill).
"""
from repro.kernels import ops, ref
