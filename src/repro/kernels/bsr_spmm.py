"""Block-sparse-weight matmul (SpMM) Pallas kernel: y = x @ W, W in BCSV.

This is the Gustavson specialization used inside the LM models
(``SparseLinear``): the *weight* matrix W [K, N] is block-sparse and the
activation x [M, K] is dense, so every "B row" of Gustavson is a dense
activation tile. W's blocks are stored column-panel-major — sorted by
``(bcol, brow)``, the CSV vector-major order with the output panel as the
vector axis — so:

* the packed W-blocks array streams sequentially from HBM (CSV regularity);
* all blocks of one output column panel are consecutive, so the f32
  accumulator tile lives in VMEM scratch for exactly one run (the PE's
  double buffer) and is written back once per (m-tile, column panel).

Scalars ``w_brow/w_bcol/first/last`` are the load-kernel side channel
(paper Table 1: B_NUM_VEC / RESET become first/last run flags).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["bsr_spmm", "plan_bsr"]


def plan_bsr(
    w_brow: np.ndarray, w_bcol: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Column-panel-major ordering + run flags for the kernel.

    Returns (order, brow_sorted, bcol_sorted, flags) where flags[t] is
    1 for the first block of a bcol run, 2 for the last, 3 for both.
    """
    order = np.lexsort((w_brow, w_bcol))
    br, bc = w_brow[order], w_bcol[order]
    t = br.shape[0]
    first = np.empty(t, bool)
    last = np.empty(t, bool)
    first[0] = True
    first[1:] = bc[1:] != bc[:-1]
    last[-1] = True
    last[:-1] = bc[1:] != bc[:-1]
    flags = first.astype(np.int32) + 2 * last.astype(np.int32)
    return order, br.astype(np.int32), bc.astype(np.int32), flags


def _kernel(brow_ref, bcol_ref, flag_ref, x_ref, w_ref, o_ref, acc_ref):
    t = pl.program_id(1)
    flag = flag_ref[t]

    @pl.when(flag & 1 == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(flag & 2 == 2)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n", "tm", "out_dtype", "interpret")
)
def bsr_spmm(
    x: jax.Array,  # [M, K] dense (M % tm == 0)
    w_blocks: jax.Array,  # [nnzb, bk, bn] in column-panel-major order
    w_brow: jax.Array,  # [nnzb] int32 (K-block index)
    w_bcol: jax.Array,  # [nnzb] int32 (N-block index), non-decreasing
    flags: jax.Array,  # [nnzb] int32 run flags from plan_bsr
    *,
    n: int,
    tm: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """y[M, N] = x @ W for block-sparse W. Absent column panels stay zero?

    No — absent column panels are never visited, so the wrapper requires the
    plan to cover every N panel (callers guarantee ≥1 block per column panel;
    ``ops.sparse_dense_matmul`` pads a zero block for empty panels).
    """
    m, k = x.shape
    nnzb, bk, bn = w_blocks.shape
    grid = (m // tm, nnzb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, bk), lambda i, t, br, bc, fl: (i, br[t])),
            pl.BlockSpec((1, bk, bn), lambda i, t, br, bc, fl: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda i, t, br, bc, fl: (i, bc[t])),
        scratch_shapes=[pltpu.VMEM((tm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(w_brow, w_bcol, flags, x, w_blocks)
