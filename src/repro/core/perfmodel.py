"""Performance / STUF / energy models (paper Sec. 4.2.4, 5.3.2, 5.3.3).

The paper measures wall-clock and power on an Arria 10 GX FPGA, a Xeon
E5-2637 v3 and a GTX TITAN X. This container is CPU-only, so (DESIGN.md
Sec. 8) the reproduction strategy is:

* CPU numbers: *measured* here with our implementations (numpy Gustavson =
  the MKL analogue, plus scipy's SpGEMM).
* FPGA numbers: *modeled* — paper Eq. 2 R = N_Ops/(F · 2·SW·NUM_PE · U)
  driven either by published STUF (Table 8) or by cycle counts from the
  faithful ``FSpGEMMSimulator``.
* Paper's published Tables 7/8/9 are embedded verbatim for comparison, and
  the benchmark output reports measured-vs-paper ratios.
* TPU numbers: roofline-modeled from the Pallas kernel's traffic/flop
  counts (the §Roofline methodology applied to the SpGEMM kernel itself).

STUF (spatial-temporal utilization factor):  U = N_Ops / (F · P · R)
with P = FLOPs available per cycle (paper Sec. 5.3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

__all__ = [
    "DeviceModel",
    "CPU_XEON_E5_2637",
    "GPU_TITAN_X",
    "FPGA_ARRIA10",
    "TPU_V5E_CHIP",
    "stuf",
    "runtime_from_stuf",
    "energy",
    "spgemm_schedule_traffic",
    "spgemm_grid_step_vmem",
    "TPU_VMEM_BYTES",
    "roofline_seconds",
    "PAPER_TABLE7_MS",
    "PAPER_TABLE8_STUF",
    "PAPER_TABLE9_J",
    "PAPER_MATRICES",
]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    clock_Hz: float  # F
    parallelism: float  # P: FLOPs per cycle available
    avg_power_W: float  # average power during SpGEMM (paper-implied)
    mem_bandwidth: float = 0.0  # bytes/s (0 = unknown; roofline helpers
    # then treat the device as compute-bound only)

    @property
    def peak_flops(self) -> float:
        return self.clock_Hz * self.parallelism


# Paper Sec. 5.3.2: CPU = 2 sockets x 4 cores x 32 FLOPs/cycle @ 3.5 GHz;
# E5-2637 v3 is 4-channel DDR4-2133 per socket: ~68 GB/s.
CPU_XEON_E5_2637 = DeviceModel(
    "xeon-e5-2637v3", 3.5e9, 256.0, 128.0, mem_bandwidth=68e9
)
# GPU: 3072 CUDA cores (Table 5; Sec. 5.3.2's 3,584 is a typo), 2 FLOPs/cycle
# @ 1.0 GHz; 336 GB/s GDDR5.
GPU_TITAN_X = DeviceModel(
    "gtx-titan-x", 1.0e9, 6144.0, 160.0, mem_bandwidth=336e9
)
# FPGA: SW*NUM_PE = 512 DSPs busy, 2 FLOPs/cycle each @ 236 MHz; the paper's
# STUF normalizes by all 1,518 DSPs. avg power implied by Table 7/9: ~18.5 W.
# Bandwidth is the paper's C1 = 15 GB/s DDR.
FPGA_ARRIA10 = DeviceModel(
    "arria10-gx", 236e6, 2 * 1518.0, 18.5, mem_bandwidth=15e9
)
# TPU v5e-class single chip (roofline constants from the brief).
TPU_V5E_CHIP = DeviceModel(
    "tpu-v5e", 940e6, 197e12 / 940e6, 170.0, mem_bandwidth=819e9
)


def stuf(n_ops: float, device: DeviceModel, runtime_s: float) -> float:
    """U = N_Ops / (F · P · R)   (paper Sec. 5.3.2)."""
    if runtime_s <= 0:
        return 0.0
    return n_ops / (device.peak_flops * runtime_s)


def runtime_from_stuf(n_ops: float, device: DeviceModel, u: float) -> float:
    """R = N_Ops / (F · P · U)   (paper Eq. 2 generalized)."""
    return n_ops / (device.peak_flops * u)


def energy(runtime_s: float, device: DeviceModel) -> float:
    """E = R · avg power (paper Sec. 5.3.3)."""
    return runtime_s * device.avg_power_W


def spgemm_schedule_traffic(
    *,
    num_triples: int,
    nnzb_a: int,
    b_fetches: int,
    n_panels: int,
    tile,
    group: int,
    dtype_bytes: int = 4,
) -> Dict[str, float]:
    """FLOP and streamed-byte counts of one scheduled block-Gustavson
    numeric phase, from the plan report's symbolic counters.

    Per triple the kernel runs a dense (bm x bk) @ (bk x bn) MAC —
    ``2·bm·bk·bn`` FLOPs. Traffic is the packed A blocks streamed once
    (``nnzb_a·bm·bk``), every scheduled B-tile fetch (``b_fetches·bk·bn``
    — the OMAR-reduced count, the paper's Sec. 4.2.2 win), and the C
    accumulator panels written out (``n_panels·group·bm·bn``).
    """
    bm, bk, bn = (int(t) for t in tile)
    flops = 2.0 * float(num_triples) * bm * bk * bn
    bytes_streamed = float(dtype_bytes) * (
        float(nnzb_a) * bm * bk
        + float(b_fetches) * bk * bn
        + float(n_panels) * group * bm * bn
    )
    return {"flops": flops, "bytes": bytes_streamed}


# Per-core VMEM capacity the Pallas kernels pipeline through (TPU v4/v5e
# class; see the accelerator guide). The kernel lint budgets grid-step
# working sets against this.
TPU_VMEM_BYTES = 16 << 20


def spgemm_grid_step_vmem(
    *,
    tile,
    group: int,
    dtype_bytes: int = 4,
    double_buffered: bool = True,
) -> float:
    """Per-grid-step VMEM working set of the scheduled Pallas kernel.

    Each grid step holds one A block (``bm x bk``), one B block
    (``bk x bn``), and one output panel (``group*bm x bn``) in VMEM —
    the same three block objects :func:`spgemm_schedule_traffic` counts
    stream traffic for, sized per step instead of per schedule. Pallas
    pipelines HBM copies against compute, so the resident set is double
    the single-step footprint (``double_buffered=True``, the default the
    kernels compile with). An oversized (tile, group) fails compilation
    or silently spills; :func:`repro.analysis.kernel_lint.
    lint_plan_kernel_specs` budgets this number against
    :data:`TPU_VMEM_BYTES` *before* any compile.
    """
    bm, bk, bn = (int(t) for t in tile)
    per_step = bm * bk + bk * bn + group * bm * bn
    return float(per_step) * dtype_bytes * (2 if double_buffered else 1)


def roofline_seconds(
    flops: float, bytes_streamed: float, device: DeviceModel
) -> float:
    """Roofline runtime estimate: max of the compute and memory floors.

    This is the model side of the autotuner's two-stage search
    (``repro.spgemm.autotune``): absolute seconds are host-dependent, but
    the *ordering* over candidate (tile, group) configs is what prunes
    the grid before measured probes. Devices with unknown bandwidth
    (``mem_bandwidth == 0``) rank by compute alone."""
    t = flops / device.peak_flops
    if device.mem_bandwidth > 0:
        t = max(t, bytes_streamed / device.mem_bandwidth)
    return t


PAPER_MATRICES = [
    "poisson3Da",
    "2cubes_sphere",
    "filter3D",
    "cage12",
    "scircuit",
    "mac_econ_fwd500",
    "offshore",
    "webbase-1M",
]

# Paper Table 7: runtime in ms (MKL CPU, cuSPARSE GPU, FSpGEMM FPGA).
PAPER_TABLE7_MS: Dict[str, Dict[str, float]] = {
    "poisson3Da": {"mkl": 27, "cusparse": 8, "fspgemm": 5},
    "2cubes_sphere": {"mkl": 21, "cusparse": 9, "fspgemm": 9},
    "filter3D": {"mkl": 44, "cusparse": 25, "fspgemm": 42},
    "cage12": {"mkl": 147, "cusparse": 46, "fspgemm": 15},
    "scircuit": {"mkl": 32, "cusparse": 14, "fspgemm": 6},
    "mac_econ_fwd500": {"mkl": 36, "cusparse": 11, "fspgemm": 7},
    "offshore": {"mkl": 71, "cusparse": 30, "fspgemm": 23},
    "webbase-1M": {"mkl": 181, "cusparse": 57, "fspgemm": 25},
}

# Paper Table 8: STUF.
PAPER_TABLE8_STUF: Dict[str, Dict[str, float]] = {
    "poisson3Da": {"mkl": 4.7e-4, "cusparse": 2.4e-4, "fspgemm": 3.4e-3},
    "2cubes_sphere": {"mkl": 1.4e-3, "cusparse": 5.0e-4, "fspgemm": 4.3e-3},
    "filter3D": {"mkl": 2.1e-3, "cusparse": 5.6e-4, "fspgemm": 2.9e-3},
    "cage12": {"mkl": 2.6e-4, "cusparse": 1.2e-4, "fspgemm": 3.2e-3},
    "scircuit": {"mkl": 2.9e-4, "cusparse": 1.0e-4, "fspgemm": 2.0e-3},
    "mac_econ_fwd500": {"mkl": 2.3e-4, "cusparse": 1.1e-4, "fspgemm": 1.5e-3},
    "offshore": {"mkl": 1.2e-4, "cusparse": 4.1e-5, "fspgemm": 4.6e-4},
    "webbase-1M": {"mkl": 4.2e-4, "cusparse": 2.0e-4, "fspgemm": 3.9e-3},
}

# Paper Table 9: energy in J.
PAPER_TABLE9_J: Dict[str, Dict[str, float]] = {
    "poisson3Da": {"mkl": 3.46, "cusparse": 1.31, "fspgemm": 0.09},
    "2cubes_sphere": {"mkl": 3.11, "cusparse": 1.22, "fspgemm": 0.17},
    "filter3D": {"mkl": 6.03, "cusparse": 3.43, "fspgemm": 0.79},
    "cage12": {"mkl": 16.91, "cusparse": 6.44, "fspgemm": 0.29},
    "scircuit": {"mkl": 4.35, "cusparse": 1.83, "fspgemm": 0.12},
    "mac_econ_fwd500": {"mkl": 5.22, "cusparse": 1.43, "fspgemm": 0.13},
    "offshore": {"mkl": 9.80, "cusparse": 3.99, "fspgemm": 0.44},
    "webbase-1M": {"mkl": 15.93, "cusparse": 9.86, "fspgemm": 0.47},
}
