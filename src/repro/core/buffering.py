"""The paper's data buffering scheme and its OMAR metric (Sec. 4.1, Eq. 1).

``omar`` implements Eq. 1 exactly:

    OMAR(%) = Σ_{v ∈ V} (nnz(A(v)) − 1) / nnz(A) × 100

where a CSV vector ``v`` is the set of nonzeros of A sharing one column
inside one NUM_PE-row group — all of which share a single fetched row of B.

``b_fetch_trace``/``omar_from_trace`` re-derive the same number from an
actual fetch trace (each CSV vector triggers exactly one B-row fetch), which
is the property the FPGA buffer enforces and the Pallas kernel reproduces
through block-index revisit elision — tested in tests/test_buffering.py.

``block_omar`` is the BCSV tile-granularity analogue used by the TPU path.
"""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

from repro.sparse.convert import to_csv
from repro.sparse.formats import BCSV, CSR, CSV

__all__ = [
    "omar",
    "omar_from_trace",
    "b_fetch_trace",
    "block_omar",
    "block_b_fetch_trace",
]


def omar(a: Union[CSR, CSV, np.ndarray], num_pe: int) -> float:
    """Off-chip memory access reduction percentage (paper Eq. 1)."""
    csv = a if isinstance(a, CSV) and a.num_pe == num_pe else to_csv(a, num_pe)
    nnz = csv.nnz
    if nnz == 0:
        return 0.0
    vid = csv.vector_id()
    num_vectors = int(vid[-1]) + 1
    # Σ_v (nnz(A(v)) − 1)  ==  nnz(A) − #vectors
    saved = nnz - num_vectors
    return 100.0 * saved / nnz


def b_fetch_trace(a: Union[CSR, CSV, np.ndarray], num_pe: int) -> np.ndarray:
    """Sequence of B-row indices fetched from off-chip memory when the
    buffering scheme of Sec. 4.1 processes A in CSV order.

    One fetch per CSV vector (the buffered row is shared by all PEs); the
    naive Gustavson scheme fetches once per A-nonzero instead.
    """
    csv = a if isinstance(a, CSV) and a.num_pe == num_pe else to_csv(a, num_pe)
    if csv.nnz == 0:
        return np.zeros(0, dtype=np.int64)
    vid = csv.vector_id()
    first_of_vector = np.empty(csv.nnz, dtype=bool)
    first_of_vector[0] = True
    first_of_vector[1:] = vid[1:] != vid[:-1]
    return csv.col_ind[first_of_vector].astype(np.int64)


def omar_from_trace(a: Union[CSR, CSV, np.ndarray], num_pe: int) -> float:
    """OMAR re-derived from the actual fetch trace (must equal Eq. 1)."""
    csv = a if isinstance(a, CSV) and a.num_pe == num_pe else to_csv(a, num_pe)
    nnz = csv.nnz
    if nnz == 0:
        return 0.0
    fetches = b_fetch_trace(csv, num_pe).shape[0]
    return 100.0 * (nnz - fetches) / nnz


def block_omar(a: BCSV) -> float:
    """Tile-granularity OMAR for the BCSV/TPU path.

    A fetched B block-row is reused by consecutive A tiles sharing ``bcol``
    inside one block-row group — the Pallas pipeline elides the HBM→VMEM
    copy whenever the B-operand block index is unchanged between steps.
    """
    if a.nnzb == 0:
        return 0.0
    g = a.group_of().astype(np.int64)
    c = a.bcol.astype(np.int64)
    change = np.empty(a.nnzb, dtype=bool)
    change[0] = True
    change[1:] = (g[1:] != g[:-1]) | (c[1:] != c[:-1])
    fetches = int(change.sum())
    return 100.0 * (a.nnzb - fetches) / a.nnzb


def block_b_fetch_trace(a: BCSV) -> np.ndarray:
    """B block-row ids fetched in kernel grid order (copy-elision model)."""
    if a.nnzb == 0:
        return np.zeros(0, dtype=np.int64)
    g = a.group_of().astype(np.int64)
    c = a.bcol.astype(np.int64)
    change = np.empty(a.nnzb, dtype=bool)
    change[0] = True
    change[1:] = (g[1:] != g[:-1]) | (c[1:] != c[:-1])
    return c[change]
