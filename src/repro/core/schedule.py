"""Host symbolic phase: static block schedules for the Pallas kernels.

The paper's host program converts A to CSV once (Sec. 4.3); the FPGA kernel
then streams it with data-dependent control flow (FIFOs, RESET tokens).
TPUs have no data-dependent grids, so the host side here additionally runs
the *symbolic* half of Gustavson's algorithm at block granularity: it
computes the output block structure and flattens the whole computation into
a static stream of (a_slot, b_slot, panel, sub_row) matmul triples.

Triple ordering = the paper's schedule, lifted to tiles:

    for each block-row group g (NUM_PE analogue):        # CSV row groups
      for each output block-column j of the group:       # one C panel
        for each inner block k with A(g-rows, k)≠0 ∧ B(k, j)≠0:
          fetch B(k, j) once                             # shared buffer
          for each row r in group with A(r, k)≠0:        # PEs in parallel
            C_panel(g, j)[r] += A(r, k) · B(k, j)

Consecutive triples share ``b_slot`` exactly when the paper's buffering
scheme would share a fetched B row, and every C panel is visited in one
contiguous run (safe Pallas output revisiting).

The symbolic phase also precomputes C's *output-scatter structure*
(:class:`AssemblyMap`, built by :func:`build_assembly_map`): the CSR pattern
of C at element granularity plus a flat gather map from the kernel's output
panels into packed CSR value order. With it, the numeric phase needs no
data-dependent ``nonzero`` scan — assembly is one static device gather
(Nagasaka et al. 2018: the symbolic phase can precompute all output
accumulation structure, leaving the numeric phase pure
gather-multiply-scatter).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.sparse.formats import BCSR, BCSV

__all__ = [
    "AssemblyMap",
    "ScheduleShard",
    "SpGEMMSchedule",
    "assembly_from_arrays",
    "assembly_to_arrays",
    "build_assembly_map",
    "build_compact_map",
    "build_spgemm_schedule",
    "partition_spgemm_schedule",
    "schedule_from_arrays",
    "schedule_to_arrays",
    "shard_from_group_range",
    "shards_from_bounds",
    "shards_to_bounds",
    "stack_shard_schedules",
    "structural_product_pattern",
]


@dataclasses.dataclass
class SpGEMMSchedule:
    """Flat static schedule consumed by kernels/gustavson_spgemm.py."""

    # Per-triple arrays, length T (padded to T_pad by the kernel wrapper).
    a_slot: np.ndarray  # index into packed A blocks [nnzb_a, bm, bk]
    b_slot: np.ndarray  # index into packed B blocks [nnzb_b, bk, bn]
    panel: np.ndarray  # index into output panels [n_panels, G*bm, bn]
    sub_row: np.ndarray  # block-row within the group (0..G-1)
    start: np.ndarray  # 1 iff first triple of its panel (zero the acc)
    # Panel -> C-block mapping (host-side scatter after the kernel).
    panel_group: np.ndarray  # [n_panels] block-row group id
    panel_bcol: np.ndarray  # [n_panels] C block-column
    # C block structure (symbolic Gustavson result).
    c_brow: np.ndarray  # [nnzb_c]
    c_bcol: np.ndarray  # [nnzb_c]
    group: int
    grid_m: int  # A block-rows
    grid_n: int  # B block-cols
    grid_k: int

    @property
    def num_triples(self) -> int:
        return int(self.a_slot.shape[0])

    @property
    def n_panels(self) -> int:
        return int(self.panel_group.shape[0])

    @property
    def nnzb_c(self) -> int:
        return int(self.c_brow.shape[0])

    def b_fetches(self) -> int:
        """Number of B-block HBM fetches under revisit elision."""
        if self.num_triples == 0:
            return 0
        change = np.empty(self.num_triples, dtype=bool)
        change[0] = True
        change[1:] = self.b_slot[1:] != self.b_slot[:-1]
        return int(change.sum())

    def block_omar(self) -> float:
        """Scheduled-level OMAR: saved B fetches / naive fetches (Eq. 1)."""
        t = self.num_triples
        if t == 0:
            return 0.0
        return 100.0 * (t - self.b_fetches()) / t


def build_spgemm_schedule(a: BCSV, b: BCSR) -> SpGEMMSchedule:
    """Symbolic block-Gustavson: structure of C + the triple schedule."""
    bm, bk = a.block_shape
    bk2, bn = b.block_shape
    if bk != bk2:
        raise ValueError(f"block inner dims mismatch: {a.block_shape} vs {b.block_shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"matrix inner dims mismatch: {a.shape} vs {b.shape}")
    grid_m, grid_k = a.grid
    grid_n = b.grid[1]
    group = a.group

    # Index A blocks by (group, k) -> [(sub_row, slot)...], preserving BCSV
    # (vector-major) order inside each group.
    a_by_group_k: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for slot in range(a.nnzb):
        g = int(a.brow[slot]) // group
        k = int(a.bcol[slot])
        a_by_group_k.setdefault((g, k), []).append((int(a.brow[slot]) - g * group, slot))

    # Index B blocks by (k, j) -> slot.
    b_slot_of: Dict[Tuple[int, int], int] = {}
    for kb in range(b.indptr.shape[0] - 1):
        for s in range(int(b.indptr[kb]), int(b.indptr[kb + 1])):
            b_slot_of[(kb, int(b.indices[s]))] = s

    n_groups = a.n_groups
    a_slots: List[int] = []
    b_slots: List[int] = []
    panels: List[int] = []
    sub_rows: List[int] = []
    starts: List[int] = []
    panel_group: List[int] = []
    panel_bcol: List[int] = []
    c_blocks: set = set()

    for g in range(n_groups):
        # ks present in this group, in ascending k (the CSV vector order).
        ks = sorted({k for (gg, k) in a_by_group_k if gg == g})
        if not ks:
            continue
        # Output block-columns reachable from this group: ∪_k cols(B(k,:)).
        js = sorted(
            {
                int(b.indices[s])
                for k in ks
                for s in range(int(b.indptr[k]), int(b.indptr[k + 1]))
            }
        )
        for j in js:
            first = True
            for k in ks:
                bs = b_slot_of.get((k, j))
                if bs is None:
                    continue
                for sub_row, a_s in a_by_group_k[(g, k)]:
                    a_slots.append(a_s)
                    b_slots.append(bs)
                    panels.append(len(panel_group))
                    sub_rows.append(sub_row)
                    starts.append(1 if first else 0)
                    first = False
                    c_blocks.add((g * group + sub_row, j))
            if not first:  # at least one triple was emitted for this panel
                panel_group.append(g)
                panel_bcol.append(j)

    c_sorted = sorted(c_blocks)
    c_brow = np.asarray([r for r, _ in c_sorted], np.int32)
    c_bcol = np.asarray([c for _, c in c_sorted], np.int32)
    return SpGEMMSchedule(
        a_slot=np.asarray(a_slots, np.int32),
        b_slot=np.asarray(b_slots, np.int32),
        panel=np.asarray(panels, np.int32),
        sub_row=np.asarray(sub_rows, np.int32),
        start=np.asarray(starts, np.int32),
        panel_group=np.asarray(panel_group, np.int32),
        panel_bcol=np.asarray(panel_bcol, np.int32),
        c_brow=c_brow,
        c_bcol=c_bcol,
        group=group,
        grid_m=grid_m,
        grid_n=grid_n,
        grid_k=grid_k,
    )


@dataclasses.dataclass
class AssemblyMap:
    """C's output-scatter structure, precomputed by the symbolic phase.

    The numeric phase produces panels ``[n_panels, group*bm, bn]``; this map
    turns them into CSR with one static gather —
    ``data = panels.reshape(-1)[gather]`` — so assembly is value-independent
    and jittable (no ``nonzero`` scan). The CSR pattern is *structural*:
    every element of every structurally nonzero C block (trimmed to the true
    ``shape``) is stored, including elements that compute to exact zero.
    """

    gather: np.ndarray  # [nnz] flat indices into panels.reshape(-1)
    indptr: np.ndarray  # [m + 1] int64 CSR row pointers
    indices: np.ndarray  # [nnz] int32 CSR column ids
    shape: Tuple[int, int]  # true (untrimmed-by-padding) C shape

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def nbytes(self) -> int:
        return self.gather.nbytes + self.indptr.nbytes + self.indices.nbytes


def build_assembly_map(
    schedule: SpGEMMSchedule,
    block_shape: Tuple[int, int],
    out_shape: Tuple[int, int],
) -> AssemblyMap:
    """Map kernel output panels to C's CSR, symbolically.

    ``block_shape`` is C's block shape ``(bm, bn)``; ``out_shape`` the true
    ``(m, n)`` (block grids are ceil-padded, so edge blocks may overhang —
    overhanging elements are structurally zero and dropped here, at plan
    time).
    """
    bm, bn = block_shape
    m, n = out_shape
    nb = schedule.nnzb_c
    if nb == 0 or bm == 0 or bn == 0:
        return AssemblyMap(
            np.zeros(0, np.int32), np.zeros(m + 1, np.int64),
            np.zeros(0, np.int32), (m, n),
        )
    g = schedule.group
    # Panel of each C block. Panels are emitted in ascending (group, bcol)
    # order by build_spgemm_schedule, so a searchsorted on the combined key
    # recovers the panel id; every C block has a panel by construction.
    pkey = schedule.panel_group.astype(np.int64) * schedule.grid_n \
        + schedule.panel_bcol
    cgrp = schedule.c_brow.astype(np.int64) // g
    ckey = cgrp * schedule.grid_n + schedule.c_bcol
    p_of = np.minimum(np.searchsorted(pkey, ckey), pkey.shape[0] - 1)
    if not np.array_equal(pkey[p_of], ckey):
        raise AssertionError("C block without a matching output panel")
    sub = schedule.c_brow.astype(np.int64) - cgrp * g
    # Per-block element coordinates and their flat panel offsets.
    rr = np.arange(bm, dtype=np.int64)[None, :, None]  # [1, bm, 1]
    cc = np.arange(bn, dtype=np.int64)[None, None, :]  # [1, 1, bn]
    rows = schedule.c_brow.astype(np.int64)[:, None, None] * bm + rr
    cols = schedule.c_bcol.astype(np.int64)[:, None, None] * bn + cc
    gather = (
        p_of[:, None, None] * (g * bm * bn)
        + (sub[:, None, None] * bm + rr) * bn
        + cc
    )
    shape3 = (nb, bm, bn)
    rows = np.broadcast_to(rows, shape3).reshape(-1)
    cols = np.broadcast_to(cols, shape3).reshape(-1)
    gather = gather.reshape(-1)
    keep = (rows < m) & (cols < n)
    if not keep.all():
        rows, cols, gather = rows[keep], cols[keep], gather[keep]
    # CSR order: row-major. Within one block-row, blocks are already
    # bcol-ascending, but one output row spans several blocks, so sort.
    order = np.lexsort((cols, rows))
    rows, cols, gather = rows[order], cols[order], gather[order]
    indptr = np.zeros(m + 1, np.int64)
    np.cumsum(np.bincount(rows, minlength=m), out=indptr[1:])
    flat_panels = schedule.n_panels * g * bm * bn
    gdtype = np.int32 if flat_panels <= np.iinfo(np.int32).max else np.int64
    return AssemblyMap(
        gather.astype(gdtype, copy=False), indptr,
        cols.astype(np.int32), (m, n),
    )


def structural_product_pattern(
    a_row: np.ndarray,
    a_col: np.ndarray,
    b_row: np.ndarray,
    b_col: np.ndarray,
    a_shape: Tuple[int, int],
    b_shape: Tuple[int, int],
) -> Tuple[np.ndarray, np.ndarray]:
    """Element-exact structural pattern of ``C = A @ B``.

    Pure symbolic Gustavson at element granularity: position ``(i, j)`` is
    in the result iff some ``k`` has ``A[i, k]`` and ``B[k, j]`` both
    structurally nonzero. Value-independent by construction — numeric
    cancellation keeps its (explicitly stored) slot, exactly like the
    block-structural pattern, just without block fill.

    Inputs are the operands' COO patterns in canonical row-major order
    (``B``'s row groups must be contiguous; ``sum_duplicates`` output
    qualifies). Returns ``(rows, cols)`` sorted strictly row-major —
    ``rows`` as int64, ``cols`` as int32 — ready for
    :func:`build_compact_map`.
    """
    m, k = int(a_shape[0]), int(a_shape[1])
    k2, n = int(b_shape[0]), int(b_shape[1])
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a_shape} x {b_shape}")
    a_row = np.asarray(a_row, np.int64)
    a_col = np.asarray(a_col, np.int64)
    b_col64 = np.asarray(b_col, np.int64)
    b_indptr = np.zeros(k + 1, np.int64)
    np.cumsum(np.bincount(np.asarray(b_row, np.int64), minlength=k),
              out=b_indptr[1:])
    counts = b_indptr[a_col + 1] - b_indptr[a_col]
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int32)
    # Expand every (i, k) against B's row k: the standard repeat/offset
    # expansion (one flat arange minus per-segment restart offsets).
    out_rows = np.repeat(a_row, counts)
    cum = np.cumsum(counts)
    offset = np.arange(total, dtype=np.int64) - np.repeat(cum - counts, counts)
    out_cols = b_col64[np.repeat(b_indptr[a_col], counts) + offset]
    key = np.unique(out_rows * n + out_cols)
    return key // n, (key % n).astype(np.int32)


def build_compact_map(
    assembly: AssemblyMap,
    rows: np.ndarray,
    cols: np.ndarray,
) -> AssemblyMap:
    """Element-exact (compacted) sibling of :func:`build_assembly_map`.

    ``assembly`` is the structural *block* map for the same schedule;
    ``(rows, cols)`` is C's element-exact pattern in strictly ascending
    row-major order (e.g. from :func:`structural_product_pattern`). Every
    compact position must exist in the block pattern — the compact map is
    a subset selection: its gather indices are the block map's gather at
    the surviving positions, so executing through it *is* the fused
    compaction (one static gather, no ``nonzero`` scan), and the
    exactly-once/pad-panel proofs inherit directly from the block map.

    Returns an :class:`AssemblyMap` whose CSR stores only the element-
    structural nonzeros (explicit zero *blocks'* fill is dropped; numeric
    cancellation within a structural element is kept).
    """
    m, n = assembly.shape
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    if rows.shape != cols.shape or rows.ndim != 1:
        raise ValueError(
            f"pattern arrays must be equal-length vectors, got "
            f"{rows.shape} / {cols.shape}"
        )
    nnz = int(rows.shape[0])
    indptr = np.zeros(m + 1, np.int64)
    if nnz == 0:
        return AssemblyMap(
            np.zeros(0, assembly.gather.dtype), indptr,
            np.zeros(0, np.int32), (m, n),
        )
    if (rows < 0).any() or (rows >= m).any() or (cols < 0).any() \
            or (cols >= n).any():
        raise ValueError(f"compact pattern indices outside {m}x{n}")
    key = rows * n + cols
    if (np.diff(key) <= 0).any():
        raise ValueError(
            "compact pattern must be strictly ascending row-major "
            "(canonical CSR order)"
        )
    np.cumsum(np.bincount(rows, minlength=m), out=indptr[1:])
    # Subset selection by searchsorted on the block map's (row, col) keys
    # — strictly ascending by build_assembly_map's lexsort, so equality at
    # the insertion point is exact membership.
    bkey = (
        np.repeat(np.arange(m, dtype=np.int64), np.diff(assembly.indptr))
        * n + assembly.indices.astype(np.int64)
    )
    pos = np.searchsorted(bkey, key)
    ok = pos < bkey.shape[0]
    if not ok.all() or not np.array_equal(bkey[np.minimum(
            pos, max(bkey.shape[0] - 1, 0))], key):
        raise ValueError(
            "compact pattern is not a subset of the structural block "
            "pattern: some element has no kernel output slot"
        )
    return AssemblyMap(
        assembly.gather[pos], indptr, cols.astype(np.int32), (m, n),
    )


# ---------------------------------------------------------------------------
# Flat-array codecs (plan persistence)
#
# The on-disk plan store (repro/spgemm/persist.py) holds nothing but named
# numpy arrays plus a JSON header, so every symbolic-phase artifact needs a
# lossless flat-array form. Codecs are *bitwise* round-trips: dtypes and
# shapes are preserved exactly, which is what lets a warm-restarted plan
# produce bit-identical results to a cold-built one.
# ---------------------------------------------------------------------------

_SCHEDULE_ARRAY_FIELDS = (
    "a_slot", "b_slot", "panel", "sub_row", "start",
    "panel_group", "panel_bcol", "c_brow", "c_bcol",
)
_SCHEDULE_DIM_FIELDS = ("group", "grid_m", "grid_n", "grid_k")


def schedule_to_arrays(
    schedule: SpGEMMSchedule, prefix: str = "sched."
) -> Dict[str, np.ndarray]:
    """:class:`SpGEMMSchedule` -> flat ``{name: ndarray}`` dict."""
    out = {prefix + f: getattr(schedule, f) for f in _SCHEDULE_ARRAY_FIELDS}
    out[prefix + "dims"] = np.asarray(
        [getattr(schedule, f) for f in _SCHEDULE_DIM_FIELDS], np.int64
    )
    return out


def schedule_from_arrays(
    arrays: Dict[str, np.ndarray], prefix: str = "sched."
) -> SpGEMMSchedule:
    """Inverse of :func:`schedule_to_arrays` (bitwise round-trip)."""
    dims = np.asarray(arrays[prefix + "dims"])
    if dims.shape != (len(_SCHEDULE_DIM_FIELDS),):
        raise ValueError(f"bad schedule dims: shape {dims.shape}")
    kwargs = {
        f: np.asarray(arrays[prefix + f]) for f in _SCHEDULE_ARRAY_FIELDS
    }
    kwargs.update(zip(_SCHEDULE_DIM_FIELDS, (int(d) for d in dims)))
    return SpGEMMSchedule(**kwargs)


def assembly_to_arrays(
    assembly: AssemblyMap, prefix: str = "asm."
) -> Dict[str, np.ndarray]:
    """:class:`AssemblyMap` -> flat ``{name: ndarray}`` dict."""
    return {
        prefix + "gather": assembly.gather,
        prefix + "indptr": assembly.indptr,
        prefix + "indices": assembly.indices,
        prefix + "shape": np.asarray(assembly.shape, np.int64),
    }


def assembly_from_arrays(
    arrays: Dict[str, np.ndarray], prefix: str = "asm."
) -> AssemblyMap:
    """Inverse of :func:`assembly_to_arrays` (bitwise round-trip)."""
    shape = np.asarray(arrays[prefix + "shape"])
    if shape.shape != (2,):
        raise ValueError(f"bad assembly shape: {shape!r}")
    return AssemblyMap(
        np.asarray(arrays[prefix + "gather"]),
        np.asarray(arrays[prefix + "indptr"]),
        np.asarray(arrays[prefix + "indices"]),
        (int(shape[0]), int(shape[1])),
    )


@dataclasses.dataclass
class ScheduleShard:
    """One device's slice of a partitioned :class:`SpGEMMSchedule`.

    ``schedule`` is a fully self-contained shard-local schedule: its panel
    ids, block-row groups, C block-rows, and A slots are all rebased to the
    shard, so it can be executed (and its :class:`AssemblyMap` built)
    exactly like an unsharded schedule. The ``*_lo``/``*_hi`` ranges map
    shard-local objects back to the parent schedule's coordinates — they
    are contiguous by construction, which is what makes the final C a
    single concatenation of per-shard CSR segments.
    """

    schedule: SpGEMMSchedule  # shard-local ids throughout
    group_lo: int  # [group_lo, group_hi) parent block-row groups
    group_hi: int
    triple_lo: int  # [triple_lo, triple_hi) parent triples
    triple_hi: int
    panel_lo: int  # [panel_lo, panel_hi) parent panels
    panel_hi: int
    a_lo: int  # [a_lo, a_hi) parent packed-A slots
    a_hi: int

    @property
    def num_triples(self) -> int:
        return self.triple_hi - self.triple_lo

    @property
    def n_panels(self) -> int:
        return self.panel_hi - self.panel_lo


def _balanced_boundaries(counts: np.ndarray, n_parts: int) -> np.ndarray:
    """Contiguous partition of ``counts`` into ``n_parts`` segments
    minimizing the max segment sum (binary search on capacity + greedy
    fill). Returns ``n_parts + 1`` boundaries; trailing segments may be
    empty when there are fewer nonempty groups than parts."""
    counts = np.asarray(counts, np.int64)
    n = counts.shape[0]
    if n == 0 or n_parts <= 1:
        return np.concatenate(
            [np.zeros(1, np.int64), np.full(n_parts, n, np.int64)]
        )
    prefix = np.concatenate([np.zeros(1, np.int64), np.cumsum(counts)])
    total = int(prefix[-1])

    def parts_needed(cap: int) -> int:
        """Greedy: number of <=cap segments required (inf if impossible)."""
        used, start = 0, 0
        while start < n:
            # Largest end with sum(start..end) <= cap.
            end = int(np.searchsorted(prefix, prefix[start] + cap, "right")) - 1
            if end <= start:  # single group exceeds cap
                return n_parts + 1
            used += 1
            start = end
        return used

    lo = max(int(counts.max(initial=0)), -(-total // n_parts))
    hi = max(total, lo)
    while lo < hi:
        mid = (lo + hi) // 2
        if parts_needed(mid) <= n_parts:
            hi = mid
        else:
            lo = mid + 1
    cap = lo
    # Greedy fill at the optimal cap. cap >= counts.max() guarantees each
    # segment advances, and cap feasibility guarantees <= n_parts segments
    # cover everything; exhausted trailing parts stay empty (ragged /
    # over-provisioned meshes).
    bounds = [0]
    start = 0
    for _ in range(n_parts):
        end = int(np.searchsorted(prefix, prefix[start] + cap, "right")) - 1
        bounds.append(end)
        start = end
    assert bounds[-1] == n, "balanced partition failed to cover all groups"
    return np.asarray(bounds, np.int64)


def partition_spgemm_schedule(
    schedule: SpGEMMSchedule, n_shards: int
) -> List[ScheduleShard]:
    """Split one schedule into ``n_shards`` shard-local schedules.

    The cut points are block-row *group* boundaries (a group's output rows
    live in exactly one shard, so C is a concatenation of per-shard row
    ranges), chosen to balance **triple count** — the numeric-phase work
    unit — not panel count. Because ``build_spgemm_schedule`` emits triples,
    panels, A slots (BCSV is group-major), and C blocks all in ascending
    group order, every shard is a contiguous slice of each parent array;
    the slices are rebased so each shard's schedule stands alone.

    Shards may be empty (``n_shards`` > nonempty groups): they get
    zero-length schedules and contribute nothing to C.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    g = schedule.group
    n_groups = -(-schedule.grid_m // g) if schedule.grid_m else 0
    # Per-triple parent group id; triples are emitted group-ascending.
    g_of_t = schedule.panel_group[schedule.panel]
    counts = np.bincount(g_of_t, minlength=max(n_groups, 1))[:max(n_groups, 1)]
    bounds = _balanced_boundaries(counts, n_shards)
    return shards_from_bounds(schedule, bounds)


def shard_from_group_range(
    schedule: SpGEMMSchedule, g_lo: int, g_hi: int
) -> ScheduleShard:
    """The shard owning parent block-row groups ``[g_lo, g_hi)``.

    Everything beyond the group range is *derived* from the parent schedule
    (triple/panel/C-block spans by searchsorted on the group-ascending
    parent arrays, the A-slot span from the triples themselves), which is
    what makes the group boundaries alone a complete serialization of a
    partition: :func:`shards_from_bounds` rebuilds bitwise-identical
    shards from an ``[n_shards + 1]`` bounds vector.
    """
    g = schedule.group
    g_lo, g_hi = int(g_lo), int(g_hi)
    g_of_t = schedule.panel_group[schedule.panel]
    t_lo, t_hi = np.searchsorted(g_of_t, [g_lo, g_hi])
    p_lo, p_hi = np.searchsorted(schedule.panel_group, [g_lo, g_hi])
    c_lo, c_hi = np.searchsorted(schedule.c_brow, [g_lo * g, g_hi * g])
    t_lo, t_hi, p_lo, p_hi, c_lo, c_hi = map(
        int, (t_lo, t_hi, p_lo, p_hi, c_lo, c_hi))
    if t_hi > t_lo:
        # BCSV packs blocks group-major, so the slots this shard's
        # triples touch form a contiguous parent range.
        a_lo = int(schedule.a_slot[t_lo:t_hi].min())
        a_hi = int(schedule.a_slot[t_lo:t_hi].max()) + 1
    else:
        a_lo = a_hi = 0
    grid_m_local = max(0, min(schedule.grid_m, g_hi * g) - g_lo * g)
    local = SpGEMMSchedule(
        a_slot=schedule.a_slot[t_lo:t_hi] - a_lo,
        b_slot=schedule.b_slot[t_lo:t_hi].copy(),
        panel=schedule.panel[t_lo:t_hi] - p_lo,
        sub_row=schedule.sub_row[t_lo:t_hi].copy(),
        start=schedule.start[t_lo:t_hi].copy(),
        panel_group=schedule.panel_group[p_lo:p_hi] - g_lo,
        panel_bcol=schedule.panel_bcol[p_lo:p_hi].copy(),
        c_brow=schedule.c_brow[c_lo:c_hi] - g_lo * g,
        c_bcol=schedule.c_bcol[c_lo:c_hi].copy(),
        group=g,
        grid_m=grid_m_local,
        grid_n=schedule.grid_n,
        grid_k=schedule.grid_k,
    )
    return ScheduleShard(
        schedule=local,
        group_lo=g_lo, group_hi=g_hi,
        triple_lo=t_lo, triple_hi=t_hi,
        panel_lo=p_lo, panel_hi=p_hi,
        a_lo=a_lo, a_hi=a_hi,
    )


def shards_to_bounds(shards: List[ScheduleShard]) -> np.ndarray:
    """Partition -> its ``[n_shards + 1]`` group-boundary vector (the
    shards' flat-array serialization; see :func:`shard_from_group_range`)."""
    if not shards:
        return np.zeros(1, np.int64)
    return np.asarray(
        [shards[0].group_lo] + [s.group_hi for s in shards], np.int64
    )


def shards_from_bounds(
    schedule: SpGEMMSchedule, bounds: np.ndarray
) -> List[ScheduleShard]:
    """Rebuild a partition from its group-boundary vector.

    Boundaries must be non-decreasing and cover all groups; anything else
    (a stale or foreign persistence payload) raises rather than silently
    mis-slicing."""
    bounds = np.asarray(bounds, np.int64)
    if bounds.ndim != 1 or bounds.shape[0] < 2:
        raise ValueError(f"bad shard bounds: {bounds!r}")
    if (np.diff(bounds) < 0).any() or int(bounds[0]) != 0:
        raise ValueError(f"shard bounds not a partition: {bounds!r}")
    n_groups = -(-schedule.grid_m // schedule.group) if schedule.grid_m else 0
    if schedule.num_triples and int(bounds[-1]) < n_groups:
        raise ValueError(
            f"shard bounds cover {int(bounds[-1])} of {n_groups} groups"
        )
    return [
        shard_from_group_range(schedule, bounds[i], bounds[i + 1])
        for i in range(bounds.shape[0] - 1)
    ]


def stack_shard_schedules(
    shards: Sequence[ScheduleShard], t_max: int, p_max: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-shard triple schedules into padded ``[n_shards, t_max]``
    arrays (the sharded executor's device-resident schedule constants).

    Returns ``(a_slot, b_slot, panel, sub_row, start)``. Padding triples
    execute a real (block 0) x (block 0) matmul into the dummy panel
    ``p_max`` — which no assembly gather reads — with ``start = 1`` so, on
    the Pallas path, each pad zeroes the dummy accumulator before writing
    (the same dummy-panel convention as
    :func:`repro.kernels.gustavson_spgemm.pad_schedule_arrays`, applied
    per shard). The ``start`` row makes every stacked shard schedule a
    complete standalone Pallas schedule; jnp consumers simply ignore it.
    """
    s = len(shards)
    a_slot = np.zeros((s, t_max), np.int32)
    b_slot = np.zeros((s, t_max), np.int32)
    panel = np.full((s, t_max), p_max, np.int32)
    sub_row = np.zeros((s, t_max), np.int32)
    start = np.ones((s, t_max), np.int32)
    for i, sh in enumerate(shards):
        t = sh.num_triples
        a_slot[i, :t] = sh.schedule.a_slot
        b_slot[i, :t] = sh.schedule.b_slot
        panel[i, :t] = sh.schedule.panel
        sub_row[i, :t] = sh.schedule.sub_row
        start[i, :t] = sh.schedule.start
    return a_slot, b_slot, panel, sub_row, start
