"""Analytical architectural-parameter models (paper Sec. 4.2.4).

FPGA side (paper-faithful): runtime R = N_Ops / (F · SW · NUM_PE · U);
subject to bandwidth  f1(SW) = sizeof(float)·SW·F ≤ C1
and logic              f2(SW, NUM_PE) = β·SW·NUM_PE ≤ C2,
with the paper's closed-form optimum
    SW      = ceil(C1 / (sizeof(float)·F))
    NUM_PE  = ceil(C2 / (β·SW))
validated to reproduce the published SW=16, NUM_PE=32 on Arria 10 GX.

TPU side (hardware adaptation, DESIGN.md Sec. 2): the same two-constraint
structure re-targeted at tile shapes — the bandwidth constraint bounds the
streaming width (lane-aligned bn), the capacity constraint (VMEM instead of
logic) bounds the row-group panel G·bm·bn. ``tpu_tile_params`` returns MXU-
aligned (bm, bk, bn, G) maximizing modeled throughput.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = [
    "FPGASpec",
    "ARRIA10_GX",
    "derive_fpga_params",
    "fpga_runtime_model",
    "TPUSpec",
    "TPU_V5E",
    "tpu_tile_params",
]


# ---------------------------------------------------------------------------
# FPGA model (paper-faithful)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGASpec:
    """Board constants (paper Table 5 for Arria 10 GX)."""

    name: str
    dsp_count: int
    mem_bandwidth_GBs: float  # C1
    clock_Hz: float  # F (achieved kernel clock)
    logic_capacity: float  # C2 (normalized logic units)
    beta: float  # fitted logic per unit parallelism (Sec. 4.2.4)


# The paper reports SW=16, NUM_PE=32 at 236 MHz with logic the binding
# constraint (97% logic @ 36% DSP).  β is back-fitted so the published
# optimum is reproduced: C2/β = SW·NUM_PE = 512.
ARRIA10_GX = FPGASpec(
    name="arria10-gx",
    dsp_count=1518,
    mem_bandwidth_GBs=15.0,
    clock_Hz=236e6,
    logic_capacity=512.0,
    beta=1.0,
)


def derive_fpga_params(spec: FPGASpec, float_bytes: int = 4) -> Tuple[int, int]:
    """Closed-form (SW, NUM_PE) per Sec. 4.2.4.

    SW = ceil(C1 / (sizeof(float) · F)); NUM_PE = ceil(C2 / (β · SW)).
    """
    sw = math.ceil(spec.mem_bandwidth_GBs * 1e9 / (float_bytes * spec.clock_Hz))
    num_pe = math.ceil(spec.logic_capacity / (spec.beta * sw))
    return sw, num_pe


def fpga_runtime_model(
    n_ops: int,
    spec: FPGASpec,
    sw: Optional[int] = None,
    num_pe: Optional[int] = None,
    stuf: float = 1.0,
) -> float:
    """Paper Eq. 2: R = N_Ops / (F · SW · NUM_PE · U)  [seconds].

    Note each DSP does a multiply+add per cycle, i.e. 2 FLOPs; N_Ops counts
    FLOPs, and SW·NUM_PE DSPs provide 2·SW·NUM_PE FLOPs/cycle. The paper
    lumps the 2 into U's definition of parallelism P; we follow the paper:
    P (computational parallelism) = 2 · #DSP-equivalents for STUF purposes,
    but Eq. 2 uses SW·NUM_PE MACs/cycle = 2·SW·NUM_PE FLOPs/cycle.
    """
    sw = sw if sw is not None else derive_fpga_params(spec)[0]
    num_pe = num_pe if num_pe is not None else derive_fpga_params(spec)[1]
    flops_per_cycle = 2.0 * sw * num_pe * stuf
    return n_ops / (spec.clock_Hz * flops_per_cycle)


# ---------------------------------------------------------------------------
# TPU re-target
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_bandwidth: float  # bytes/s per link
    vmem_bytes: int  # per-core VMEM budget
    mxu_dim: int  # systolic array edge (tile alignment)
    lane: int  # vector lane count (last-dim alignment)
    sublane: int  # second-minor alignment for fp32


TPU_V5E = TPUSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    vmem_bytes=16 * 2**20,  # ~16 MiB usable VMEM per core
    mxu_dim=128,
    lane=128,
    sublane=8,
)


def tpu_tile_params(
    spec: TPUSpec = TPU_V5E,
    dtype_bytes: int = 4,
    bn_target: Optional[int] = None,
    vmem_fraction: float = 0.7,
) -> Tuple[int, int, int, int]:
    """(bm, bk, bn, G) for the block-Gustavson kernels.

    Mirrors Sec. 4.2.4's two constraints:
      * streaming constraint — bn is the widest lane-aligned tile such that
        the B-stream bandwidth need ≤ HBM bandwidth at full MXU rate (on
        TPU this is trivially satisfied up to the VMEM bound, so bn is
        capacity-limited in practice, like the paper's SW was bandwidth-
        limited on the much slower DDR);
      * capacity constraint — the C accumulator panel (G·bm × bn), one B
        tile (bk × bn) and double buffers must fit ``vmem_fraction`` of
        VMEM; G (the NUM_PE analogue) is the largest group satisfying it.
    """
    bm = bk = spec.mxu_dim
    budget = spec.vmem_bytes * vmem_fraction
    bn = bn_target or spec.lane * 4  # 512 default: MXU-efficient N tile
    bn = max(spec.lane, (bn // spec.lane) * spec.lane)

    def footprint(g: int, bn_: int) -> float:
        acc = g * bm * bn_ * dtype_bytes  # C panel (single-buffered output)
        b_tile = 2 * bk * bn_ * dtype_bytes  # double-buffered B tile
        a_tile = 2 * bm * bk * dtype_bytes  # double-buffered A block
        return acc + b_tile + a_tile

    g = 1
    while footprint(g * 2, bn) <= budget:
        g *= 2
    # If even G=1 does not fit, shrink bn.
    while footprint(g, bn) > budget and bn > spec.lane:
        bn //= 2
    return bm, bk, bn, g
