"""Architectural-parameter models + measured calibration probes.

Analytical side (paper Sec. 4.2.4) — FPGA (paper-faithful): runtime
R = N_Ops / (F · SW · NUM_PE · U);
subject to bandwidth  f1(SW) = sizeof(float)·SW·F ≤ C1
and logic              f2(SW, NUM_PE) = β·SW·NUM_PE ≤ C2,
with the paper's closed-form optimum
    SW      = ceil(C1 / (sizeof(float)·F))
    NUM_PE  = ceil(C2 / (β·SW))
validated to reproduce the published SW=16, NUM_PE=32 on Arria 10 GX.

TPU side (hardware adaptation, DESIGN.md Sec. 2): the same two-constraint
structure re-targeted at tile shapes — the bandwidth constraint bounds the
streaming width (lane-aligned bn), the capacity constraint (VMEM instead of
logic) bounds the row-group panel G·bm·bn. ``tpu_tile_params`` returns MXU-
aligned (bm, bk, bn, G) maximizing modeled throughput.

Measured side: :func:`measure_chunk_knee` calibrates the batch-fusion
working-set budget (``repro.spgemm.executor._CHUNK_POLICY``) on the
*current* backend by sweeping plans of growing per-set working bytes and
timing fused vs. one-per-call batches. It is the documented re-measurement
path for the policy table (``python -m benchmarks.bench_chunk_knee``, or
the "Chunk-fusion knee" section of ``benchmarks/run.py``).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "FPGASpec",
    "ARRIA10_GX",
    "best_ms",
    "derive_fpga_params",
    "fpga_runtime_model",
    "interleaved_best_ms",
    "TPUSpec",
    "TPU_V5E",
    "measure_chunk_knee",
    "tpu_tile_params",
]


# ---------------------------------------------------------------------------
# FPGA model (paper-faithful)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGASpec:
    """Board constants (paper Table 5 for Arria 10 GX)."""

    name: str
    dsp_count: int
    mem_bandwidth_GBs: float  # C1
    clock_Hz: float  # F (achieved kernel clock)
    logic_capacity: float  # C2 (normalized logic units)
    beta: float  # fitted logic per unit parallelism (Sec. 4.2.4)


# The paper reports SW=16, NUM_PE=32 at 236 MHz with logic the binding
# constraint (97% logic @ 36% DSP).  β is back-fitted so the published
# optimum is reproduced: C2/β = SW·NUM_PE = 512.
ARRIA10_GX = FPGASpec(
    name="arria10-gx",
    dsp_count=1518,
    mem_bandwidth_GBs=15.0,
    clock_Hz=236e6,
    logic_capacity=512.0,
    beta=1.0,
)


def derive_fpga_params(spec: FPGASpec, float_bytes: int = 4) -> Tuple[int, int]:
    """Closed-form (SW, NUM_PE) per Sec. 4.2.4.

    SW = ceil(C1 / (sizeof(float) · F)); NUM_PE = ceil(C2 / (β · SW)).
    """
    sw = math.ceil(spec.mem_bandwidth_GBs * 1e9 / (float_bytes * spec.clock_Hz))
    num_pe = math.ceil(spec.logic_capacity / (spec.beta * sw))
    return sw, num_pe


def fpga_runtime_model(
    n_ops: int,
    spec: FPGASpec,
    sw: Optional[int] = None,
    num_pe: Optional[int] = None,
    stuf: float = 1.0,
) -> float:
    """Paper Eq. 2: R = N_Ops / (F · SW · NUM_PE · U)  [seconds].

    Note each DSP does a multiply+add per cycle, i.e. 2 FLOPs; N_Ops counts
    FLOPs, and SW·NUM_PE DSPs provide 2·SW·NUM_PE FLOPs/cycle. The paper
    lumps the 2 into U's definition of parallelism P; we follow the paper:
    P (computational parallelism) = 2 · #DSP-equivalents for STUF purposes,
    but Eq. 2 uses SW·NUM_PE MACs/cycle = 2·SW·NUM_PE FLOPs/cycle.
    """
    sw = sw if sw is not None else derive_fpga_params(spec)[0]
    num_pe = num_pe if num_pe is not None else derive_fpga_params(spec)[1]
    flops_per_cycle = 2.0 * sw * num_pe * stuf
    return n_ops / (spec.clock_Hz * flops_per_cycle)


# ---------------------------------------------------------------------------
# TPU re-target
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_bandwidth: float  # bytes/s per link
    vmem_bytes: int  # per-core VMEM budget
    mxu_dim: int  # systolic array edge (tile alignment)
    lane: int  # vector lane count (last-dim alignment)
    sublane: int  # second-minor alignment for fp32


TPU_V5E = TPUSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    vmem_bytes=16 * 2**20,  # ~16 MiB usable VMEM per core
    mxu_dim=128,
    lane=128,
    sublane=8,
)


def tpu_tile_params(
    spec: TPUSpec = TPU_V5E,
    dtype_bytes: int = 4,
    bn_target: Optional[int] = None,
    vmem_fraction: float = 0.7,
) -> Tuple[int, int, int, int]:
    """(bm, bk, bn, G) for the block-Gustavson kernels.

    Mirrors Sec. 4.2.4's two constraints:
      * streaming constraint — bn is the widest lane-aligned tile such that
        the B-stream bandwidth need ≤ HBM bandwidth at full MXU rate (on
        TPU this is trivially satisfied up to the VMEM bound, so bn is
        capacity-limited in practice, like the paper's SW was bandwidth-
        limited on the much slower DDR);
      * capacity constraint — the C accumulator panel (G·bm × bn), one B
        tile (bk × bn) and double buffers must fit ``vmem_fraction`` of
        VMEM; G (the NUM_PE analogue) is the largest group satisfying it.
    """
    bm = bk = spec.mxu_dim
    budget = spec.vmem_bytes * vmem_fraction
    bn = bn_target or spec.lane * 4  # 512 default: MXU-efficient N tile
    bn = max(spec.lane, (bn // spec.lane) * spec.lane)

    def footprint(g: int, bn_: int) -> float:
        acc = g * bm * bn_ * dtype_bytes  # C panel (single-buffered output)
        b_tile = 2 * bk * bn_ * dtype_bytes  # double-buffered B tile
        a_tile = 2 * bm * bk * dtype_bytes  # double-buffered A block
        return acc + b_tile + a_tile

    g = 1
    while footprint(g * 2, bn) <= budget:
        g *= 2
    # If even G=1 does not fit, shrink bn.
    while footprint(g, bn) > budget and bn > spec.lane:
        bn //= 2
    return bm, bk, bn, g


# ---------------------------------------------------------------------------
# Measured calibration: the batch-fusion knee
# ---------------------------------------------------------------------------

# (m, k, n, density, tile, group): element-plan cases whose per-set working
# bytes (4 * (n_panels*group + triples) * bm * bn, the batch_chunk basis)
# ramp from ~80 KiB to ~8 MiB — well under to well over every plausible
# CPU-cache knee, dense in the 0.25–3 MiB band where L2/L3 crossovers
# actually land, so the sweep brackets the fused-vs-split crossover.
_KNEE_CASES: Tuple[Tuple[int, int, int, float, int, int], ...] = (
    (64, 64, 64, 0.03, 16, 4),
    (96, 96, 96, 0.03, 16, 4),
    (128, 128, 128, 0.03, 16, 4),
    (160, 160, 160, 0.025, 16, 4),
    (192, 192, 192, 0.025, 16, 4),
    (224, 224, 224, 0.02, 16, 4),
    (256, 256, 256, 0.02, 16, 4),
    (320, 320, 320, 0.02, 16, 4),
)


def _random_int_coo(m: int, n: int, density: float, seed: int):
    """Small-integer float32 COO — values exact in f32, so fused/split
    paths are comparable bitwise as a calibration sanity check."""
    import numpy as np

    from repro.sparse.formats import COO

    rng = np.random.default_rng(seed)
    nnz = max(1, int(m * n * density))
    return COO(
        rng.integers(0, m, nnz),
        rng.integers(0, n, nnz),
        rng.integers(-3, 4, nnz).astype(np.float32),
        (m, n),
    ).sum_duplicates()


def best_ms(fn, repeats: int, timer=None) -> float:
    """Min-of-N wall time of ``fn`` in milliseconds.

    The shared probe primitive behind :func:`measure_chunk_knee` and the
    plan autotuner (``repro.spgemm.autotune``). ``timer`` is a
    ``time.perf_counter``-like callable, injectable so tuner tests run
    against a deterministic fake clock; it is called exactly twice per
    repeat (start, stop). The result is forced to host
    (``np.asarray``) inside the timed region so JAX's async dispatch
    cannot hide device time."""
    import numpy as np

    timer = timer if timer is not None else time.perf_counter
    best = float("inf")
    for _ in range(repeats):
        t0 = timer()
        np.asarray(fn())
        best = min(best, (timer() - t0) * 1e3)
    return best


def interleaved_best_ms(fns: Sequence, repeats: int, timer=None) -> List[float]:
    """Min-of-N over several probe thunks with **interleaved** repeats:
    round r times every ``fn`` once before round r+1 starts, so slow
    drift (thermal, background load) lands evenly on all candidates
    instead of biasing whichever ran last. Returns one best-ms per fn,
    in order. Timer calls: exactly two per (repeat, fn) measurement."""
    import numpy as np

    timer = timer if timer is not None else time.perf_counter
    best = [float("inf")] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = timer()
            np.asarray(fn())
            best[i] = min(best[i], (timer() - t0) * 1e3)
    return best


# Back-compat private alias (pre-autotune callers).
def _best_ms(fn, repeats: int) -> float:
    return best_ms(fn, repeats)


def measure_chunk_knee(
    batch: int = 8,
    repeats: int = 3,
    backend: str = "jnp",
    cases: Optional[Sequence[Tuple[int, int, int, float, int, int]]] = None,
    threshold: float = 1.0,
    seed: int = 0,
) -> Dict:
    """Measure the batch-fusion knee for ``executor._CHUNK_POLICY``.

    For each case the probe times a ``batch``-element value batch through
    the executor's ``run_batch`` two ways — **fused** (one device call for
    the whole batch) and **split** (one call per element, the ``chunk=1``
    policy) — bypassing ``batch_chunk`` so the policy under test does not
    steer its own calibration. The *knee* is the largest per-set working
    size (``4 * per_set_rows * bn`` bytes, the exact quantity
    ``batch_chunk`` compares against the policy budget) at which fusing
    still wins: above it the fused accumulator working set leaves the fast
    memory tier and per-set cost regresses.

    The smallest case additionally sweeps chunk sizes (1..batch) to place
    the second policy knob — the ``cache_bytes`` target that caps
    ``chunk * per_set`` — at the measured throughput plateau.

    Returns a JSON-able dict: per-case samples, ``knee_bytes``,
    ``chunk_sweep``, the suggested and currently configured policy rows.
    Run it on the backend being calibrated (CPU here; on a TPU/GPU host the
    same probe re-measures those rows — that is the documented path for
    updating the table).
    """
    import jax
    import numpy as np

    from repro.spgemm import PlanCache, spgemm_plan
    from repro.spgemm.executor import _CHUNK_POLICY

    rng = np.random.default_rng(seed)
    cache = PlanCache()
    samples: List[Dict] = []
    chunk_sweep: List[Dict] = []
    for ci, (m, k, n, density, tile, group) in enumerate(
        cases if cases is not None else _KNEE_CASES
    ):
        a = _random_int_coo(m, k, density, seed=seed + 2 * ci + 1)
        b = _random_int_coo(k, n, density, seed=seed + 2 * ci + 2)
        plan = spgemm_plan(a, b, tile=tile, group=group, backend=backend,
                           cache=cache)
        ex = plan._executor
        if ex is None:  # pragma: no cover - degenerate pattern
            continue
        per_set = 4 * ex._per_set_rows * ex._bn
        av = rng.integers(-3, 4, (batch, a.val.shape[0])).astype(np.float32)
        bv = rng.integers(-3, 4, (batch, b.val.shape[0])).astype(np.float32)

        def fused():
            return ex.run_batch(av, bv, rebind=True)

        def split():
            return [
                ex.run_batch(av[i:i + 1], bv[i:i + 1], rebind=True)
                for i in range(batch)
            ]

        fused(), split()  # compile both paths off the clock
        fused_ms = _best_ms(fused, repeats) / batch
        split_ms = _best_ms(lambda: np.concatenate(split()), repeats) / batch
        samples.append({
            "case": f"{m}x{k}x{n} d={density} tile={tile} g={group}",
            "per_set_bytes": int(per_set),
            "fused_ms_per_set": fused_ms,
            "split_ms_per_set": split_ms,
            "speedup": split_ms / max(fused_ms, 1e-9),
        })
        if ci == 0:
            for chunk in (1, 2, 4, batch):
                if chunk > batch:
                    continue

                def chunked():
                    return [
                        ex.run_batch(av[lo:lo + chunk], bv[lo:lo + chunk],
                                     rebind=True)
                        for lo in range(0, batch, chunk)
                    ]

                chunked()
                ms = _best_ms(lambda: np.concatenate(chunked()), repeats)
                chunk_sweep.append({
                    "chunk": chunk,
                    "ms_per_set": ms / batch,
                    "working_bytes": int(chunk * per_set),
                })

    # Prefix rule: the knee is the last per-set size (ascending) where
    # fusing still clears the threshold before the first regression.
    knee = 0
    for s in sorted(samples, key=lambda s: s["per_set_bytes"]):
        if s["speedup"] >= threshold:
            knee = s["per_set_bytes"]
        else:
            break
    best_chunk = min(chunk_sweep, key=lambda c: c["ms_per_set"])["chunk"] \
        if chunk_sweep else 1
    cache_bytes = max(knee, best_chunk * (samples[0]["per_set_bytes"]
                                          if samples else 0))
    device = jax.default_backend()
    return {
        "device_backend": device,
        "plan_backend": backend,
        "batch": batch,
        "repeats": repeats,
        "threshold": threshold,
        "samples": samples,
        "chunk_sweep": chunk_sweep,
        "knee_bytes": int(knee),
        "suggested_policy_row": [int(knee), int(cache_bytes)],
        "configured_policy_row": list(
            _CHUNK_POLICY.get(device, _CHUNK_POLICY["cpu"])
        ),
    }
