"""Row-wise Gustavson SpGEMM + the paper's comparison algorithms.

Three execution layers, all bit-identical in result:

1. ``spgemm_gustavson`` — vectorized numpy implementation of row-wise
   Gustavson (paper Fig. 1): C(i,:) = Σ_j A(i,j) · B(j,:). This is the
   production host/oracle path (expansion + sort + compression realizes the
   same sort-merge semantics as the hardware SM unit).
2. ``FSpGEMMSimulator`` — a faithful functional + performance simulator of
   the paper's FPGA kernel (Sec. 4.2): NUM_PE PEs consuming the CSV stream,
   a shared B-row buffer (Sec. 4.1), SW-wide VecMult, and the double-buffered
   Sort-Merge unit of Algorithm 1. It counts cycles, B-row fetches and
   off-chip traffic — these feed the STUF/runtime/energy models
   (Tables 7-9) and validate OMAR (Eq. 1) against an actual fetch trace.
3. ``spgemm_inner`` / ``spgemm_outer`` — the inner-product and
   outer-product baselines (Sec. 2.2) with their characteristic overheads
   surfaced as statistics (index-matching comparisons, zero-output work,
   partial-matrix traffic).

FLOP accounting: ``gustavson_flops`` returns the paper's N_Ops — one
multiply and one add per (A-nonzero × matching B-row nonzero), i.e.
``2 · Σ_{A(i,j)≠0} nnz(B(j,:))``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.sparse.formats import COO, CSC, CSR, CSV

__all__ = [
    "spgemm_gustavson",
    "spgemm_inner",
    "spgemm_outer",
    "gustavson_flops",
    "SpGEMMStats",
    "FSpGEMMSimulator",
]


# ---------------------------------------------------------------------------
# Vectorized row-wise Gustavson (expansion-sort-compression semantics)
# ---------------------------------------------------------------------------

def spgemm_gustavson(a: CSR, b: CSR) -> CSR:
    """Row-wise Gustavson's algorithm (paper Fig. 1), vectorized.

    For every nonzero A(i, j), expand the sparse partial-product row
    A(i, j) · B(j, :); then sort by (row, col) and merge equal columns —
    exactly the sort + merge of the paper's Sec. 2.2 description.
    """
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
    m, n = a.shape[0], b.shape[1]
    a_rows = np.repeat(np.arange(m, dtype=np.int64), a.row_nnz())
    # Products per A-nonzero = nnz of the matching B row.
    b_row_nnz = b.row_nnz()
    counts = b_row_nnz[a.indices]
    total = int(counts.sum())
    if total == 0:
        return CSR(np.zeros(m + 1, np.int64), np.zeros(0, np.int32), np.zeros(0, a.data.dtype), (m, n))
    # Expansion: for A-nonzero t with column j, emit B[indptr[j]:indptr[j+1]).
    starts = b.indptr[a.indices]
    seg = np.repeat(np.arange(a.nnz, dtype=np.int64), counts)
    # offset within each segment
    seg_starts = np.zeros(a.nnz + 1, dtype=np.int64)
    np.cumsum(counts, out=seg_starts[1:])
    within = np.arange(total, dtype=np.int64) - seg_starts[seg]
    b_pos = starts[seg] + within
    prod_row = a_rows[seg]
    prod_col = b.indices[b_pos].astype(np.int64)
    prod_val = a.data[seg] * b.data[b_pos]
    # Sort by (row, col) then merge runs with equal keys.
    order = np.lexsort((prod_col, prod_row))
    prod_row, prod_col, prod_val = prod_row[order], prod_col[order], prod_val[order]
    change = np.empty(total, dtype=bool)
    change[0] = True
    change[1:] = (prod_row[1:] != prod_row[:-1]) | (prod_col[1:] != prod_col[:-1])
    out_idx = np.cumsum(change) - 1
    out_nnz = int(out_idx[-1]) + 1
    out_val = np.zeros(out_nnz, dtype=prod_val.dtype)
    np.add.at(out_val, out_idx, prod_val)
    out_row = prod_row[change]
    out_col = prod_col[change].astype(np.int32)
    indptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(indptr, out_row + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSR(indptr, out_col, out_val, (m, n))


def gustavson_flops(a: CSR, b: CSR) -> int:
    """Paper's N_Ops: 2 FLOPs per expanded partial product (mul + add)."""
    return int(2 * b.row_nnz()[a.indices].sum())


# ---------------------------------------------------------------------------
# Baseline algorithms (paper Sec. 2.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpGEMMStats:
    """Operation statistics used by the performance models."""

    flops: int = 0  # useful multiply+add FLOPs
    index_match_ops: int = 0  # inner product's comparison overhead
    zero_outputs: int = 0  # inner product's wasted dot products
    partial_nnz: int = 0  # outer product's partial-matrix traffic (elements)
    b_row_fetches: int = 0  # Gustavson off-chip B-row fetches
    b_elements_fetched: int = 0
    cycles: int = 0  # simulator only


def spgemm_inner(a: CSR, b_csc: CSC) -> Tuple[CSR, SpGEMMStats]:
    """Inner-product SpGEMM (paper Sec. 2.2): computes *every* C(i, j) by a
    sorted index-matching dot product — including the zero outputs that
    Gustavson never touches. Returns the result plus overhead statistics.

    Only suitable for small/scaled matrices (it inspects all M·N pairs at
    row-column granularity, as the algorithm semantically must).
    """
    m, n = a.shape[0], b_csc.shape[1]
    out_rows, out_cols, out_vals = [], [], []
    stats = SpGEMMStats()
    for i in range(m):
        a_cols, a_vals = a.row_slice(i)
        if a_cols.shape[0] == 0:
            # Still "computes" the whole empty row in the inner-product model.
            stats.zero_outputs += n
            continue
        for j in range(n):
            b_rows, b_vals = b_csc.col_slice(j)
            # merge-style index matching (two-pointer; each comparison is
            # the hardware-expensive op identified by Jamro et al.)
            p = q = 0
            acc = 0.0
            matched = 0
            while p < a_cols.shape[0] and q < b_rows.shape[0]:
                stats.index_match_ops += 1
                if a_cols[p] == b_rows[q]:
                    acc += float(a_vals[p]) * float(b_vals[q])
                    matched += 1
                    p += 1
                    q += 1
                elif a_cols[p] < b_rows[q]:
                    p += 1
                else:
                    q += 1
            stats.flops += 2 * matched
            if matched and acc != 0.0:
                out_rows.append(i)
                out_cols.append(j)
                out_vals.append(acc)
            else:
                stats.zero_outputs += 1
    coo = COO(
        np.asarray(out_rows, np.int32),
        np.asarray(out_cols, np.int32),
        np.asarray(out_vals, a.data.dtype),
        (m, n),
    )
    return CSR.from_coo(coo), stats


def spgemm_outer(a_csc: CSC, b: CSR) -> Tuple[CSR, SpGEMMStats]:
    """Outer-product SpGEMM (paper Sec. 2.2): Σ_k outer(A(:,k), B(k,:)).

    Each outer product emits a partial matrix; the total partial-element
    count models the off-chip buffering traffic the paper criticizes.
    """
    if a_csc.shape[1] != b.shape[0]:
        raise ValueError("inner dims mismatch")
    m, n = a_csc.shape[0], b.shape[1]
    stats = SpGEMMStats()
    rows_l, cols_l, vals_l = [], [], []
    for k in range(a_csc.shape[1]):
        a_rows, a_vals = a_csc.col_slice(k)
        b_cols, b_vals = b.row_slice(k)
        if a_rows.shape[0] == 0 or b_cols.shape[0] == 0:
            continue
        rr = np.repeat(a_rows, b_cols.shape[0])
        cc = np.tile(b_cols, a_rows.shape[0])
        vv = np.outer(a_vals, b_vals).ravel()
        stats.flops += 2 * vv.shape[0]
        stats.partial_nnz += vv.shape[0]
        rows_l.append(rr)
        cols_l.append(cc)
        vals_l.append(vv)
    if rows_l:
        coo = COO(
            np.concatenate(rows_l),
            np.concatenate(cols_l),
            np.concatenate(vals_l).astype(a_csc.data.dtype),
            (m, n),
        ).sum_duplicates()
    else:
        coo = COO(np.zeros(0, np.int32), np.zeros(0, np.int32), np.zeros(0, a_csc.data.dtype), (m, n))
    return CSR.from_coo(coo), stats


# ---------------------------------------------------------------------------
# Faithful FPGA-kernel simulator (Sec. 4.2 + Algorithm 1)
# ---------------------------------------------------------------------------

class _SortMergeUnit:
    """One PE's Sort-Merge unit + double-buffered memory (Algorithm 1).

    Holds C_TEMP_ROW as two (VAL, COL_IND) buffers. ``merge`` combines the
    incoming sorted partial-product vector C_TEMP_VEC with the active buffer
    into the other buffer, counting comparison/merge cycles.
    """

    def __init__(self):
        self.buffers = [([], []), ([], [])]  # (cols, vals) per buffer
        self.sel = 0

    def reset(self):
        self.buffers = [([], []), ([], [])]
        self.sel = 0

    def merge(self, vec_cols: np.ndarray, vec_vals: np.ndarray) -> int:
        """Merge one sorted C_TEMP_VEC into C_TEMP_ROW. Returns cycles."""
        s = self.sel
        cols, vals = self.buffers[s]
        out_cols: list = []
        out_vals: list = []
        head, tail = 0, len(cols)
        ptr, sw = 0, len(vec_cols)
        cycles = 0
        # Algorithm 1: two-pointer sorted merge, one element per cycle.
        while ptr < sw:
            cycles += 1
            if head < tail:
                if cols[head] < vec_cols[ptr]:
                    out_cols.append(cols[head])
                    out_vals.append(vals[head])
                    head += 1
                elif cols[head] == vec_cols[ptr]:
                    out_cols.append(cols[head])
                    out_vals.append(vals[head] + vec_vals[ptr])
                    head += 1
                    ptr += 1
                else:
                    out_cols.append(int(vec_cols[ptr]))
                    out_vals.append(float(vec_vals[ptr]))
                    ptr += 1
            else:
                out_cols.append(int(vec_cols[ptr]))
                out_vals.append(float(vec_vals[ptr]))
                ptr += 1
        # Drain remaining buffered elements (paper: "no comparison needed").
        while head < tail:
            cycles += 1
            out_cols.append(cols[head])
            out_vals.append(vals[head])
            head += 1
        self.buffers[1 - s] = (out_cols, out_vals)
        self.sel = 1 - s
        return cycles

    def row(self) -> Tuple[np.ndarray, np.ndarray]:
        cols, vals = self.buffers[self.sel]
        return np.asarray(cols, np.int64), np.asarray(vals, np.float64)


class FSpGEMMSimulator:
    """Functional + performance simulator of the FSpGEMM FPGA kernel.

    Consumes the first input matrix in CSV format (paper Sec. 3) and the
    second in CSR (Sec. 4.2.2), processes CSV vectors with ``num_pe``
    parallel PEs sharing each fetched B row (Sec. 4.1), performs SW-wide
    VecMult + SM merges, and tracks:

      * ``b_row_fetches`` / ``b_elements_fetched`` — off-chip traffic to B
        (one fetch per CSV vector; OMAR's denominator counts one per
        A-nonzero in the naive scheme).
      * ``cycles`` — max over PEs per vector of VecMult/SM pipeline cycles
        (PEs run in parallel; the load kernel streams one CSV vector at a
        time), plus B streaming cycles at SW elements/cycle.
      * result correctness — bit-comparable to ``spgemm_gustavson``.
    """

    def __init__(self, num_pe: int, sw: int):
        if num_pe < 1 or sw < 1:
            raise ValueError("num_pe and sw must be >= 1")
        self.num_pe = num_pe
        self.sw = sw

    def run(self, a_csv: CSV, b: CSR) -> Tuple[CSR, SpGEMMStats]:
        if a_csv.num_pe != self.num_pe:
            raise ValueError("CSV group size != simulator NUM_PE")
        m, n = a_csv.shape[0], b.shape[1]
        stats = SpGEMMStats()
        sms = [_SortMergeUnit() for _ in range(self.num_pe)]
        out_rows: list = []
        out_cols: list = []
        out_vals: list = []

        # Iterate the CSV stream vector-by-vector (load kernel, Sec. 4.2.2):
        # a vector = run of consecutive entries with equal (group, col).
        vid = a_csv.vector_id()
        nnz = a_csv.nnz
        # Precompute the last nonzero position per row (RESET signal).
        last_of_row: Dict[int, int] = {}
        for t in range(nnz):
            last_of_row[int(a_csv.row_ind[t])] = t
        group = a_csv.group_of()
        t = 0
        while t < nnz:
            v = vid[t]
            t_end = t
            while t_end < nnz and vid[t_end] == v:
                t_end += 1
            j = int(a_csv.col_ind[t])
            b_cols, b_vals = b.row_slice(j)
            b_nnz = b_cols.shape[0]
            # One off-chip fetch of B(j,:) shared by all PEs of this vector.
            stats.b_row_fetches += 1
            stats.b_elements_fetched += int(b_nnz)
            n_b_vec = max(1, -(-b_nnz // self.sw))  # B_NUM_VEC (ceil)
            vec_cycles = n_b_vec  # streaming B at SW elems/cycle
            for tt in range(t, t_end):
                i = int(a_csv.row_ind[tt])
                pe = i % self.num_pe
                a_val = float(a_csv.val[tt])
                stats.flops += 2 * int(b_nnz)
                # VecMult: SW multiplies per cycle (n_b_vec cycles) feeding SM.
                prod_vals = a_val * b_vals.astype(np.float64)
                sm_cycles = sms[pe].merge(b_cols.astype(np.int64), prod_vals)
                vec_cycles = max(vec_cycles, sm_cycles)
                if tt == last_of_row[i]:
                    # RESET: drain this PE's row to the store kernel.
                    cols_i, vals_i = sms[pe].row()
                    keep = vals_i != 0.0
                    out_rows.extend([i] * int(keep.sum()))
                    out_cols.extend(cols_i[keep].tolist())
                    out_vals.extend(vals_i[keep].tolist())
                    sms[pe].reset()
            stats.cycles += vec_cycles
            t = t_end
        coo = COO(
            np.asarray(out_rows, np.int32),
            np.asarray(out_cols, np.int32),
            np.asarray(out_vals, np.float64).astype(a_csv.val.dtype),
            (m, n),
        )
        return CSR.from_coo(coo), stats
