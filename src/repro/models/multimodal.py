"""Modality frontend stubs (the brief: ``[audio]``/``[vlm]`` entries specify
the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

* audio  (hubert):    [B, S, frontend_dim] conv-feature frames -> linear
  projection to d_model (the CNN feature extractor itself is out of scope).
* vision (paligemma): [B, num_patches, frontend_dim] SigLIP patch embeddings
  -> linear projection, prepended to the text-token embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.nn import dense, dense_t

__all__ = ["frontend_t", "apply_frontend"]


def frontend_t(cfg: ModelConfig) -> Dict:
    if cfg.frontend == "none":
        return {}
    return {"proj": dense_t(cfg.frontend_dim, cfg.d_model,
                            (None, "embed"), bias=True)}


def apply_frontend(p: Dict, feats: jax.Array, cfg: ModelConfig) -> jax.Array:
    """feats: [B, S_frames|N_patches, frontend_dim] -> [B, *, d_model]."""
    return dense(p["proj"], feats.astype(cfg.compute_dtype()))
