"""Parameter-template NN primitives.

Each module describes its parameters as a *template* tree of ``Param``
leaves (shape + logical axes + initializer). ``init_params`` materializes a
params pytree from a template; ``logical_axes`` extracts the matching tree
of logical-axis tuples (consumed by launch/sharding.py). Templates keep the
param tree and its sharding annotations structurally identical by
construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard

__all__ = [
    "Param",
    "init_params",
    "logical_axes",
    "dense_t",
    "rmsnorm_t",
    "embedding_t",
    "optimization_barrier",
    "rmsnorm",
    "dense",
    "embed_lookup",
]


@jax.custom_jvp
def optimization_barrier(x):
    """Differentiable identity fence: ``jax.lax.optimization_barrier`` with
    pass-through tangents.

    The raw primitive has no differentiation rule, so placing it inside a
    ``grad``-transformed scan body (the remat residual fence in
    ``transformer.forward``) raises ``NotImplementedError``. The barrier
    only needs to pin the *primal* value against XLA hoisting; tangents and
    cotangents flow through unchanged (the JVP is linear in the tangent, so
    reverse mode transposes it for free). Accepts any pytree, like the raw
    primitive.
    """
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return jax.lax.optimization_barrier(x), dx


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | normal:<std>

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _init_leaf(key: jax.Array, p: Param, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init.startswith("normal"):
        std = float(p.init.split(":")[1]) if ":" in p.init else (
            1.0 / np.sqrt(p.shape[0])
        )
        return (jax.random.normal(key, p.shape) * std).astype(dtype)
    raise ValueError(f"unknown init {p.init}")


def init_params(key: jax.Array, template: Any, dtype=jnp.float32) -> Any:
    """Materialize a params pytree from a template tree of Param leaves."""
    leaves, treedef = jax.tree.flatten(
        template, is_leaf=lambda x: isinstance(x, Param)
    )
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def logical_axes(template: Any) -> Any:
    """Extract the tree of logical-axis tuples matching init_params."""
    return jax.tree.map(
        lambda p: p.axes, template, is_leaf=lambda x: isinstance(x, Param)
    )


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def dense_t(
    d_in: int,
    d_out: Tuple[int, ...] | int,
    axes: Tuple[Optional[str], ...],
    *,
    bias: bool = False,
    std: Optional[float] = None,
) -> Dict[str, Param]:
    out_dims = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    init = f"normal:{std}" if std is not None else "normal"
    t = {"w": Param((d_in, *out_dims), axes, init)}
    if bias:
        t["b"] = Param(out_dims, axes[1:], "zeros")
    return t


def rmsnorm_t(d: int) -> Dict[str, Param]:
    return {"scale": Param((d,), ("embed",), "ones")}


def embedding_t(vocab: int, d: int) -> Dict[str, Param]:
    return {"table": Param((vocab, d), ("vocab", "embed"), "normal:0.02")}


# ---------------------------------------------------------------------------
# Apply functions
# ---------------------------------------------------------------------------

def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 statistics but no full-tensor f32 copy.

    The square+convert fuses into the mean reduction; only the [..., 1]
    statistics are f32. Converting the whole tensor (x.astype(f32) * ...)
    makes XLA sink the convert into upstream saved buffers (observed: the
    layer-scan residual save doubled to f32).
    """
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * p["scale"].astype(dt)


def dense(p: Dict, x: jax.Array, dtype=None) -> jax.Array:
    """x [..., d_in] @ w [d_in, *out] (+ b). Contracts the last axis."""
    w = p["w"]
    dt = dtype or x.dtype
    y = jax.lax.dot_general(
        x.astype(dt), w.astype(dt),
        (((x.ndim - 1,), (0,)), ((), ())),
    )
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


def embed_lookup(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)
