"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "BlockSpec"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One position in the repeating layer pattern."""

    mixer: str  # "attn" | "ssm"
    ff: str  # "mlp" | "moe" | "none" (pure-mixer layers, e.g. Mamba stacks)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # Layer composition: the pattern repeats n_layers / len(pattern) times.
    block_pattern: Tuple[BlockSpec, ...] = (BlockSpec("attn", "mlp"),)
    d_head: Optional[int] = None  # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0  # expert hidden dim (d_ff used if 0)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0  # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- attention flavour ---
    window: Optional[int] = None  # sliding-window attention
    rope_theta: float = 10_000.0
    causal: bool = True  # False for encoder-only archs
    # --- embeddings / head ---
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None
    # --- frontend stubs (audio / vision) ---
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0  # precomputed frame/patch embedding width
    num_patches: int = 0  # vision prefix length inside seq
    # --- MLP flavour ---
    act: str = "silu"
    mlp_gated: bool = True
    attn_bias: bool = False
    # --- sparse-weight feature (the paper's technique on FFN weights) ---
    sparse_ffn: bool = False
    sparse_block: int = 128
    sparse_density: float = 0.25
    # --- numerics / execution ---
    vocab_pad_multiple: int = 128  # pad embed/head so the vocab TP-shards
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"  # activation/param compute dtype
    param_dtype: str = "float32"
    remat: str = "full"  # none | full | dots
    attn_impl: str = "dense"  # dense | blocked (per-shape override)
    attn_block_q: int = 1024
    scan_unroll: bool = False  # unroll the layer loop (cost sub-compiles)
    kernel_backend: str = "auto"  # auto | pallas | pallas_interpret | jnp

    def __post_init__(self):
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.block_pattern)}"
            )

    # -- derived ---------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return any(b.mixer == "attn" for b in self.block_pattern)

    @property
    def has_ssm(self) -> bool:
        return any(b.mixer == "ssm" for b in self.block_pattern)

    @property
    def has_moe(self) -> bool:
        return any(b.ff == "moe" for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if 500k-token decode is serveable: attention is window-
        bounded or absent, or the arch is a hybrid (SSM layers are O(1)-
        state and the few attention layers' KV shards over kv_seq)."""
        return (not self.has_attention) or (self.window is not None) \
            or self.has_ssm

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_ff(self) -> int:
        return self.d_ff_expert if self.d_ff_expert else self.d_ff

    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND model FLOPs) ---------------------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.head_dim
        attn = (
            d * self.n_heads * hd  # Wq
            + 2 * d * self.n_kv_heads * hd  # Wk, Wv
            + self.n_heads * hd * d  # Wo
        )
        ff_table = {"none": 0}
        mlp = (3 if self.mlp_gated else 2) * d * self.d_ff
        moe = self.n_experts * (3 if self.mlp_gated else 2) * d * self.expert_ff \
            + d * self.n_experts
        moe_active = self.top_k * (3 if self.mlp_gated else 2) * d * self.expert_ff \
            + d * self.n_experts
        di, n_state, h = self.d_inner, self.ssm_state, self.ssm_heads
        ssm = (
            d * (2 * di + 2 * n_state + h)  # in_proj (z,x,B,C,dt)
            + self.ssm_conv_width * (di + 2 * n_state)  # conv
            + 3 * h  # A_log, D, dt_bias
            + di  # gated norm
            + di * d  # out_proj
        )
        total = active = 0
        for li in range(self.n_layers):
            b = self.block_pattern[li % self.period]
            mix = attn if b.mixer == "attn" else ssm
            ff = ff_table.get(b.ff, mlp if b.ff == "mlp" else moe)
            ff_a = ff_table.get(b.ff, mlp if b.ff == "mlp" else moe_active)
            norms = 2 * d
            total += mix + ff + norms
            active += mix + ff_a + norms
        embed = self.vocab * d
        head = 0 if self.tie_embeddings else d * self.vocab
        total += embed + head + d
        active += embed + head + d
        return {"total": total, "active": active, "embed": embed}
