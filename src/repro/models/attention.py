"""GQA/MQA attention with RoPE, sliding windows, KV cache, and two
execution plans:

* ``dense``   — full [Sq, Skv] score materialization. Used for train_4k
  (fits VMEM/HBM comfortably per layer under scan+remat) and gives exact
  HLO cost accounting in the dry-run.
* ``blocked`` — lax.scan over query blocks (each block attends to the full
  KV). O(bq * Skv) live memory; required for 32k prefill. The Pallas
  flash-attention kernel (kernels/flash_attention.py) is the TPU hot-spot
  twin selected via ``kernel_backend="pallas"``.

Decode attends one new token against the cache with a dense [1, Skv] score
row — no scan, exact cost accounting, and the KV-sequence axis may be
sharded (``kv_seq`` logical axis): XLA turns the softmax reductions into the
flash-decoding LSE combine across shards.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.launch.sharding import shard
from repro.models.config import ModelConfig
from repro.models.nn import Param, dense, dense_t

__all__ = ["attn_t", "attn_forward", "attn_decode", "init_kv_cache", "rope"]

_NEG_INF = -1e30


def attn_t(cfg: ModelConfig) -> Dict:
    hd = cfg.head_dim
    return {
        "wq": dense_t(cfg.d_model, (cfg.n_heads, hd),
                      ("embed", "heads", "head_dim"), bias=cfg.attn_bias),
        "wk": dense_t(cfg.d_model, (cfg.n_kv_heads, hd),
                      ("embed", "kv_heads", "head_dim"), bias=cfg.attn_bias),
        "wv": dense_t(cfg.d_model, (cfg.n_kv_heads, hd),
                      ("embed", "kv_heads", "head_dim"), bias=cfg.attn_bias),
        "wo": {"w": Param((cfg.n_heads, hd, cfg.d_model),
                          ("heads", "head_dim", "embed"))},
    }


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding over the last axis. x: [B, S, H, D], positions [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if d % 2:  # odd head_dim (hubert's 80 is even; safety)
        rot = jnp.concatenate([rot, x[..., 2 * half :]], axis=-1)
    return rot.astype(x.dtype)


def _mask(
    q_pos: jax.Array,  # [Sq] absolute positions of queries
    k_pos: jax.Array,  # [Skv]
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _gqa_scores_apply(
    q: jax.Array,  # [B, Sq, KV, R, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,  # [B, Skv, KV, hd]
    mask: jax.Array,  # [Sq, Skv] bool
    scale: float,
) -> jax.Array:
    # bf16 operands, f32 accumulation (native MXU contract); probabilities
    # drop back to the compute dtype for the PV matmul so the only f32
    # buffer is the score block.
    s = jnp.einsum("bqkrd,bskd->bkrqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, axis=-1)[None, None, None, :, None], p, 0.0)
    return jnp.einsum("bkrqs,bskd->bqkrd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _project_qkv(p: Dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array):
    q = dense(p["wq"], x)  # [B, S, H, hd]
    k = dense(p["wk"], x)  # [B, S, KV, hd]
    v = dense(p["wv"], x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def attn_forward(
    p: Dict,
    x: jax.Array,  # [B, S, D]
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    kv, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.head_dim
    scale = 1.0 / float(np.sqrt(hd))
    qg = q.reshape(b, s, kv, rep, hd)

    backend = kops.resolve_backend(cfg.kernel_backend)
    if backend in ("pallas", "pallas_interpret") and s % 512 == 0:
        # Flash kernel path: flatten (B, KV, R) into the BH grid axis.
        qf = qg.transpose(0, 2, 3, 1, 4).reshape(b * kv * rep, s, hd)
        kf = jnp.repeat(
            k.transpose(0, 2, 1, 3), rep, axis=1
        ).reshape(b * kv * rep, s, hd)
        vf = jnp.repeat(
            v.transpose(0, 2, 1, 3), rep, axis=1
        ).reshape(b * kv * rep, s, hd)
        of = kops.attention(qf, kf, vf, cfg.causal, cfg.window, 0,
                            cfg.kernel_backend)
        out = of.reshape(b, kv, rep, s, hd).transpose(0, 3, 1, 2, 4)
    elif cfg.attn_impl == "blocked" and s > cfg.attn_block_q and \
            s % cfg.attn_block_q == 0:
        bq = cfg.attn_block_q
        k_pos = positions[0]

        @jax.checkpoint  # recompute the score block in bwd: the inner-scan
        # residuals would otherwise stack n_q f32 score blocks
        def body(_, qi):
            q_blk, qpos_blk = qi  # [B, bq, KV, R, hd], [bq]
            # seq_q shards the score block over the query-position dim for
            # archs whose head count doesn't divide TP (llama4's 40H/16).
            q_blk = shard(q_blk, "batch", "seq_q", "kv_heads", None, None)
            m = _mask(qpos_blk, k_pos, cfg.causal, cfg.window)
            o = _gqa_scores_apply(q_blk, k, v, m, scale)
            o = shard(o, "batch", "seq_q", "kv_heads", None, None)
            return None, o

        q_blocks = qg.reshape(b, s // bq, bq, kv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
        pos_blocks = positions[0].reshape(s // bq, bq)
        _, o_blocks = jax.lax.scan(body, None, (q_blocks, pos_blocks))
        out = o_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kv, rep, hd)
    else:
        m = _mask(positions[0], positions[0], cfg.causal, cfg.window)
        out = _gqa_scores_apply(qg, k, v, m, scale)

    out = out.reshape(b, s, cfg.n_heads, hd).astype(x.dtype)
    out = shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshd,hde->bse", out, p["wo"]["w"].astype(x.dtype))
    return shard(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# KV cache / decode
# ---------------------------------------------------------------------------

def init_kv_cache(
    cfg: ModelConfig, batch: int, max_seq: int, n_attn_layers: int, dtype
) -> Dict[str, jax.Array]:
    """Cache stacked over attention-layer instances. For SWA archs the
    cache is a ring buffer of ``window`` slots."""
    s = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (n_attn_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attn_decode(
    p: Dict,
    x: jax.Array,  # [B, 1, D]
    cache_k: jax.Array,  # [B, S_cache, KV, hd]
    cache_v: jax.Array,
    pos: jax.Array,  # [] int32 — absolute position of the new token
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step vs the cache. Returns (y, new_k, new_v)."""
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    # Ring-buffer slot for SWA caches; plain slot otherwise. The write is
    # a one-hot masked select, NOT dynamic-update-slice: GSPMD handles a
    # dynamic index on the sequence-sharded cache dim by all-gathering the
    # whole cache (measured: +17 GiB/layer for the 32k decode cell); the
    # masked write stays local to each sequence shard.
    slot = pos % s_cache if cfg.window else pos
    hit = (jnp.arange(s_cache) == slot)[None, :, None, None]
    cache_k = jnp.where(hit, k_new.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(hit, v_new.astype(cache_v.dtype), cache_v)
    cache_k = shard(cache_k, "kv_batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = shard(cache_v, "kv_batch", "kv_seq", "kv_heads", "head_dim")

    kv, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.head_dim
    qg = q.reshape(b, 1, kv, rep, hd)
    # Validity of cache slots: slot index positions vs current pos.
    idx = jnp.arange(s_cache)
    if cfg.window:
        # Ring buffer: slot i holds absolute position p_i ≡ i (mod s_cache)
        # with p_i <= pos; valid iff pos - p_i < window and p_i <= pos.
        age = (slot - idx) % s_cache  # 0 = newest
        valid = age < jnp.minimum(pos + 1, cfg.window)
    else:
        valid = idx <= pos
    # bf16 operands + f32 accumulation: an explicit .astype(f32) on the
    # cache makes XLA materialize a full f32 cache copy (+2.5 GiB at 32k).
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, cache_k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    s = jnp.where(valid[None, None, None, None, :], s, _NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", pr.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads, hd).astype(x.dtype)
    y = jnp.einsum("bshd,hde->bse", out, p["wo"]["w"].astype(x.dtype))
    return shard(y, "batch", None, "embed"), cache_k, cache_v
