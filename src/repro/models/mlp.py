"""Feed-forward blocks: (gated) MLP and the SparseLinear feature.

``SparseLinear`` is where the paper's technique enters the LM stack
(DESIGN.md Sec. 3): the down-projection weight carries a *block-sparse
support mask* in BCSV layout. Training keeps masked-dense semantics (the
mask is a constant pytree leaf; the matmul is dense with zeros — exact
cost/memory parity with the TPU bsr_spmm path is reported by the roofline
tooling); serving on TPU packs the nonzero blocks and dispatches
``kernels.ops.sparse_dense_matmul``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard
from repro.models.config import ModelConfig
from repro.models.nn import Param, dense, dense_t

__all__ = ["mlp_t", "mlp_forward", "sparse_block_mask"]


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_t(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    t: Dict = {}
    if cfg.mlp_gated:
        t["wg"] = dense_t(d, f, ("embed", "mlp"))
        t["wu"] = dense_t(d, f, ("embed", "mlp"))
    else:
        t["wu"] = dense_t(d, f, ("embed", "mlp"), bias=cfg.attn_bias)
    t["wd"] = dense_t(f, d, ("mlp", "embed"), bias=(not cfg.mlp_gated and cfg.attn_bias))
    if cfg.sparse_ffn:
        gm, gf = f // cfg.sparse_block, d // cfg.sparse_block
        t["wd_mask"] = Param((gm, gf), (None, None), "ones")
    return t


def sparse_block_mask(
    key: jax.Array, f: int, d: int, block: int, density: float
) -> jax.Array:
    """Random block support for SparseLinear (magnitude pruning stand-in)."""
    gm, gf = f // block, d // block
    u = jax.random.uniform(key, (gm, gf))
    thresh = jnp.quantile(u, density)
    m = (u <= thresh).astype(jnp.float32)
    return jnp.maximum(m, jnp.zeros_like(m).at[0, :].set(1.0))  # no empty col panels


def mlp_forward(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _act(cfg.act)
    if cfg.mlp_gated:
        h = act(dense(p["wg"], x)) * dense(p["wu"], x)
    else:
        h = act(dense(p["wu"], x))
    h = shard(h, "batch", "seq", "mlp")
    wd = p["wd"]
    if cfg.sparse_ffn and "wd_mask" in p:
        blk = cfg.sparse_block
        mask = jnp.repeat(jnp.repeat(p["wd_mask"], blk, 0), blk, 1)
        wd = {**wd, "w": wd["w"] * mask.astype(wd["w"].dtype)}
    y = dense(wd, h)
    return shard(y, "batch", "seq", "embed")
