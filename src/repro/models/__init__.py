"""Composable model definitions (pure-JAX, param-dict style).

Every architecture in the assigned pool is expressed as a ``ModelConfig``
whose ``block_pattern`` composes mixer (attention / SSM) and feed-forward
(dense MLP / MoE / SparseLinear) choices per layer-period position. One
``transformer.py`` forward serves dense, MoE, SSM, hybrid, audio-encoder and
VLM archs.
"""
from repro.models.config import ModelConfig
