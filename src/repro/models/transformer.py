"""The LM assembly: embedding/frontend -> scan over layer periods ->
norm -> head. One forward serves all 10 assigned architectures.

Execution structure (matters for the dry-run cost accounting, DESIGN.md
Sec. 6):

* **train/prefill forward**: ``lax.scan`` over the ``n_periods`` stacked
  layer groups (bounded HLO size; the dry-run applies the L=1/L=2
  trip-count correction). Remat policy wraps the scan body.
* **decode**: fully *unrolled* over layers — decode ops are small, the HLO
  stays modest, and cost analysis needs no correction.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.attention import init_kv_cache
from repro.models.blocks import block_decode, block_forward, block_t
from repro.models.config import ModelConfig
from repro.models.multimodal import apply_frontend, frontend_t
from repro.models.nn import (
    dense,
    dense_t,
    embed_lookup,
    embedding_t,
    init_params,
    logical_axes,
    optimization_barrier,
    rmsnorm,
    rmsnorm_t,
)
from repro.models.ssm import init_ssm_cache

__all__ = [
    "lm_template",
    "init_lm",
    "lm_axes",
    "forward",
    "decode_step",
    "init_cache",
    "lm_loss",
]


def _stack_template(t, n: int):
    """Prepend a layer-period axis to every Param in a block template."""
    from repro.models.nn import Param

    return jax.tree.map(
        lambda p: Param((n, *p.shape), (None, *p.axes), p.init),
        t,
        is_leaf=lambda x: isinstance(x, Param),
    )


def lm_template(cfg: ModelConfig) -> Dict:
    t: Dict = {
        "embed": embedding_t(cfg.vocab_padded, cfg.d_model),
        "layers": [
            _stack_template(block_t(cfg, spec), cfg.n_periods)
            for spec in cfg.block_pattern
        ],
        "final_norm": rmsnorm_t(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = dense_t(cfg.d_model, cfg.vocab_padded,
                               ("embed", "vocab"))
    fe = frontend_t(cfg)
    if fe:
        t["frontend"] = fe
    return t


def init_lm(key: jax.Array, cfg: ModelConfig) -> Dict:
    return init_params(key, lm_template(cfg), dtype=cfg.params_dtype())


def lm_axes(cfg: ModelConfig) -> Dict:
    return logical_axes(lm_template(cfg))


def _embed_inputs(
    params: Dict,
    cfg: ModelConfig,
    tokens: Optional[jax.Array],
    feats: Optional[jax.Array],
) -> jax.Array:
    dt = cfg.compute_dtype()
    if cfg.frontend == "audio":
        h = apply_frontend(params["frontend"], feats, cfg)
    elif cfg.frontend == "vision":
        img = apply_frontend(params["frontend"], feats, cfg)
        txt = embed_lookup(params["embed"], tokens, dt)
        h = jnp.concatenate([img, txt], axis=1)
    else:
        h = embed_lookup(params["embed"], tokens, dt)
    return shard(h, "batch", "seq", "embed")


def _head(params: Dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", h, params["embed"]["table"].astype(h.dtype)
        )
    else:
        logits = dense(params["lm_head"], h)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.vocab_padded != cfg.vocab:
        # Mask the padded vocabulary tail (never sampled, never trained up).
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32)).astype(
            logits.dtype
        )
    return shard(logits, "batch", "seq", "vocab")


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    feats: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B,S,V], moe_aux scalar)."""
    h = _embed_inputs(params, cfg, tokens, feats)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def period_body(carry, period_params):
        x, aux = carry
        for pos_idx, spec in enumerate(cfg.block_pattern):
            x, a = block_forward(
                period_params[pos_idx], x, cfg, spec, positions
            )
            aux = aux + a
        # The scan carry is the activation tensor remat keeps alive per
        # layer; pin it to the sequence-parallel layout (1/TP bytes) and
        # fence it so XLA cannot hoist the next layer's f32 upcast across
        # the save (observed: the stacked residual buffer became f32 —
        # 2x the bytes — without the barrier). The differentiable wrapper
        # keeps the fence legal under grad (the raw primitive has no
        # differentiation rule).
        x = shard(x, "batch", "seq_resid", "embed")
        x = optimization_barrier(x)
        return (x, aux), None

    if cfg.remat == "full":
        period_body = jax.checkpoint(period_body)
    elif cfg.remat == "dots":
        period_body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    (h, aux), _ = jax.lax.scan(
        period_body, (h, jnp.zeros((), jnp.float32)), params["layers"],
        unroll=cfg.n_periods if cfg.scan_unroll else 1,
    )
    return _head(params, h, cfg), aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _attn_positions(cfg: ModelConfig):
    return [i for i in range(cfg.n_layers)
            if cfg.block_pattern[i % cfg.period].mixer == "attn"]


def _ssm_positions(cfg: ModelConfig):
    return [i for i in range(cfg.n_layers)
            if cfg.block_pattern[i % cfg.period].mixer == "ssm"]


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    """Decode cache for all layers (KV ring buffers + SSM states)."""
    dt = cfg.compute_dtype()
    cache: Dict = {"pos": jnp.zeros((), jnp.int32)}
    n_attn = len(_attn_positions(cfg))
    if n_attn:
        cache["kv"] = init_kv_cache(cfg, batch, max_seq, n_attn, dt)
    n_ssm = len(_ssm_positions(cfg))
    if n_ssm:
        cache["ssm"] = init_ssm_cache(cfg, batch, n_ssm, dt)
    return cache


def decode_step(
    params: Dict,
    cache: Dict,
    cfg: ModelConfig,
    token: jax.Array,  # [B, 1] int32
) -> Tuple[jax.Array, Dict]:
    """One token of autoregressive decode. Returns (logits [B,1,V], cache).

    Scans over layer periods (like the forward pass): an unrolled decode
    let the scheduler keep per-layer buffers concurrently live. Cache
    slices ride the scan as xs/ys; the dry-run applies the same L=1/L=2
    cost correction as training.
    """
    dt = cfg.compute_dtype()
    h = embed_lookup(params["embed"], token, dt)
    h = shard(h, "batch", None, "embed")
    pos = cache["pos"]
    cache = dict(cache)
    n_p = cfg.n_periods
    attn_pp = sum(1 for b in cfg.block_pattern if b.mixer == "attn")
    ssm_pp = sum(1 for b in cfg.block_pattern if b.mixer == "ssm")
    # fori_loop (not scan): the cache rides the carry and is updated with
    # dynamic-index .at[].set on the (unsharded) layer dim, which XLA
    # bufferizes in place — scan xs/ys would double-buffer the multi-GiB
    # KV cache twice over.
    carry = {
        "h": h,
        "k": cache.get("kv", {}).get("k"),
        "v": cache.get("kv", {}).get("v"),
        "state": cache.get("ssm", {}).get("state"),
        "conv": cache.get("ssm", {}).get("conv"),
    }
    carry = {k: v for k, v in carry.items() if v is not None}

    def period_body(i, c):
        ai = i * attn_pp
        si = i * ssm_pp
        h = c["h"]
        for pos_idx, spec in enumerate(cfg.block_pattern):
            p_li = jax.tree.map(lambda a: a[i], params["layers"][pos_idx])
            if spec.mixer == "attn":
                kv = (c["k"][ai], c["v"][ai])
                h, new_kv, _ = block_decode(p_li, h, cfg, spec, pos, kv=kv)
                c = dict(c)
                c["k"] = c["k"].at[ai].set(new_kv[0])
                c["v"] = c["v"].at[ai].set(new_kv[1])
                ai += 1
            else:
                st = (c["state"][si], c["conv"][si])
                h, _, new_ssm = block_decode(p_li, h, cfg, spec, pos,
                                             ssm_state=st)
                c = dict(c)
                c["state"] = c["state"].at[si].set(new_ssm[0])
                c["conv"] = c["conv"].at[si].set(new_ssm[1])
                si += 1
        c["h"] = h
        return c

    if cfg.scan_unroll:  # exact cost accounting for the dry-run sub-compiles
        for i in range(n_p):
            carry = period_body(i, carry)
    else:
        carry = jax.lax.fori_loop(0, n_p, period_body, carry)
    if attn_pp:
        cache["kv"] = {"k": carry["k"], "v": carry["v"]}
    if ssm_pp:
        cache["ssm"] = {"state": carry["state"], "conv": carry["conv"]}
    logits = _head(params, carry["h"], cfg)
    cache["pos"] = pos + 1
    return logits, cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _token_nll(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token -log p(label). Custom VJP keeps every [T, V] tensor in the
    compute dtype: at vocab 256k x 64k tokens/device the default autodiff
    path materializes several f32 [T, V] buffers (exp, dlogits, transposes)
    — ~4 GiB each — that dominate HBM."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - picked.astype(jnp.float32)


def _token_nll_fwd(logits, labels):
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - picked.astype(jnp.float32), (logits, labels, lse)


def _token_nll_bwd(res, g):
    logits, labels, lse = res
    # softmax in the compute dtype (exp of a ≤0 number: safe in bf16).
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None]).astype(logits.dtype)
    dl = p * g[..., None].astype(logits.dtype)
    onehot_g = jnp.zeros_like(dl).at[
        jnp.arange(dl.shape[0])[:, None], labels[..., None]
    ].add(g[..., None].astype(logits.dtype)) if dl.ndim == 2 else None
    if dl.ndim == 3:  # [B, S, V]
        b_idx = jnp.arange(dl.shape[0])[:, None, None]
        s_idx = jnp.arange(dl.shape[1])[None, :, None]
        dl = dl.at[b_idx, s_idx, labels[..., None]].add(
            -g[..., None].astype(dl.dtype)
        )
    else:
        dl = dl - onehot_g
    return dl, None


_token_nll.defvjp(_token_nll_fwd, _token_nll_bwd)


def lm_loss(
    params: Dict,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    labels: Optional[jax.Array] = None,
    feats: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Cross-entropy LM loss.

    Decoder LMs: ``labels`` are next tokens (pre-shifted by the pipeline).
    Encoder (hubert): ``labels`` are per-frame targets, ``mask`` selects the
    masked-prediction positions.
    """
    logits, aux = forward(params, cfg, tokens=tokens, feats=feats)
    if cfg.frontend == "vision":
        # Loss on the text region only.
        logits = logits[:, cfg.num_patches :]
    # Fused CE with a compute-dtype custom VJP (see _token_nll).
    nll = _token_nll(logits, labels)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "moe_aux": aux}
