"""Decoder/encoder blocks: pre-norm mixer (attention or SSD) + FF (MLP or
MoE), composed per the config's ``block_pattern``."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attn_decode, attn_forward, attn_t
from repro.models.config import BlockSpec, ModelConfig
from repro.models.mlp import mlp_forward, mlp_t
from repro.models.moe import moe_forward, moe_t
from repro.models.nn import rmsnorm, rmsnorm_t
from repro.models.ssm import ssm_decode, ssm_forward, ssm_t

__all__ = ["block_t", "block_forward", "block_decode"]


def block_t(cfg: ModelConfig, spec: BlockSpec) -> Dict:
    t = {
        "ln1": rmsnorm_t(cfg.d_model),
        "mixer": attn_t(cfg) if spec.mixer == "attn" else ssm_t(cfg),
    }
    if spec.ff != "none":
        t["ln2"] = rmsnorm_t(cfg.d_model)
        t["ff"] = mlp_t(cfg) if spec.ff == "mlp" else moe_t(cfg)
    return t


def block_forward(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    spec: BlockSpec,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux_loss)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        h = attn_forward(p["mixer"], h, cfg, positions)
    else:
        h = ssm_forward(p["mixer"], h, cfg)
    x = x + h
    if spec.ff == "none":
        return x, jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if spec.ff == "mlp":
        h = mlp_forward(p["ff"], h, cfg)
        aux = jnp.zeros((), jnp.float32)
    else:
        h, aux = moe_forward(p["ff"], h, cfg)
    return x + h, aux


def block_decode(
    p: Dict,
    x: jax.Array,  # [B, 1, D]
    cfg: ModelConfig,
    spec: BlockSpec,
    pos: jax.Array,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    ssm_state: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """One decode step through one block. Returns (x, new_kv, new_ssm)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    new_kv = new_ssm = None
    if spec.mixer == "attn":
        h, ck, cv = attn_decode(p["mixer"], h, kv[0], kv[1], pos, cfg)
        new_kv = (ck, cv)
    else:
        h, st, conv = ssm_decode(p["mixer"], h, ssm_state[0], ssm_state[1], cfg)
        new_ssm = (st, conv)
    x = x + h
    if spec.ff == "none":
        return x, new_kv, new_ssm
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if spec.ff == "mlp":
        h = mlp_forward(p["ff"], h, cfg)
    else:
        h, _ = moe_forward(p["ff"], h, cfg)
    return x + h, new_kv, new_ssm
