"""Mamba-2 (SSD, state-space duality) mixer — chunked training form +
O(1)-state decode.

Two implementation notes (DESIGN.md Sec. 6):

* **Cost accounting**: the chunked SSD form keeps every FLOPs-heavy
  contraction *outside* the sequential scan — intra-chunk attention-like
  matmuls and the inter-chunk output contraction are batched einsums over
  the chunk axis; only the cheap elementwise state decay/accumulate runs
  inside ``lax.scan``. HLO cost analysis therefore counts ~all SSD FLOPs
  exactly once (no trip-count correction needed in the sequence dim).

* **TP sharding**: the fused Mamba in_proj is split into per-output
  projections (z / x / B / C / dt) so each output gets a clean logical
  sharding — in particular dt and the head-indexed decay tensors shard over
  ``heads``, which keeps the [B, nC, Q, Q, H] intra-chunk decay tensor
  (the big SSD intermediate) distributed over the model axis.
  Mathematically identical to the fused projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.config import ModelConfig
from repro.models.nn import Param, dense, rmsnorm

__all__ = ["ssm_t", "ssm_forward", "ssm_decode", "init_ssm_cache"]


def ssm_t(cfg: ModelConfig) -> Dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    return {
        "z_proj": {"w": Param((d, di), ("embed", "inner"))},
        "x_proj": {"w": Param((d, di), ("embed", "inner"))},
        "b_proj": {"w": Param((d, n), ("embed", "state"))},
        "c_proj": {"w": Param((d, n), ("embed", "state"))},
        "dt_proj": {"w": Param((d, h), ("embed", "heads"))},
        "conv_x": Param((cw, di), (None, "inner"), "normal:0.2"),
        "conv_b": Param((cw, n), (None, "state"), "normal:0.2"),
        "conv_c": Param((cw, n), (None, "state"), "normal:0.2"),
        "a_log": Param((h,), ("heads",), "zeros"),
        "d_skip": Param((h,), ("heads",), "ones"),
        "dt_bias": Param((h,), ("heads",), "zeros"),
        "norm": {"scale": Param((di,), ("inner",), "ones")},
        "out_proj": {"w": Param((di, d), ("inner", "embed"))},
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: [B, S, C], w: [cw, C]."""
    cw = w.shape[0]
    out = x * w[-1]
    for i in range(cw - 1):
        shift = cw - 1 - i
        out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]] * w[i]
    return out


def _post(p: Dict, y: jax.Array, z: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Gated RMSNorm + out projection (y, z: [..., d_inner])."""
    g = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(y.dtype)
    out = dense(p["out_proj"], g.astype(z.dtype))
    return shard(out, "batch", "seq", "embed")


def ssm_forward(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence SSD. x: [B, S, D]; S % ssm_chunk == 0."""
    b, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} % chunk {q}"
    nc = s // q

    z = shard(dense(p["z_proj"], x), "batch", "seq", "inner")
    xc = jax.nn.silu(_causal_conv(dense(p["x_proj"], x), p["conv_x"].astype(x.dtype)))
    bmat = jax.nn.silu(_causal_conv(dense(p["b_proj"], x), p["conv_b"].astype(x.dtype)))
    cmat = jax.nn.silu(_causal_conv(dense(p["c_proj"], x), p["conv_c"].astype(x.dtype)))
    dt_raw = dense(p["dt_proj"], x)  # [B,S,H]

    f32 = jnp.float32
    dt = jax.nn.softplus(dt_raw.astype(f32) + p["dt_bias"].astype(f32))
    dt = shard(dt, "batch", "seq", "heads")
    a = -jnp.exp(p["a_log"].astype(f32))  # [H]
    da = dt * a  # ≤ 0
    xh = shard(xc.reshape(b, s, h, pdim), "batch", "seq", "heads", None)

    # chunk — keep x in the compute dtype; only the small decay statistics
    # ([*, Q, H] and smaller) live in f32. The big [B,nC,Q,Q,H] decay
    # tensor materializes ONCE, in bf16 (the elementwise chain
    # sub->clamp->exp->mul->convert fuses into its producer), feeding the
    # MXU with f32 accumulation.
    dt_c = x.dtype
    xhc = xh.reshape(b, nc, q, h, pdim)
    dtc = dt.reshape(b, nc, q, h)
    dac = da.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    acum = jnp.cumsum(dac, axis=2)  # [B,nC,Q,H] f32
    # --- intra-chunk (quadratic-in-Q attention-like form) ----------------
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,nC,Qi,Qj,H]
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # Clamp BEFORE exp: masked (j > i) entries have positive seg, and
    # exp(+big)=inf leaks NaN through the where in the backward pass.
    seg = jnp.where(causal, seg, 0.0)
    l_mat = (jnp.where(causal, jnp.exp(seg), 0.0)
             * dtc[:, :, None, :, :]).astype(dt_c)  # decay(i<-j) * dt_j
    l_mat = shard(l_mat, "batch", None, None, None, "heads")
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                    preferred_element_type=jnp.float32)  # [B,nC,Q,Q]
    scores = cb[..., None].astype(dt_c) * l_mat  # [B,nC,Qi,Qj,H] bf16
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xhc,
                         preferred_element_type=jnp.float32)

    # --- chunk-local end states ------------------------------------------
    a_last = acum[:, :, -1:, :]  # [B,nC,1,H]
    decay_to_end = (jnp.exp(a_last - acum) * dtc).astype(dt_c)  # [B,nC,Q,H]
    s_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end, bc, xhc,
                       preferred_element_type=jnp.float32)

    # --- inter-chunk state propagation (cheap scan) -----------------------
    a_sum = acum[:, :, -1, :]  # [B,nC,H]

    def step(carry, inp):
        s_local, decay = inp  # [B,H,N,P], [B,H]
        h_in = carry
        carry = s_local + decay[:, :, None, None] * carry
        return carry, h_in

    _, h_in = jax.lax.scan(
        step,
        jnp.zeros((b, h, n, pdim), f32),
        (s_loc.transpose(1, 0, 2, 3, 4), jnp.exp(a_sum).transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # [B,nC,H,N,P]

    # --- inter-chunk output (batched, outside the scan) --------------------
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cc,
                         jnp.exp(acum).astype(dt_c), h_in,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    y = y + p["d_skip"].astype(f32)[None, None, :, None] * xh.astype(f32)
    y = y.reshape(b, s, di).astype(x.dtype)
    return _post(p, y, z, cfg)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_ssm_cache(
    cfg: ModelConfig, batch: int, n_ssm_layers: int, dtype
) -> Dict[str, jax.Array]:
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cw = cfg.ssm_conv_width
    return {
        "state": jnp.zeros((n_ssm_layers, batch, h, n, pdim), jnp.float32),
        "conv": jnp.zeros((n_ssm_layers, batch, cw - 1, di + 2 * n), dtype),
    }


def ssm_decode(
    p: Dict,
    x: jax.Array,  # [B, 1, D]
    state: jax.Array,  # [B, H, N, P] f32
    conv: jax.Array,  # [B, cw-1, di+2N]
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b = x.shape[0]
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = dense(p["z_proj"], x)
    xbc_new = jnp.concatenate(
        [dense(p["x_proj"], x), dense(p["b_proj"], x), dense(p["c_proj"], x)],
        axis=-1,
    )  # [B,1,di+2N]
    window = jnp.concatenate([conv, xbc_new], axis=1)  # [B,cw,di+2N]
    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_b"], p["conv_c"]], axis=1
    ).astype(window.dtype)
    xbc = jax.nn.silu(jnp.einsum("bsc,sc->bc", window, conv_w))[:, None, :]
    conv_next = window[:, 1:]
    xc, bmat, cmat = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]

    f32 = jnp.float32
    dt_raw = dense(p["dt_proj"], x)[:, 0]
    dt = jax.nn.softplus(dt_raw.astype(f32) + p["dt_bias"].astype(f32))  # [B,H]
    a = -jnp.exp(p["a_log"].astype(f32))
    decay = jnp.exp(dt * a)  # [B,H]
    xh = xc.reshape(b, h, pdim).astype(f32)
    bv = bmat[:, 0].astype(f32)  # [B,N]
    cv = cmat[:, 0].astype(f32)
    state = decay[:, :, None, None] * state + (
        dt[:, :, None, None] * bv[:, None, :, None] * xh[:, :, None, :]
    )
    y = jnp.einsum("bn,bhnp->bhp", cv, state)
    y = y + p["d_skip"].astype(f32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    return _post(p, y, z, cfg), state, conv_next
