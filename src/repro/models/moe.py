"""Mixture-of-Experts with sort-based capacity dispatch.

DESIGN.md Sec. 3: the token→expert assignment is a block-sparse matrix and
sorting the (token, expert) pairs by expert *is* the paper's CSV vector-major
pre-processing — every token tile of one expert shares that expert's weight
tile exactly like CSV vectors share one buffered B row (the Sec. 4.1 scheme).
On TPU the expert compute dispatches to the ``moe_gmm`` grouped-matmul
Pallas kernel; the portable path below realizes the same schedule with a
capacity-slotted batched einsum (deterministic shapes for pjit).

Experts are sharded over the ``expert`` logical axis (EP); the scatter into
the [E, C, D] dispatch tensor from batch-sharded tokens is the all-to-all.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import current_mesh, mesh_axis, shard, shard_map
from repro.models.config import ModelConfig
from repro.models.nn import Param
from repro.models.mlp import _act

__all__ = ["moe_t", "moe_forward"]


def moe_t(cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.expert_ff, cfg.n_experts
    t: Dict = {
        "router": {"w": Param((d, e), ("embed", None), "normal:0.02")},
        "wd": {"w": Param((e, f, d), ("expert", "expert_mlp", "embed"))},
    }
    if cfg.mlp_gated:
        t["wg"] = {"w": Param((e, d, f), ("expert", "embed", "expert_mlp"))}
        t["wu"] = {"w": Param((e, d, f), ("expert", "embed", "expert_mlp"))}
    else:
        t["wu"] = {"w": Param((e, d, f), ("expert", "embed", "expert_mlp"))}
    return t


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # multiple of 8, ≥ 8


def _moe_local(p: Dict, x: jax.Array, cfg: ModelConfig, n_local: int,
               model_axis) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE body (runs inside shard_map).

    Tokens are replicated across the expert-parallel axis; each shard owns
    ``n_local`` experts (weights arrive pre-sliced), routes the *full*
    token set against the full router, dispatches only the tokens whose
    expert lives here (local scatter — no cross-shard gather/scatter, the
    pattern GSPMD otherwise replicates), computes, and contributes a
    partial combine that is psum-reduced across the axis.

    The expert-sorted dispatch order is the paper's CSV vector-major
    pre-processing at expert granularity (DESIGN.md Sec. 3).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    gates, experts = jax.lax.top_k(logits, k)  # [T, k]
    gates = jax.nn.softmax(gates, axis=-1)

    # Load-balance auxiliary loss (Switch/GShard form).
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)

    # Which of my local experts does each (token, slot) pair hit?
    if model_axis is not None:
        shard_id = jax.lax.axis_index(model_axis)
    else:
        shard_id = 0
    first = shard_id * n_local
    local_e = experts - first  # [T, k]; valid iff 0 <= local_e < n_local
    e_flat = local_e.reshape(-1)
    g_flat = gates.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    mine = (e_flat >= 0) & (e_flat < n_local)

    # CSV-order: stable-sort pairs by local expert; position within group.
    order = jnp.argsort(jnp.where(mine, e_flat, n_local), stable=True)
    e_sort = e_flat[order]
    g_sort = g_flat[order]
    tok_sort = tok_flat[order]
    mine_sort = mine[order]
    group_start = jnp.searchsorted(
        jnp.where(mine_sort, e_sort, n_local), jnp.arange(n_local), side="left")
    pos = jnp.arange(t * k) - group_start[jnp.clip(e_sort, 0, n_local - 1)]
    cap = _capacity(t, cfg)
    keep = mine_sort & (pos < cap)

    dt = x.dtype
    dispatch = jnp.zeros((n_local, cap, d), dt)
    dispatch = dispatch.at[
        jnp.where(keep, e_sort, n_local - 1),
        jnp.where(keep, pos, cap - 1),
    ].add(jnp.where(keep[:, None], xf[tok_sort], 0).astype(dt))

    # --- expert compute (grouped matmul; jnp twin of kernels/moe_gmm) -----
    act = _act(cfg.act)
    if cfg.mlp_gated:
        h = act(jnp.einsum("ecd,edf->ecf", dispatch, p["wg"]["w"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", dispatch, p["wu"]["w"].astype(dt))
    else:
        h = act(jnp.einsum("ecd,edf->ecf", dispatch, p["wu"]["w"].astype(dt)))
    y_exp = jnp.einsum("ecf,efd->ecd", h, p["wd"]["w"].astype(dt))

    # --- combine: local gather + gate weight; partial across shards -------
    gathered = y_exp[
        jnp.where(keep, e_sort, 0), jnp.where(keep, pos, 0)
    ]  # [T*k, D]
    contrib = jnp.where(keep[:, None], gathered * g_sort[:, None].astype(dt), 0)
    y = jnp.zeros((t, d), dt).at[tok_sort].add(contrib)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
    return y.reshape(b, s, d), aux.astype(jnp.float32)


def moe_forward(
    p: Dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss). Capacity-dropped tokens pass through
    with zero expert contribution (standard Switch behaviour).

    Expert parallelism runs under shard_map: GSPMD's handling of the
    scatter/gather dispatch replicates the [E, C, D] tensor across the
    mesh (measured: 83 GiB/device for qwen3 train_4k); the shard_map body
    keeps dispatch local to each expert shard and reduces the combine.
    """
    mesh = current_mesh()
    exp_axis = mesh_axis("expert")
    if mesh is None or exp_axis is None:
        y, aux = _moe_local(p, x, cfg, cfg.n_experts, None)
        return shard(y, "batch", "seq", "embed"), aux

    axis = exp_axis if isinstance(exp_axis, str) else exp_axis[0]
    ep = mesh.shape[axis]
    n_local = cfg.n_experts // ep
    # Follow the rules table for the batch layout (B=1 decode replicates).
    batch_spec = mesh_axis("batch")

    gated = "wg" in p

    def body(router_w, ws, xs):
        pl = {"router": {"w": router_w}, "wu": {"w": ws[0]}, "wd": {"w": ws[1]}}
        if gated:
            pl["wg"] = {"w": ws[2]}
        return _moe_local(pl, xs, cfg, n_local, axis)

    ws = (p["wu"]["w"], p["wd"]["w"]) + ((p["wg"]["w"],) if gated else ())
    in_specs = (
        P(None, None),  # router replicated
        tuple(P(axis, None, None) for _ in ws),  # expert-sharded weights
        P(batch_spec, None, None),  # x: batch over data, replicated on model
    )
    out_specs = (P(batch_spec, None, None), P())
    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(p["router"]["w"], ws, x)
    return shard(y, "batch", "seq", "embed"), aux
