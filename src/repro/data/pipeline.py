"""Deterministic synthetic data pipeline.

Properties a production input pipeline needs and this one has:

* **step-indexed determinism** — ``batch(step)`` is a pure function of
  (seed, step), so restarts resume mid-epoch with no state file and every
  data-parallel worker can regenerate any batch (elastic restarts re-slice
  the same global batch across a different device count);
* **device placement** — ``shard_batch`` lays the global batch out on the
  mesh with the ``batch``-axis sharding the model expects;
* **prefetch** — a background thread keeps ``prefetch`` batches ahead of
  the training loop.

The token stream is a mixture of structured sequences (ramps, repeats,
n-gram chains) so tiny-model training visibly reduces loss — pure-uniform
tokens have no learnable signal.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sparse.formats import COO

__all__ = ["SyntheticLM", "SpGEMMValueStream", "batch_specs", "shard_batch"]


def _prefetch_iter(batch_at, start_step: int, prefetch: int) -> Iterator[Dict]:
    """Background-thread prefetching iterator over ``batch_at(step)``.

    The producer uses a timed ``put`` so it re-checks the stop flag even
    while the queue is full — dropping the iterator can never leak a
    thread blocked in ``q.put``. A ``batch_at`` failure is forwarded and
    re-raised in the consumer instead of silently killing the producer
    (which would deadlock the consumer in ``q.get``).
    """
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def _put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        step = start_step
        try:
            while not stop.is_set():
                if not _put(("batch", batch_at(step))):
                    return
                step += 1
        except BaseException as e:  # forward to the consumer
            _put(("error", e))

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            kind, payload = q.get()
            if kind == "error":
                raise payload
            yield payload
    finally:
        stop.set()


class SyntheticLM:
    """Deterministic synthetic LM batches for a given config."""

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def _tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab
        kind = rng.integers(0, 3, b)
        out = np.empty((b, s), np.int32)
        for i in range(b):
            if kind[i] == 0:  # ramp with random stride
                start, stride = rng.integers(0, v), rng.integers(1, 7)
                out[i] = (start + stride * np.arange(s)) % v
            elif kind[i] == 1:  # repeated motif
                mlen = int(rng.integers(2, 17))
                motif = rng.integers(0, v, mlen)
                out[i] = np.tile(motif, s // mlen + 1)[:s]
            else:  # first-order chain: next = (3*prev + c) % v
                c = int(rng.integers(1, v))
                seq = np.empty(s, np.int64)
                seq[0] = rng.integers(0, v)
                for t in range(1, s):
                    seq[t] = (3 * seq[t - 1] + c) % v
                out[i] = seq
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        cfg = self.cfg
        if cfg.frontend == "audio":
            feats = rng.standard_normal(
                (self.batch, self.seq, cfg.frontend_dim)
            ).astype(np.float32)
            labels = rng.integers(0, cfg.vocab, (self.batch, self.seq)).astype(np.int32)
            mask = (rng.random((self.batch, self.seq)) < 0.08).astype(np.float32)
            return {"feats": feats, "labels": labels, "mask": mask}
        if cfg.frontend == "vision":
            s_text = self.seq - cfg.num_patches
            toks = self._tokens(rng, self.batch, s_text + 1)
            feats = rng.standard_normal(
                (self.batch, cfg.num_patches, cfg.frontend_dim)
            ).astype(np.float32)
            return {
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
                "feats": feats,
            }
        toks = self._tokens(rng, self.batch, self.seq + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iter(self, start_step: int = 0, prefetch: int = 2) -> Iterator[Dict]:
        """Background-thread prefetching iterator starting at start_step."""
        return _prefetch_iter(self.batch_at, start_step, prefetch)


class SpGEMMValueStream:
    """Serving-shaped SpGEMM workload: one fixed sparsity pattern, fresh
    values every step.

    This is the input side of the plan/execute split
    (:mod:`repro.spgemm`): the pattern is fixed at construction — exactly
    what a cached :class:`~repro.spgemm.plan.SpGEMMPlan` amortizes over —
    and ``values_at(step)`` is a pure function of ``(seed, step)``, so the
    stream has the same step-indexed determinism/restart properties as
    :class:`SyntheticLM`.

    ``integer_values=True`` draws small integers (exact in float32 under
    any accumulation order) so results can be compared bit-for-bit against
    the ``spgemm_gustavson`` oracle.

    ``batch`` switches the stream to batch mode — the input side of
    ``SpGEMMPlan.execute_batch``: ``values_batch_at(step)`` stacks ``batch``
    consecutive single-step value sets into ``[batch, nnz]`` arrays, with
    element ``i`` of batch-step ``s`` equal to ``values_at(s * batch + i)``,
    so batched serving consumes exactly the single-stream sequence.
    """

    def __init__(
        self,
        a_pattern: COO,
        b_pattern: COO,
        seed: int = 0,
        integer_values: bool = False,
        batch: Optional[int] = None,
    ):
        if a_pattern.shape[1] != b_pattern.shape[0]:
            raise ValueError(
                f"inner dims mismatch: {a_pattern.shape} x {b_pattern.shape}"
            )
        if batch is not None and batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.a_pattern = a_pattern
        self.b_pattern = b_pattern
        self.seed = seed
        self.integer_values = integer_values
        self.batch = batch

    def _vals(self, rng: np.random.Generator, nnz: int) -> np.ndarray:
        if self.integer_values:
            v = rng.integers(-4, 5, nnz).astype(np.float32)
            return np.where(v == 0, np.float32(1.0), v)
        return rng.standard_normal(nnz).astype(np.float32)

    def values_at(self, step: int):
        """Fresh ``(a_vals, b_vals)`` for this step, aligned with the
        patterns' canonical coordinate order."""
        rng = np.random.default_rng((self.seed, step))
        return (
            self._vals(rng, self.a_pattern.nnz),
            self._vals(rng, self.b_pattern.nnz),
        )

    def values_batch_at(self, step: int, batch: Optional[int] = None):
        """Stacked ``(a_vals[batch, nnz_a], b_vals[batch, nnz_b])`` for
        batch-step ``step`` — row ``i`` is ``values_at(step * batch + i)``.

        ``batch`` overrides the stream's constructed batch size."""
        b = self.batch if batch is None else batch
        if b is None:
            raise ValueError(
                "no batch size: construct with batch=... or pass batch"
            )
        a_out = np.empty((b, self.a_pattern.nnz), np.float32)
        b_out = np.empty((b, self.b_pattern.nnz), np.float32)
        for i in range(b):
            a_out[i], b_out[i] = self.values_at(step * b + i)
        return a_out, b_out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Single-step value dict, or stacked ``[batch, nnz]`` arrays when
        the stream was constructed in batch mode."""
        if self.batch is not None:
            a_vals, b_vals = self.values_batch_at(step)
        else:
            a_vals, b_vals = self.values_at(step)
        return {"a_vals": a_vals, "b_vals": b_vals}

    def iter(self, start_step: int = 0, prefetch: int = 2) -> Iterator[Dict]:
        """Background-thread prefetching iterator (same contract as
        :meth:`SyntheticLM.iter`)."""
        return _prefetch_iter(self.batch_at, start_step, prefetch)

    def value_iter(
        self,
        start_step: int = 0,
        steps: Optional[int] = None,
        prefetch: int = 2,
    ) -> Iterator[tuple]:
        """``(a_vals, b_vals)`` tuples, prefetched — the feed side of
        ``SpGEMMPlan.execute_stream`` / ``SpGEMMPipeline.stream``.

        Value generation runs in the prefetch thread, so it overlaps the
        pipeline's device compute like every other stage. ``steps=N``
        makes the iterator finite (the stream drains after N results);
        ``steps=None`` streams forever. In batch mode each item is a
        stacked ``[batch, nnz]`` pair (one pipelined ``execute_batch``
        step)."""
        it = self.iter(start_step, prefetch)
        try:
            n = 0
            while steps is None or n < steps:
                d = next(it)
                yield d["a_vals"], d["b_vals"]
                n += 1
        finally:
            it.close()


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins matching batch_at (for the dry-run)."""
    f32, i32 = jnp.float32, jnp.int32
    if cfg.frontend == "audio":
        return {
            "feats": jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32),
        }
    if cfg.frontend == "vision":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq - cfg.num_patches), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq - cfg.num_patches), i32),
            "feats": jax.ShapeDtypeStruct(
                (batch, cfg.num_patches, cfg.frontend_dim), f32
            ),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]) -> Dict:
    """Place a host batch on the mesh, batch dim over the data axes."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = P(data_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
