from repro.data.pipeline import SyntheticLM, batch_specs, shard_batch
