"""Fault-tolerant checkpointing.

Guarantees:

* **atomicity** — writes go to ``step_<n>.tmp/`` and are renamed to
  ``step_<n>/`` only after every chunk and the manifest are fsynced; a
  crash mid-save never corrupts the latest checkpoint;
* **integrity** — the manifest records SHA256 per chunk; ``restore``
  verifies before use and refuses truncated/bit-rotten files;
* **mesh-agnosticism (elastic)** — chunks store *full* (unsharded) arrays,
  so a checkpoint written on N devices restores onto any mesh/device count:
  ``restore(..., shardings=...)`` lays leaves out per the target sharding
  (reshard-on-load). Tested across 8->4->1 device moves;
* **retention** — keeps the newest ``keep`` checkpoints, deleting older
  ones only after a newer one is durable;
* **async** — ``save(..., blocking=False)`` snapshots to host memory
  synchronously (consistent view) and writes in a background thread.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _tree_paths(tree: Any) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()  # one async save in flight at a time
        # Snapshot to host memory synchronously: consistent view even if
        # training mutates arrays afterwards.
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(x) for x in leaves]
        paths = _tree_paths(tree)

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest: Dict[str, Any] = {"step": step, "chunks": []}
            for i, (arr, p) in enumerate(zip(host, paths)):
                fn = f"chunk_{i:05d}.npy"
                fp = os.path.join(tmp, fn)
                logical = str(arr.dtype)
                stored = arr
                if arr.dtype.kind == "V" or logical not in np.sctypeDict:
                    # ml_dtypes (bfloat16, fp8...) don't survive np.save;
                    # store raw bits and record the logical dtype.
                    stored = arr.view(f"u{arr.dtype.itemsize}")
                with open(fp, "wb") as f:
                    np.save(f, stored)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["chunks"].append(
                    {
                        "index": i,
                        "path": p,
                        "file": fn,
                        "shape": list(arr.shape),
                        "dtype": logical,
                        "stored_dtype": str(stored.dtype),
                        "sha256": _sha256(fp),
                    }
                )
            mf = os.path.join(tmp, "manifest.json")
            with open(mf, "w") as f:
                json.dump(manifest, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            def run():
                try:
                    write()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- introspection -----------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- restore -----------------------------------------------------------
    def restore(
        self,
        step: int,
        like: Any,
        shardings: Optional[Any] = None,
        verify: bool = True,
    ) -> Any:
        """Restore into the structure of ``like``; place leaves per
        ``shardings`` (same structure, NamedSharding leaves) when given —
        this is the elastic reshard-on-load path."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(manifest["chunks"]) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['chunks'])} leaves, "
                f"target structure has {len(leaves)}"
            )
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for rec, ref, shd in zip(manifest["chunks"], leaves, shard_leaves):
            fp = os.path.join(d, rec["file"])
            if verify and _sha256(fp) != rec["sha256"]:
                raise IOError(f"checkpoint chunk corrupt: {fp}")
            arr = np.load(fp)
            if rec.get("stored_dtype", rec["dtype"]) != rec["dtype"]:
                # raw-bits chunk: view back to the logical dtype
                try:
                    dt = np.dtype(rec["dtype"])
                except TypeError:
                    import ml_dtypes

                    dt = np.dtype(getattr(ml_dtypes, rec["dtype"]))
                arr = arr.view(dt)
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {rec['path']}: "
                    f"{arr.shape} vs {ref.shape}"
                )
            if shd is not None:
                out.append(jax.device_put(arr.astype(ref.dtype), shd))
            else:
                out.append(jax.numpy.asarray(arr.astype(ref.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out)
