"""Liveness heartbeats.

Each worker process touches ``<dir>/heartbeat_<host>.json`` every
``interval`` seconds from a daemon thread; an external supervisor (or the
coordinator) declares a worker dead after ``timeout`` without a beat and
triggers restart-from-checkpoint. ``check_peers`` implements the
supervisor-side scan."""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List

__all__ = ["Heartbeat", "check_peers"]


class Heartbeat:
    def __init__(self, directory: str, host: str = "host0", interval: float = 5.0):
        self.path = os.path.join(directory, f"heartbeat_{host}.json")
        self.interval = interval
        self.host = host
        os.makedirs(directory, exist_ok=True)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.step = 0

    def beat(self) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host, "time": time.time(),
                       "step": self.step}, f)
        os.replace(tmp, self.path)

    def start(self) -> None:
        def run():
            while not self._stop.wait(self.interval):
                self.beat()

        self.beat()
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join()


def check_peers(directory: str, timeout: float) -> Dict[str, List[str]]:
    """Supervisor scan: classify workers as alive/dead by beat age."""
    now = time.time()
    alive, dead = [], []
    if os.path.isdir(directory):
        for name in os.listdir(directory):
            if not name.startswith("heartbeat_") or name.endswith(".tmp"):
                continue
            try:
                with open(os.path.join(directory, name)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue
            (alive if now - rec["time"] <= timeout else dead).append(rec["host"])
    return {"alive": sorted(alive), "dead": sorted(dead)}
