"""Liveness heartbeats + a reusable process-metrics exporter.

Two layers:

* **Metrics** (:class:`MetricsRegistry` and its :class:`Counter` /
  :class:`Gauge` / :class:`Summary` instruments) — a dependency-free,
  thread-safe registry any subsystem can write into.  The serving gateway
  (:mod:`repro.spgemm.gateway`) records per-pattern queue depth, batch
  fill, latency quantiles, throughput, and shed counts here;
  ``registry.snapshot()`` renders everything as one plain dict.
* **Liveness** (:class:`Heartbeat`) — each worker process touches
  ``<dir>/heartbeat_<host>.json`` every ``interval`` seconds from a
  daemon thread; an external supervisor (or the coordinator) declares a
  worker dead after ``timeout`` without a beat and triggers
  restart-from-checkpoint.  ``check_peers`` implements the
  supervisor-side scan.  Passing ``metrics=registry`` embeds a metrics
  snapshot in every beat, which turns the heartbeat file into a cheap
  pull-based metrics export: whatever scrapes liveness scrapes the
  serving metrics too.
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Heartbeat",
    "MetricsRegistry",
    "Summary",
    "check_peers",
]


class Counter:
    """Monotonic counter (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Summary:
    """Windowed distribution: lifetime count/sum plus quantiles over the
    last ``window`` observations (enough for serving p50/p99 without
    unbounded memory)."""

    __slots__ = ("_lock", "_window", "count", "total")

    def __init__(self, window: int = 2048):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._lock = threading.Lock()
        self._window: deque = deque(maxlen=window)
        self.count = 0
        self.total = 0.0

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._window.append(v)
            self.count += 1
            self.total += v

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained window (0 when
        empty). ``p`` in [0, 100]."""
        with self._lock:
            vals = sorted(self._window)
        if not vals:
            return 0.0
        rank = max(0, min(len(vals) - 1, math.ceil(p / 100.0 * len(vals)) - 1))
        return vals[rank]

    def snapshot(self) -> dict:
        with self._lock:
            vals = sorted(self._window)
            count, total = self.count, self.total

        def pct(p: float) -> float:
            if not vals:
                return 0.0
            rank = max(0, min(len(vals) - 1,
                              math.ceil(p / 100.0 * len(vals)) - 1))
            return vals[rank]

        return {
            "count": count,
            "mean": (total / count) if count else 0.0,
            "min": vals[0] if vals else 0.0,
            "max": vals[-1] if vals else 0.0,
            "p50": pct(50.0),
            "p90": pct(90.0),
            "p99": pct(99.0),
        }


class MetricsRegistry:
    """Named instruments, created on first use, rendered by
    :meth:`snapshot`.

    Names are opaque dotted strings (``gateway.<pattern>.latency_s``);
    re-requesting a name returns the same instrument, and requesting an
    existing name as a different instrument type raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(*args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def summary(self, name: str, window: int = 2048) -> Summary:
        return self._get(name, Summary, window)

    def snapshot(self) -> dict:
        """Every instrument's current value as a plain (JSON-serializable)
        dict: counters/gauges flatten to numbers, summaries to their
        quantile dicts."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for name, m in items:
            out[name] = m.snapshot() if isinstance(m, Summary) else m.value
        return out


class Heartbeat:
    def __init__(self, directory: str, host: str = "host0",
                 interval: float = 5.0,
                 metrics: Optional[MetricsRegistry] = None):
        self.path = os.path.join(directory, f"heartbeat_{host}.json")
        self.interval = interval
        self.host = host
        self.metrics = metrics
        os.makedirs(directory, exist_ok=True)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.step = 0

    def beat(self) -> None:
        rec = {"host": self.host, "time": time.time(), "step": self.step}
        if self.metrics is not None:
            rec["metrics"] = self.metrics.snapshot()
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)

    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("heartbeat already running; stop() it first")
        # A fresh event per start: stop() leaves the old event set, and a
        # restarted thread waiting on it would exit immediately without
        # ever beating again.
        self._stop = threading.Event()
        stop = self._stop

        def run():
            while not stop.wait(self.interval):
                self.beat()

        self.beat()
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join()
            self._thread = None


def check_peers(directory: str, timeout: float) -> Dict[str, List[str]]:
    """Supervisor scan: classify workers as alive/dead by beat age."""
    now = time.time()
    alive, dead = [], []
    if os.path.isdir(directory):
        for name in os.listdir(directory):
            if not name.startswith("heartbeat_") or name.endswith(".tmp"):
                continue
            try:
                with open(os.path.join(directory, name)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue
            (alive if now - rec["time"] <= timeout else dead).append(rec["host"])
    return {"alive": sorted(alive), "dead": sorted(dead)}
