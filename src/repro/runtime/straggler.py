"""Step-time straggler detection.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, noisy
neighbours) stretch every synchronous step. The detector keeps an EMA of
step time and variance; a step whose z-score exceeds the threshold for
``patience`` consecutive steps fires the mitigation hook (in production:
drain + re-slice the mesh; here: a callback + log record, exercised by
tests)."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

__all__ = ["StragglerDetector"]


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1  # EMA coefficient
    z_threshold: float = 3.0
    patience: int = 3
    warmup: int = 5  # steps before detection arms
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _breaches: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record one step time; returns True when mitigation fires."""
        self._n += 1
        if self._n == 1:
            self._mean = dt
            return False
        delta = dt - self._mean
        z = delta / math.sqrt(self._var) if self._var > 0 else 0.0
        fired = False
        if self._n > self.warmup and z > self.z_threshold:
            self._breaches += 1
            if self._breaches >= self.patience:
                fired = True
                self.events.append({"step": step, "dt": dt, "z": z})
                if self.on_straggler:
                    self.on_straggler(step, dt, z)
                self._breaches = 0
        else:
            self._breaches = 0
            # Only fold healthy steps into the baseline.
            self._mean += self.alpha * delta
            self._var = (1 - self.alpha) * (self._var + self.alpha * delta * delta)
        return fired
