"""Fault-tolerant training loop.

Composes the substrate into the loop a cluster job actually runs:

* **auto-resume** — on start, restores the newest intact checkpoint (mesh-
  agnostic chunks -> works across device-count changes = elastic restart);
* **SIGTERM/SIGINT safety** — preemption signals set a flag; the loop
  checkpoints at the next step boundary and exits cleanly;
* **periodic + async checkpoints** — snapshot every ``ckpt_every`` steps
  without stalling the step loop;
* **straggler watchdog** — EMA z-score step-time detector with a hook;
* **heartbeats** — liveness files for an external supervisor.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.heartbeat import Heartbeat
from repro.runtime.straggler import StragglerDetector

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    install_signal_handlers: bool = True
    heartbeat: bool = True


class Trainer:
    def __init__(
        self,
        tc: TrainerConfig,
        train_step: Callable,  # (params, opt_state, batch) -> (p, s, metrics)
        batches: Iterator[Dict],
        params: Any,
        opt_state: Any,
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ):
        self.tc = tc
        self.train_step = train_step
        self.batches = batches
        self.params = params
        self.opt_state = opt_state
        self.on_metrics = on_metrics
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep)
        self.detector = StragglerDetector()
        self.hb = Heartbeat(tc.ckpt_dir) if tc.heartbeat else None
        self.step = 0
        self._preempted = False
        self.history: list = []

    # -- fault-tolerance plumbing ----------------------------------------
    def _handle_signal(self, signum, frame):  # pragma: no cover - signal path
        self._preempted = True

    def _maybe_resume(self) -> None:
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        state = {"params": self.params, "opt": self.opt_state}
        restored = self.ckpt.restore(latest, state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = latest
        print(f"[trainer] resumed from checkpoint step {latest}")

    def _save(self, blocking: bool) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            blocking=blocking,
        )

    # -- the loop -----------------------------------------------------------
    def run(self) -> Dict:
        tc = self.tc
        if tc.install_signal_handlers:
            try:
                signal.signal(signal.SIGTERM, self._handle_signal)
                signal.signal(signal.SIGINT, self._handle_signal)
            except ValueError:  # non-main thread (tests)
                pass
        self._maybe_resume()
        if self.hb:
            self.hb.start()
        t_prev = time.perf_counter()
        try:
            while self.step < tc.total_steps and not self._preempted:
                batch = next(self.batches)
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch
                )
                jax.block_until_ready(metrics["loss"])
                self.step += 1
                now = time.perf_counter()
                self.detector.observe(self.step, now - t_prev)
                t_prev = now
                if self.hb:
                    self.hb.step = self.step
                if self.step % tc.log_every == 0 or self.step == tc.total_steps:
                    rec = {k: float(v) for k, v in metrics.items()}
                    rec["step"] = self.step
                    self.history.append(rec)
                    if self.on_metrics:
                        self.on_metrics(self.step, rec)
                if self.step % tc.ckpt_every == 0:
                    self._save(blocking=not tc.ckpt_async)
        finally:
            # Preemption / normal exit: make the final state durable.
            self.ckpt.wait()
            self._save(blocking=True)
            if self.hb:
                self.hb.stop()
        return {
            "final_step": self.step,
            "preempted": self._preempted,
            "history": self.history,
            "straggler_events": self.detector.events,
        }
