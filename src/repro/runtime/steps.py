"""pjit-able train / prefill / decode step builders.

The returned functions are pure (params/state in, params/state out) and are
annotated internally with logical-axis sharding constraints; the launcher
decides in/out shardings and wraps them in ``jax.jit`` under an active
``use_rules`` context.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.optim.clip import clip_by_global_norm

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "cache_axes",
    "batch_axes",
]


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    clip_norm: float = 1.0,
    microbatches: int = 1,
    grad_shardings: Optional[Any] = None,
):
    """Build the train step.

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split along dim 0 and fwd+bwd runs once per microbatch (an *unrolled*
    loop — exact HLO cost accounting, same live-memory behaviour as a scan
    since buffers are reused sequentially). The f32 accumulator is pinned
    to ``grad_shardings`` (the ZeRO-1 layout) so each shard holds 1/DP of
    the gradient — XLA fuses the DP all-reduce into a reduce-scatter.
    """
    compute_dt = cfg.compute_dtype()

    def loss_fn(p, ubatch):
        pc = jax.tree.map(
            lambda x: x.astype(compute_dt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            p,
        )
        return tr.lm_loss(pc, cfg, **ubatch)

    def train_step(params, opt_state, batch):
        u = microbatches
        if u == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            # Keep gradients in bf16 end-to-end: the DP reduction moves
            # half the bytes and no full-size f32 gradient tensor ever
            # exists — the optimizer upcasts per-element on ZeRO shards.
            if grad_shardings is not None:
                grads = jax.tree.map(
                    jax.lax.with_sharding_constraint, grads, grad_shardings)
        else:
            # lax.scan over microbatches: true sequential execution — the
            # scheduler cannot overlap two microbatch backwards (observed
            # with an unrolled loop: u live gradient trees). The f32
            # accumulator rides the carry in the ZeRO-sharded layout.
            split = jax.tree.map(
                lambda x: x.reshape(u, x.shape[0] // u, *x.shape[1:]), batch)

            def ubatch_body(carry, ub):
                acc, loss_acc, met_acc = carry
                (li, mi), gi = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, ub)
                # bf16 reduce-scatter, then accumulate in f32 on the shard.
                if grad_shardings is not None:
                    gi = jax.tree.map(
                        jax.lax.with_sharding_constraint, gi, grad_shardings)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, gi)
                return (acc, loss_acc + li / u,
                        jax.tree.map(lambda a, b: a + b / u, met_acc, mi)), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if grad_shardings is not None:
                acc0 = jax.tree.map(
                    jax.lax.with_sharding_constraint, acc0, grad_shardings)
            met0 = {"loss": jnp.zeros((), jnp.float32),
                    "moe_aux": jnp.zeros((), jnp.float32)}
            (grads, loss, metrics), _ = jax.lax.scan(
                ubatch_body, (acc0, jnp.zeros((), jnp.float32), met0), split)
            grads = jax.tree.map(lambda g: g / u, grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["total_loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt; returns last-position logits."""

    def prefill_step(params, batch):
        logits, _ = tr.forward(
            params, cfg,
            tokens=batch.get("tokens"), feats=batch.get("feats"),
        )
        return logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token):
        return tr.decode_step(params, cache, cfg, token)

    return decode_step


# ---------------------------------------------------------------------------
# Logical axes for the non-param trees (the launcher resolves via rules)
# ---------------------------------------------------------------------------

def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical axes tree matching tr.init_cache."""
    out: Dict[str, Any] = {"pos": ()}
    if any(b.mixer == "attn" for b in cfg.block_pattern):
        kvax = (None, "kv_batch", "kv_seq", "kv_heads", "head_dim")
        out["kv"] = {"k": kvax, "v": kvax}
    if any(b.mixer == "ssm" for b in cfg.block_pattern):
        out["ssm"] = {
            "state": (None, "kv_batch", "heads", None, None),
            "conv": (None, "kv_batch", None, None),
        }
    return out


def batch_axes(cfg: ModelConfig, kind: str = "train") -> Dict:
    """Logical axes for the data batch (matches data.batch_specs)."""
    if cfg.frontend == "audio":
        base = {"feats": ("batch", "seq", None)}
        if kind == "train":
            base["labels"] = ("batch", "seq")
            base["mask"] = ("batch", "seq")
        return base
    if cfg.frontend == "vision":
        base = {
            "tokens": ("batch", "seq"),
            "feats": ("batch", None, None),
        }
        if kind == "train":
            base["labels"] = ("batch", "seq")
        return base
    base = {"tokens": ("batch", "seq")}
    if kind == "train":
        base["labels"] = ("batch", "seq")
    return base
