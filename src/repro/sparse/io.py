"""Matrix file I/O (the paper's "raw matrix files", Sec. 4.3).

Supports MatrixMarket (.mtx) coordinate format — the SuiteSparse interchange
format — plus a fast binary container for the pre-processed CSV/BCSV forms
("the pre-processing step only needs to be performed once").
"""
from __future__ import annotations

import json
import os
from typing import Union

import numpy as np

from repro.sparse.formats import BCSV, COO, CSR, CSV


def read_matrix_market(path: str) -> COO:
    """Minimal MatrixMarket coordinate reader (real/integer/pattern, general
    or symmetric)."""
    with open(path, "r") as f:
        header = f.readline()
        if not header.startswith("%%MatrixMarket"):
            raise ValueError("not a MatrixMarket file")
        parts = header.lower().split()
        fmt, field, symmetry = parts[2], parts[3], parts[4]
        if fmt != "coordinate":
            raise ValueError("only coordinate format supported")
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        m, n, nnz = (int(x) for x in line.split())
        rows = np.empty(nnz, dtype=np.int64)
        cols = np.empty(nnz, dtype=np.int64)
        vals = np.empty(nnz, dtype=np.float32)
        for i in range(nnz):
            toks = f.readline().split()
            rows[i] = int(toks[0]) - 1
            cols[i] = int(toks[1]) - 1
            vals[i] = float(toks[2]) if field != "pattern" else 1.0
    if symmetry == "symmetric":
        off = rows != cols  # mirror strictly-off-diagonal entries
        rows, cols, vals = (
            np.concatenate([rows, cols[off]]),
            np.concatenate([cols, rows[off]]),
            np.concatenate([vals, vals[off]]),
        )
    coo = COO(rows.astype(np.int32), cols.astype(np.int32), vals, (m, n))
    return coo.sum_duplicates()


def write_matrix_market(path: str, a: Union[COO, CSR]) -> None:
    coo = a if isinstance(a, COO) else a.to_coo()
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"{coo.shape[0]} {coo.shape[1]} {coo.nnz}\n")
        for r, c, v in zip(coo.row, coo.col, coo.val):
            f.write(f"{int(r) + 1} {int(c) + 1} {float(v):.9g}\n")


def save_csv(path: str, a: CSV) -> None:
    """Persist a pre-processed CSV matrix (one .npz + manifest)."""
    np.savez(
        path if path.endswith(".npz") else path + ".npz",
        val=a.val,
        row_ind=a.row_ind,
        col_ind=a.col_ind,
        shape=np.asarray(a.shape, dtype=np.int64),
        num_pe=np.asarray([a.num_pe], dtype=np.int64),
    )


def load_csv(path: str) -> CSV:
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    return CSV(
        z["val"],
        z["row_ind"],
        z["col_ind"],
        tuple(int(x) for x in z["shape"]),
        int(z["num_pe"][0]),
    )


def save_bcsv(path: str, a: BCSV) -> None:
    np.savez(
        path if path.endswith(".npz") else path + ".npz",
        blocks=a.blocks,
        brow=a.brow,
        bcol=a.bcol,
        group_ptr=a.group_ptr,
        shape=np.asarray(a.shape, dtype=np.int64),
        group=np.asarray([a.group], dtype=np.int64),
    )


def load_bcsv(path: str) -> BCSV:
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    return BCSV(
        z["blocks"],
        z["brow"],
        z["bcol"],
        z["group_ptr"],
        tuple(int(x) for x in z["shape"]),
        int(z["group"][0]),
    )
