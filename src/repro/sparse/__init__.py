"""Sparse matrix formats and utilities.

Implements the paper's Compressed Sparse Vector (CSV) format (Sec. 3) plus
the standard formats it is defined against (COO/CSR/CSC) and the TPU-native
block variants (BCSR/BCSV) used by the Pallas kernels.
"""
from repro.sparse.formats import (
    COO,
    CSR,
    CSC,
    CSV,
    BCSR,
    BCSV,
    SparseFormat,
)
from repro.sparse import convert, random, io

__all__ = [
    "COO",
    "CSR",
    "CSC",
    "CSV",
    "BCSR",
    "BCSV",
    "SparseFormat",
    "convert",
    "random",
    "io",
]
