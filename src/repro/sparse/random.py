"""Synthetic sparse matrix generation.

The paper evaluates on 8 SuiteSparse matrices (Table 4). This container has
no network access, so we synthesize matrices with the *published dimensions
and densities* and a structure class matching each matrix's provenance:

* ``fem``      — banded + local stencil couplings (poisson3Da, 2cubes_sphere,
                 filter3D, offshore): nonzeros clustered near the diagonal.
* ``graph``    — power-law degree distribution (webbase-1M, cage12).
* ``circuit``  — sparse quasi-symmetric with a few dense rows/cols
                 (scircuit, mac_econ_fwd500).
* ``uniform``  — iid Erdos-Renyi (control).

``suite_matrix(name, scale=...)`` reproduces Table 4's spec; ``scale < 1``
shrinks dimensions (keeping density) so CI-sized runs stay fast. Real
``.mtx`` files are supported through :mod:`repro.sparse.io` when available.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.sparse.formats import COO, CSR


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """One row of the paper's Table 4."""

    name: str
    rows: int
    cols: int
    density: float
    structure: str  # fem | graph | circuit | uniform

    @property
    def nnz(self) -> int:
        return int(round(self.rows * self.cols * self.density))


# Paper Table 4 (dimensions use the paper's K = 1000-based rounding; offshore
# is 260K x 260K — the "260 x 260K" in the table is a typo in the original).
SUITE: Dict[str, MatrixSpec] = {
    "poisson3Da": MatrixSpec("poisson3Da", 14_000, 14_000, 1.9e-3, "fem"),
    "2cubes_sphere": MatrixSpec("2cubes_sphere", 101_000, 101_000, 1.6e-4, "fem"),
    "filter3D": MatrixSpec("filter3D", 106_000, 106_000, 2.4e-4, "fem"),
    "cage12": MatrixSpec("cage12", 130_000, 130_000, 1.2e-4, "graph"),
    "scircuit": MatrixSpec("scircuit", 171_000, 171_000, 3.3e-5, "circuit"),
    "mac_econ_fwd500": MatrixSpec("mac_econ_fwd500", 207_000, 207_000, 3.0e-5, "circuit"),
    "offshore": MatrixSpec("offshore", 260_000, 260_000, 6.3e-5, "fem"),
    "webbase-1M": MatrixSpec("webbase-1M", 1_000_000, 1_000_000, 3.1e-6, "graph"),
}


def random_coo(
    rows: int,
    cols: int,
    density: float,
    structure: str = "uniform",
    seed: int = 0,
    dtype=np.float32,
) -> COO:
    """Generate a synthetic sparse matrix of the given structure class.

    Duplicate coordinates (common for the banded classes at small scale)
    are topped up so the realized nnz tracks the requested density.
    """
    target = max(1, int(round(rows * cols * density)))
    acc: COO | None = None
    for round_ in range(4):
        need = target - (acc.nnz if acc is not None else 0)
        if need <= 0:
            break
        part = _random_coo_once(rows, cols, int(need * 1.15) + 1, structure,
                                seed + 101 * round_, dtype)
        if acc is None:
            acc = part
        else:
            import numpy as _np
            acc = COO(
                _np.concatenate([acc.row, part.row]),
                _np.concatenate([acc.col, part.col]),
                _np.concatenate([acc.val, part.val]),
                (rows, cols),
            ).sum_duplicates()
    return acc.sort_rowmajor()


def _random_coo_once(
    rows: int,
    cols: int,
    nnz: int,
    structure: str,
    seed: int,
    dtype,
) -> COO:
    rng = np.random.default_rng(seed)
    if structure == "uniform":
        r = rng.integers(0, rows, nnz)
        c = rng.integers(0, cols, nnz)
    elif structure == "fem":
        # Banded stencil: nonzeros within a narrow band around the diagonal,
        # plus per-row clustering (each row couples to ~nnz/rows neighbours).
        bandwidth = max(4, int(np.sqrt(rows)))
        r = rng.integers(0, rows, nnz)
        off = np.rint(rng.normal(0.0, bandwidth / 3.0, nnz)).astype(np.int64)
        c = np.clip(r + off, 0, cols - 1)
    elif structure == "graph":
        # Power-law (Zipf) column popularity: a few hub columns, heavy tail.
        r = rng.integers(0, rows, nnz)
        u = rng.random(nnz)
        # Inverse-CDF sample from a truncated zipf-like distribution.
        alpha = 1.3
        c = np.floor(cols * u ** (1.0 / (1.0 - alpha)) % cols).astype(np.int64)
        c = np.clip(c, 0, cols - 1)
    elif structure == "circuit":
        # Mostly near-diagonal with a sparse set of dense rows (rails).
        n_rail = max(1, rows // 2000)
        rails = rng.choice(rows, n_rail, replace=False)
        n_rail_nnz = nnz // 10
        r1 = rng.choice(rails, n_rail_nnz)
        c1 = rng.integers(0, cols, n_rail_nnz)
        n_rest = nnz - n_rail_nnz
        r2 = rng.integers(0, rows, n_rest)
        off = np.rint(rng.normal(0.0, 8.0, n_rest)).astype(np.int64)
        c2 = np.clip(r2 + off, 0, cols - 1)
        r = np.concatenate([r1, r2])
        c = np.concatenate([c1, c2])
    else:
        raise ValueError(f"unknown structure {structure!r}")
    v = rng.standard_normal(nnz).astype(dtype)
    # Avoid exact zeros so nnz is stable under dedup-by-value.
    v = np.where(v == 0, dtype(1.0), v)
    coo = COO(r.astype(np.int32), c.astype(np.int32), v, (rows, cols))
    return coo.sum_duplicates().sort_rowmajor()


def suite_matrix(name: str, scale: float = 1.0, seed: int = 0) -> CSR:
    """Synthetic stand-in for a Table 4 matrix, optionally scaled down."""
    spec = SUITE[name]
    rows = max(64, int(spec.rows * scale))
    cols = max(64, int(spec.cols * scale))
    # Keep nnz-per-row constant when scaling so the work profile matches.
    density = min(1.0, spec.density / max(scale, 1e-9))
    coo = random_coo(rows, cols, density, spec.structure, seed=seed)
    return CSR.from_coo(coo)


def random_block_sparse(
    rows: int,
    cols: int,
    block_shape: Tuple[int, int],
    block_density: float,
    seed: int = 0,
    dtype=np.float32,
) -> np.ndarray:
    """Dense array whose nonzero support is block-structured (for kernels)."""
    rng = np.random.default_rng(seed)
    bm, bk = block_shape
    if rows % bm or cols % bk:
        raise ValueError("dims must divide block shape")
    gm, gk = rows // bm, cols // bk
    mask = rng.random((gm, gk)) < block_density
    if not mask.any():
        mask[rng.integers(0, gm), rng.integers(0, gk)] = True
    dense = rng.standard_normal((rows, cols)).astype(dtype)
    dense *= np.repeat(np.repeat(mask, bm, axis=0), bk, axis=1)
    return dense
