"""Sparse matrix container formats.

Paper mapping (FSpGEMM Sec. 2.1 and Sec. 3):

* ``COO`` / ``CSR`` / ``CSC`` — the standard formats the paper builds on.
* ``CSV`` — the paper's Compressed Sparse Vector format: nonzeros stored in
  *vector-major* order. Rows are partitioned into groups of ``num_pe`` rows
  (one row per computing unit); within each group nonzeros are sorted by
  ``(col, row)``. Each nonzero carries ``(VAL, ROW_IND, COL_IND)`` so the
  reader never needs a per-row lookup table (Sec. 3). Consecutive nonzeros
  sharing a column inside one group form a "CSV vector" — they share a
  single fetch of the corresponding row of the second input matrix
  (the buffering scheme of Sec. 4.1, measured by OMAR, Eq. 1).
* ``BCSR`` / ``BCSV`` — TPU-native block variants (DESIGN.md Sec. 2): the
  same layouts at tile granularity. ``BCSV`` orders nonzero (bm, bk) blocks
  by ``(brow // group, bcol, brow)`` so the Pallas grid streams the packed
  value array sequentially from HBM and revisits the same B block-row on
  consecutive steps (the VMEM analogue of the paper's B-row buffer).

All containers are host-side ``numpy`` structures (the paper's host program
owns format conversion; Sec. 4.3). Kernels receive plain arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["SparseFormat", "COO", "CSR", "CSC", "CSV", "BCSR", "BCSV"]


def _as1d(a, dtype=None) -> np.ndarray:
    out = np.asarray(a)
    if dtype is not None:
        out = out.astype(dtype, copy=False)
    return np.ascontiguousarray(out)


class SparseFormat:
    """Base class: every format knows its dense shape and nnz."""

    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        raise NotImplementedError

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / float(m * n) if m and n else 0.0

    def todense(self) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        m, n = self.shape
        return (
            f"{type(self).__name__}(shape=({m}, {n}), nnz={self.nnz}, "
            f"density={self.density:.2e})"
        )


@dataclasses.dataclass(repr=False)
class COO(SparseFormat):
    """Coordinate format. Canonical order is row-major ``(row, col)``."""

    row: np.ndarray
    col: np.ndarray
    val: np.ndarray
    shape: Tuple[int, int]

    def __post_init__(self):
        self.row = _as1d(self.row, np.int32)
        self.col = _as1d(self.col, np.int32)
        self.val = _as1d(self.val)
        if not (self.row.shape == self.col.shape == self.val.shape):
            raise ValueError("COO arrays must have identical 1-D shapes")

    @property
    def nnz(self) -> int:
        return int(self.val.shape[0])

    def sort_rowmajor(self) -> "COO":
        order = np.lexsort((self.col, self.row))
        return COO(self.row[order], self.col[order], self.val[order], self.shape)

    def sum_duplicates(self) -> "COO":
        """Merge duplicate coordinates (paper: the 'merge' half of sort-merge)."""
        if self.nnz == 0:
            return self
        order = np.lexsort((self.col, self.row))
        r, c, v = self.row[order], self.col[order], self.val[order]
        key_change = np.empty(r.shape[0], dtype=bool)
        key_change[0] = True
        key_change[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
        idx = np.cumsum(key_change) - 1
        out_v = np.zeros(int(idx[-1]) + 1, dtype=v.dtype)
        np.add.at(out_v, idx, v)
        return COO(r[key_change], c[key_change], out_v, self.shape)

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.val.dtype)
        np.add.at(out, (self.row, self.col), self.val)
        return out

    @staticmethod
    def fromdense(a: np.ndarray) -> "COO":
        a = np.asarray(a)
        r, c = np.nonzero(a)
        return COO(r.astype(np.int32), c.astype(np.int32), a[r, c], a.shape)


@dataclasses.dataclass(repr=False)
class CSR(SparseFormat):
    """Compressed Sparse Row (paper Fig. 2, row-major order).

    ``V = data``, ``COL_INDEX = indices``, ``ROW_PTR = indptr``.
    FSpGEMM stores the *second* input matrix in CSR so a full row can be
    streamed contiguously (Sec. 4.2.2).
    """

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    def __post_init__(self):
        self.indptr = _as1d(self.indptr, np.int64)
        self.indices = _as1d(self.indices, np.int32)
        self.data = _as1d(self.data)
        if self.indptr.shape[0] != self.shape[0] + 1:
            raise ValueError(
                f"indptr length {self.indptr.shape[0]} != rows+1 ({self.shape[0] + 1})"
            )

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def row_slice(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        rows = np.repeat(np.arange(self.shape[0]), self.row_nnz())
        out[rows, self.indices] = self.data
        return out

    @staticmethod
    def fromdense(a: np.ndarray) -> "CSR":
        return CSR.from_coo(COO.fromdense(a))

    @staticmethod
    def from_coo(coo: COO) -> "CSR":
        coo = coo.sort_rowmajor()
        indptr = np.zeros(coo.shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, coo.row + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSR(indptr, coo.col, coo.val, coo.shape)

    def to_coo(self) -> COO:
        rows = np.repeat(
            np.arange(self.shape[0], dtype=np.int32), self.row_nnz()
        )
        return COO(rows, self.indices.copy(), self.data.copy(), self.shape)

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix((self.data, self.indices, self.indptr), shape=self.shape)

    @staticmethod
    def from_scipy(m) -> "CSR":
        m = m.tocsr()
        m.sort_indices()
        return CSR(m.indptr.astype(np.int64), m.indices.astype(np.int32), m.data, m.shape)


@dataclasses.dataclass(repr=False)
class CSC(SparseFormat):
    """Compressed Sparse Column (paper Sec. 2.1)."""

    indptr: np.ndarray
    indices: np.ndarray  # row indices
    data: np.ndarray
    shape: Tuple[int, int]

    def __post_init__(self):
        self.indptr = _as1d(self.indptr, np.int64)
        self.indices = _as1d(self.indices, np.int32)
        self.data = _as1d(self.data)
        if self.indptr.shape[0] != self.shape[1] + 1:
            raise ValueError("indptr length must be cols+1")

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def col_slice(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[j]), int(self.indptr[j + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        cols = np.repeat(np.arange(self.shape[1]), np.diff(self.indptr))
        out[self.indices, cols] = self.data
        return out

    @staticmethod
    def fromdense(a: np.ndarray) -> "CSC":
        coo = COO.fromdense(a)
        order = np.lexsort((coo.row, coo.col))
        r, c, v = coo.row[order], coo.col[order], coo.val[order]
        indptr = np.zeros(a.shape[1] + 1, dtype=np.int64)
        np.add.at(indptr, c + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSC(indptr, r, v, a.shape)

    def to_coo(self) -> COO:
        cols = np.repeat(
            np.arange(self.shape[1], dtype=np.int32), np.diff(self.indptr)
        )
        return COO(self.indices.copy(), cols, self.data.copy(), self.shape)


@dataclasses.dataclass(repr=False)
class CSV(SparseFormat):
    """The paper's Compressed Sparse Vector format (Sec. 3, Fig. 2).

    Nonzeros are stored in vector-major order: rows are partitioned into
    groups of ``num_pe`` consecutive rows; nonzeros of a group are sorted by
    ``(col, row)``. Attributes per nonzero: ``val``, ``row_ind``,
    ``col_ind`` (the paper's VAL / ROW_INDEX / COL_INDEX).

    A *CSV vector* is the run of consecutive entries inside one row-group
    sharing the same column index — exactly the set of A-nonzeros that share
    one buffered row of B in the Sec. 4.1 buffering scheme.
    """

    val: np.ndarray
    row_ind: np.ndarray
    col_ind: np.ndarray
    shape: Tuple[int, int]
    num_pe: int

    def __post_init__(self):
        self.val = _as1d(self.val)
        self.row_ind = _as1d(self.row_ind, np.int32)
        self.col_ind = _as1d(self.col_ind, np.int32)
        if self.num_pe < 1:
            raise ValueError("num_pe must be >= 1")

    @property
    def nnz(self) -> int:
        return int(self.val.shape[0])

    def group_of(self) -> np.ndarray:
        """Row-group id of every stored nonzero."""
        return self.row_ind // self.num_pe

    def vector_id(self) -> np.ndarray:
        """Integer id of the CSV vector each nonzero belongs to.

        A vector is identified by ``(row_group, col)``. Ids are assigned in
        storage order; by construction entries of the same vector are
        consecutive.
        """
        if self.nnz == 0:
            return np.zeros(0, dtype=np.int64)
        g = self.group_of().astype(np.int64)
        c = self.col_ind.astype(np.int64)
        change = np.empty(self.nnz, dtype=bool)
        change[0] = True
        change[1:] = (g[1:] != g[:-1]) | (c[1:] != c[:-1])
        return np.cumsum(change) - 1

    def num_vectors(self) -> int:
        vid = self.vector_id()
        return int(vid[-1]) + 1 if vid.size else 0

    def validate(self) -> None:
        """Assert the storage order is exactly the paper's vector-major order."""
        g = self.group_of().astype(np.int64)
        key = (g, self.col_ind.astype(np.int64), self.row_ind.astype(np.int64))
        order = np.lexsort(key[::-1])  # lexsort: last key is primary
        if not np.array_equal(order, np.arange(self.nnz)):
            raise ValueError("CSV entries are not in vector-major order")

    def to_coo(self) -> COO:
        return COO(self.row_ind.copy(), self.col_ind.copy(), self.val.copy(), self.shape)

    def todense(self) -> np.ndarray:
        return self.to_coo().todense()

    @staticmethod
    def from_coo(coo: COO, num_pe: int) -> "CSV":
        """Host pre-processing (paper Sec. 4.3): convert to vector-major order."""
        g = (coo.row // num_pe).astype(np.int64)
        order = np.lexsort(
            (coo.row.astype(np.int64), coo.col.astype(np.int64), g)
        )
        return CSV(
            coo.val[order],
            coo.row[order],
            coo.col[order],
            coo.shape,
            num_pe,
        )

    @staticmethod
    def fromdense(a: np.ndarray, num_pe: int) -> "CSV":
        return CSV.from_coo(COO.fromdense(a), num_pe)


@dataclasses.dataclass(repr=False)
class BCSR(SparseFormat):
    """Block CSR: nonzero (bm, bn) tiles in block-row-major order.

    Used for the second input matrix of the block-Gustavson kernel (the
    analogue of the paper storing B in CSR, Sec. 4.2.2).
    """

    indptr: np.ndarray  # [n_brows + 1]
    indices: np.ndarray  # [nnzb] block-column ids
    blocks: np.ndarray  # [nnzb, bm, bn]
    shape: Tuple[int, int]

    def __post_init__(self):
        self.indptr = _as1d(self.indptr, np.int64)
        self.indices = _as1d(self.indices, np.int32)
        self.blocks = np.ascontiguousarray(self.blocks)
        if self.blocks.ndim != 3:
            raise ValueError("blocks must be [nnzb, bm, bn]")

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (int(self.blocks.shape[1]), int(self.blocks.shape[2]))

    @property
    def nnzb(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def nnz(self) -> int:
        # Count of explicitly stored entries (a dense tile's worth each).
        return int(np.count_nonzero(self.blocks))

    @property
    def grid(self) -> Tuple[int, int]:
        bm, bn = self.block_shape
        return (self.shape[0] // bm, self.shape[1] // bn)

    def todense(self) -> np.ndarray:
        bm, bn = self.block_shape
        out = np.zeros(self.shape, dtype=self.blocks.dtype)
        n_brows = self.indptr.shape[0] - 1
        for bi in range(n_brows):
            lo, hi = int(self.indptr[bi]), int(self.indptr[bi + 1])
            for k in range(lo, hi):
                bj = int(self.indices[k])
                out[bi * bm : (bi + 1) * bm, bj * bn : (bj + 1) * bn] = self.blocks[k]
        return out

    @staticmethod
    def fromdense(a: np.ndarray, block_shape: Tuple[int, int]) -> "BCSR":
        bm, bn = block_shape
        m, n = a.shape
        if m % bm or n % bn:
            raise ValueError(f"shape {a.shape} not divisible by block {block_shape}")
        gm, gn = m // bm, n // bn
        tiles = a.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)
        mask = np.any(tiles != 0, axis=(2, 3))
        indptr = np.zeros(gm + 1, dtype=np.int64)
        indptr[1:] = np.cumsum(mask.sum(axis=1))
        brows, bcols = np.nonzero(mask)
        return BCSR(indptr, bcols.astype(np.int32), tiles[brows, bcols], (m, n))

    def to_coo(self) -> "COO":
        """Element-level COO of the stored entries (never densifies)."""
        bm, bn = self.block_shape
        s, r, c = np.nonzero(self.blocks)
        brows = np.repeat(
            np.arange(self.indptr.shape[0] - 1, dtype=np.int64),
            np.diff(self.indptr),
        )
        row = brows[s] * bm + r
        col = self.indices[s].astype(np.int64) * bn + c
        return COO(
            row.astype(np.int32), col.astype(np.int32), self.blocks[s, r, c],
            self.shape,
        )


@dataclasses.dataclass(repr=False)
class BCSV(SparseFormat):
    """Block CSV — the TPU-native adaptation of the paper's CSV format.

    Nonzero (bm, bk) tiles stored vector-major: block-rows are partitioned
    into groups of ``group`` block-rows; within a group tiles are sorted by
    ``(bcol, brow)``. The packed ``blocks`` array is therefore read strictly
    sequentially by the Pallas grid, and consecutive tiles sharing ``bcol``
    reuse the same B block-row in VMEM (paper Sec. 4.1 buffering scheme at
    tile granularity). ``group`` plays the role of NUM_PE.
    """

    blocks: np.ndarray  # [nnzb, bm, bk]
    brow: np.ndarray  # [nnzb]
    bcol: np.ndarray  # [nnzb]
    group_ptr: np.ndarray  # [n_groups + 1] offsets into the nnzb axis
    shape: Tuple[int, int]
    group: int

    def __post_init__(self):
        self.blocks = np.ascontiguousarray(self.blocks)
        self.brow = _as1d(self.brow, np.int32)
        self.bcol = _as1d(self.bcol, np.int32)
        self.group_ptr = _as1d(self.group_ptr, np.int64)
        if self.blocks.ndim != 3:
            raise ValueError("blocks must be [nnzb, bm, bk]")

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (int(self.blocks.shape[1]), int(self.blocks.shape[2]))

    @property
    def nnzb(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def nnz(self) -> int:
        return int(np.count_nonzero(self.blocks))

    @property
    def grid(self) -> Tuple[int, int]:
        bm, bk = self.block_shape
        return (self.shape[0] // bm, self.shape[1] // bk)

    @property
    def n_groups(self) -> int:
        return int(self.group_ptr.shape[0]) - 1

    def group_of(self) -> np.ndarray:
        return self.brow // self.group

    def validate(self) -> None:
        g = self.group_of().astype(np.int64)
        key = (g, self.bcol.astype(np.int64), self.brow.astype(np.int64))
        order = np.lexsort(key[::-1])
        if not np.array_equal(order, np.arange(self.nnzb)):
            raise ValueError("BCSV blocks are not in vector-major order")
        # group_ptr consistency
        gm = self.grid[0]
        n_groups = -(-gm // self.group)
        if self.n_groups != n_groups:
            raise ValueError("group_ptr has wrong number of groups")
        for gi in range(n_groups):
            lo, hi = int(self.group_ptr[gi]), int(self.group_ptr[gi + 1])
            if not np.all(g[lo:hi] == gi):
                raise ValueError(f"group_ptr[{gi}] range holds foreign blocks")

    def todense(self) -> np.ndarray:
        bm, bk = self.block_shape
        out = np.zeros(self.shape, dtype=self.blocks.dtype)
        for i in range(self.nnzb):
            r, c = int(self.brow[i]), int(self.bcol[i])
            out[r * bm : (r + 1) * bm, c * bk : (c + 1) * bk] = self.blocks[i]
        return out

    def to_coo(self) -> "COO":
        """Element-level COO of the stored entries (never densifies)."""
        bm, bk = self.block_shape
        s, r, c = np.nonzero(self.blocks)
        row = self.brow[s].astype(np.int64) * bm + r
        col = self.bcol[s].astype(np.int64) * bk + c
        return COO(
            row.astype(np.int32), col.astype(np.int32), self.blocks[s, r, c],
            self.shape,
        )

    @staticmethod
    def fromdense(
        a: np.ndarray, block_shape: Tuple[int, int], group: int
    ) -> "BCSV":
        bm, bk = block_shape
        m, k = a.shape
        if m % bm or k % bk:
            raise ValueError(f"shape {a.shape} not divisible by block {block_shape}")
        gm, gk = m // bm, k // bk
        tiles = a.reshape(gm, bm, gk, bk).transpose(0, 2, 1, 3)
        mask = np.any(tiles != 0, axis=(2, 3))
        brows, bcols = np.nonzero(mask)
        g = brows // group
        order = np.lexsort((brows, bcols, g))
        brows, bcols = brows[order], bcols[order]
        blocks = tiles[brows, bcols]
        n_groups = -(-gm // group)
        group_ptr = np.zeros(n_groups + 1, dtype=np.int64)
        np.add.at(group_ptr, (brows // group) + 1, 1)
        np.cumsum(group_ptr, out=group_ptr)
        return BCSV(
            blocks,
            brows.astype(np.int32),
            bcols.astype(np.int32),
            group_ptr,
            (m, k),
            group,
        )
