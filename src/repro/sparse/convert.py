"""Format conversions (the paper's host pre-processing utilities, Sec. 4.3).

The paper: "the utility functions read in the raw matrix files in an
existing sparse matrix format then convert and store the matrices in the
CSV format. The pre-processing step only needs to be performed once."
"""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

from repro.sparse.formats import BCSR, BCSV, COO, CSC, CSR, CSV, SparseFormat

AnySparse = Union[COO, CSR, CSC, CSV, BCSR, BCSV]


def to_coo(a: Union[np.ndarray, AnySparse]) -> COO:
    if isinstance(a, np.ndarray):
        return COO.fromdense(a)
    if isinstance(a, COO):
        return a
    if isinstance(a, (CSR, CSC, CSV, BCSR, BCSV)):
        return a.to_coo()
    raise TypeError(f"cannot convert {type(a)} to COO")


def to_csr(a: Union[np.ndarray, AnySparse]) -> CSR:
    if isinstance(a, CSR):
        return a
    return CSR.from_coo(to_coo(a).sum_duplicates())


def to_csc(a: Union[np.ndarray, AnySparse]) -> CSC:
    if isinstance(a, CSC):
        return a
    return _coo_to_csc(to_coo(a).sum_duplicates())


def _coo_to_csc(coo: COO) -> CSC:
    order = np.lexsort((coo.row, coo.col))
    r, c, v = coo.row[order], coo.col[order], coo.val[order]
    indptr = np.zeros(coo.shape[1] + 1, dtype=np.int64)
    np.add.at(indptr, c.astype(np.int64) + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSC(indptr, r, v, coo.shape)


def to_csv(a: Union[np.ndarray, AnySparse], num_pe: int) -> CSV:
    """Convert to the paper's CSV format with ``num_pe`` rows per group."""
    if isinstance(a, CSV) and a.num_pe == num_pe:
        return a
    return CSV.from_coo(to_coo(a).sum_duplicates(), num_pe)


def _block_coords(
    coo: COO, block_shape: Tuple[int, int]
) -> Tuple[np.ndarray, Tuple[int, int]]:
    """Per-nonzero block keys for a *deduplicated* COO, plus the padded grid.

    Returns ``(bid, (gm, gk))`` where ``bid = brow * gk + bcol`` is a single
    sortable block key (callers recover ``brow``/``bcol`` of the *unique*
    blocks via ``divmod(bid, gk)``). The grid covers ceil-divided (padded)
    dims, so no dense padding is ever materialized.
    """
    bm, bk = block_shape
    m, k = coo.shape
    gm, gk = -(-m // bm), -(-k // bk)
    brow = (coo.row // bm).astype(np.int64)
    bcol = (coo.col // bk).astype(np.int64)
    return brow * gk + bcol, (gm, gk)


def bcsr_from_coo(
    coo: COO, block_shape: Tuple[int, int]
) -> Tuple[BCSR, np.ndarray]:
    """Sparse-native COO -> BCSR: O(nnz log nnz), never densifies.

    ``coo`` must have unique coordinates (``sum_duplicates`` first).
    Returns the BCSR plus ``scatter``: flat indices into ``blocks`` such
    that ``blocks.reshape(-1)[scatter] = coo.val`` re-materializes the
    packed value array from a fresh value vector in ``coo`` order — the
    numeric-phase rebind used by SpGEMMPlan.execute.
    """
    bm, bk = block_shape
    bid, (gm, gk) = _block_coords(coo, block_shape)
    ub = np.unique(bid)  # ascending == (brow, bcol) block-row-major
    slot = np.searchsorted(ub, bid)
    scatter = slot * (bm * bk) + (coo.row % bm).astype(np.int64) * bk + (
        coo.col % bk
    ).astype(np.int64)
    blocks = np.zeros((ub.shape[0], bm, bk), coo.val.dtype)
    blocks.reshape(-1)[scatter] = coo.val
    ubr, ubc = ub // gk, ub % gk
    indptr = np.zeros(gm + 1, dtype=np.int64)
    np.add.at(indptr, ubr + 1, 1)
    np.cumsum(indptr, out=indptr)
    return (
        BCSR(indptr, ubc.astype(np.int32), blocks, (gm * bm, gk * bk)),
        scatter,
    )


def bcsv_from_coo(
    coo: COO, block_shape: Tuple[int, int], group: int
) -> Tuple[BCSV, np.ndarray]:
    """Sparse-native COO -> BCSV (vector-major block order), never densifies.

    Same contract as :func:`bcsr_from_coo`: unique coordinates in, format
    plus flat ``scatter`` indices out.
    """
    bm, bk = block_shape
    bid, (gm, gk) = _block_coords(coo, block_shape)
    ub = np.unique(bid)
    ubr, ubc = ub // gk, ub % gk
    # Vector-major order: (block-row group, bcol, brow).
    order = np.lexsort((ubr, ubc, ubr // group))
    rank = np.empty(ub.shape[0], np.int64)
    rank[order] = np.arange(ub.shape[0])
    slot = rank[np.searchsorted(ub, bid)]
    scatter = slot * (bm * bk) + (coo.row % bm).astype(np.int64) * bk + (
        coo.col % bk
    ).astype(np.int64)
    blocks = np.zeros((ub.shape[0], bm, bk), coo.val.dtype)
    blocks.reshape(-1)[scatter] = coo.val
    sbr, sbc = ubr[order], ubc[order]
    n_groups = -(-gm // group)
    group_ptr = np.zeros(n_groups + 1, dtype=np.int64)
    np.add.at(group_ptr, (sbr // group) + 1, 1)
    np.cumsum(group_ptr, out=group_ptr)
    return (
        BCSV(
            blocks,
            sbr.astype(np.int32),
            sbc.astype(np.int32),
            group_ptr,
            (gm * bm, gk * bk),
            group,
        ),
        scatter,
    )


def to_bcsr(
    a: Union[np.ndarray, AnySparse], block_shape: Tuple[int, int]
) -> BCSR:
    if isinstance(a, BCSR) and a.block_shape == tuple(block_shape):
        return a
    bcsr, _ = bcsr_from_coo(to_coo(a).sum_duplicates(), block_shape)
    return bcsr


def to_bcsv(
    a: Union[np.ndarray, AnySparse], block_shape: Tuple[int, int], group: int
) -> BCSV:
    if (
        isinstance(a, BCSV)
        and a.block_shape == tuple(block_shape)
        and a.group == group
    ):
        return a
    bcsv, _ = bcsv_from_coo(to_coo(a).sum_duplicates(), block_shape, group)
    return bcsv


def pad_to_blocks(a: np.ndarray, block_shape: Tuple[int, int]) -> np.ndarray:
    """Zero-pad a dense matrix so both dims divide the block shape."""
    bm, bn = block_shape
    m, n = a.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm == 0 and pn == 0:
        return a
    return np.pad(a, ((0, pm), (0, pn)))


def csr_to_csv(a: CSR, num_pe: int) -> CSV:
    """Direct CSR -> CSV conversion (the paper's primary preprocessing path)."""
    return CSV.from_coo(a.to_coo(), num_pe)


def csv_to_csr(a: CSV) -> CSR:
    return CSR.from_coo(a.to_coo())
