"""Format conversions (the paper's host pre-processing utilities, Sec. 4.3).

The paper: "the utility functions read in the raw matrix files in an
existing sparse matrix format then convert and store the matrices in the
CSV format. The pre-processing step only needs to be performed once."
"""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

from repro.sparse.formats import BCSR, BCSV, COO, CSC, CSR, CSV, SparseFormat

AnySparse = Union[COO, CSR, CSC, CSV, BCSR, BCSV]


def to_coo(a: Union[np.ndarray, AnySparse]) -> COO:
    if isinstance(a, np.ndarray):
        return COO.fromdense(a)
    if isinstance(a, COO):
        return a
    if isinstance(a, (CSR, CSC, CSV)):
        return a.to_coo()
    if isinstance(a, (BCSR, BCSV)):
        return COO.fromdense(a.todense())
    raise TypeError(f"cannot convert {type(a)} to COO")


def to_csr(a: Union[np.ndarray, AnySparse]) -> CSR:
    if isinstance(a, CSR):
        return a
    return CSR.from_coo(to_coo(a).sum_duplicates())


def to_csc(a: Union[np.ndarray, AnySparse]) -> CSC:
    if isinstance(a, CSC):
        return a
    return _coo_to_csc(to_coo(a).sum_duplicates())


def _coo_to_csc(coo: COO) -> CSC:
    order = np.lexsort((coo.row, coo.col))
    r, c, v = coo.row[order], coo.col[order], coo.val[order]
    indptr = np.zeros(coo.shape[1] + 1, dtype=np.int64)
    np.add.at(indptr, c.astype(np.int64) + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSC(indptr, r, v, coo.shape)


def to_csv(a: Union[np.ndarray, AnySparse], num_pe: int) -> CSV:
    """Convert to the paper's CSV format with ``num_pe`` rows per group."""
    if isinstance(a, CSV) and a.num_pe == num_pe:
        return a
    return CSV.from_coo(to_coo(a).sum_duplicates(), num_pe)


def to_bcsr(
    a: Union[np.ndarray, AnySparse], block_shape: Tuple[int, int]
) -> BCSR:
    if isinstance(a, BCSR) and a.block_shape == tuple(block_shape):
        return a
    dense = a if isinstance(a, np.ndarray) else to_coo(a).sum_duplicates().todense()
    dense = pad_to_blocks(dense, block_shape)
    return BCSR.fromdense(dense, block_shape)


def to_bcsv(
    a: Union[np.ndarray, AnySparse], block_shape: Tuple[int, int], group: int
) -> BCSV:
    if (
        isinstance(a, BCSV)
        and a.block_shape == tuple(block_shape)
        and a.group == group
    ):
        return a
    dense = a if isinstance(a, np.ndarray) else to_coo(a).sum_duplicates().todense()
    dense = pad_to_blocks(dense, block_shape)
    return BCSV.fromdense(dense, block_shape, group)


def pad_to_blocks(a: np.ndarray, block_shape: Tuple[int, int]) -> np.ndarray:
    """Zero-pad a dense matrix so both dims divide the block shape."""
    bm, bn = block_shape
    m, n = a.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm == 0 and pn == 0:
        return a
    return np.pad(a, ((0, pm), (0, pn)))


def csr_to_csv(a: CSR, num_pe: int) -> CSV:
    """Direct CSR -> CSV conversion (the paper's primary preprocessing path)."""
    return CSV.from_coo(a.to_coo(), num_pe)


def csv_to_csr(a: CSV) -> CSR:
    return CSR.from_coo(a.to_coo())
