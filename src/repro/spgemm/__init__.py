"""Plan/execute SpGEMM API (the paper's "pre-process once" claim as code).

Typical use::

    from repro.spgemm import spgemm_plan

    plan = spgemm_plan(a, b, tile=64, group=4, backend="auto")
    c0 = plan.execute()                     # staged values
    c1 = plan.execute(a_vals2, b_vals2)     # fresh values, zero symbolic work
    cs = plan.execute_batch(a_batch, b_batch)  # [batch, nnz] values, one
                                               # vmapped device call
    print(plan.report.block_omar, plan.report.cache_hits)

The numeric phase is device-resident (``repro.spgemm.executor``): value
rebind, the scheduled kernel, and output assembly run under one ``jax.jit``
against the symbolic phase's precomputed CSR structure. Plans are cached
process-wide on ``(pattern hash, tile, group, backend)`` with optional
byte-budget eviction; ``repro.kernels.ops.spgemm`` is a thin compatibility
shim over this package.
"""
from repro.spgemm.cache import (
    CacheStats,
    PlanCache,
    default_cache,
    pattern_digest,
)
from repro.spgemm.executor import SpGEMMExecutor
from repro.spgemm.plan import (
    PlanReport,
    SpGEMMPlan,
    resolve_backend,
    schedule_build_count,
    spgemm_plan,
)

__all__ = [
    "CacheStats",
    "PlanCache",
    "PlanReport",
    "SpGEMMExecutor",
    "SpGEMMPlan",
    "default_cache",
    "pattern_digest",
    "resolve_backend",
    "schedule_build_count",
    "spgemm_plan",
]
