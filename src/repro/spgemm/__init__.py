"""Plan/execute SpGEMM API (the paper's "pre-process once" claim as code).

Typical use::

    from repro.spgemm import spgemm_plan

    plan = spgemm_plan(a, b, tile=64, group=4, backend="auto")
    c0 = plan.execute()                     # staged values
    c1 = plan.execute(a_vals2, b_vals2)     # fresh values, zero symbolic work
    print(plan.report.block_omar, plan.report.cache_hits)

Plans are cached process-wide on ``(pattern hash, tile, group, backend)``;
``repro.kernels.ops.spgemm`` is a thin compatibility shim over this package.
"""
from repro.spgemm.cache import (
    CacheStats,
    PlanCache,
    default_cache,
    pattern_digest,
)
from repro.spgemm.plan import (
    PlanReport,
    SpGEMMPlan,
    resolve_backend,
    schedule_build_count,
    spgemm_plan,
)

__all__ = [
    "CacheStats",
    "PlanCache",
    "PlanReport",
    "SpGEMMPlan",
    "default_cache",
    "pattern_digest",
    "resolve_backend",
    "schedule_build_count",
    "spgemm_plan",
]
