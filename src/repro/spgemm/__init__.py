"""Plan/execute SpGEMM API (the paper's "pre-process once" claim as code).

Typical use::

    from repro.spgemm import spgemm_plan
    from repro.launch.mesh import make_shard_mesh

    plan = spgemm_plan(a, b, tile=64, group=4, backend="auto")
    c0 = plan.execute()                     # staged values
    c1 = plan.execute(a_vals2, b_vals2)     # fresh values, zero symbolic work
    cs = plan.execute_batch(a_batch, b_batch)  # [batch, nnz] values, one
                                               # vmapped device call

    with plan.pipeline(depth=2) as pipe:    # async serving (submit/collect)
        for c in pipe.stream(values.value_iter(steps=1000)):
            consume(c)

    sharded = spgemm_plan(a, b, tile=64, group=4,
                          mesh=make_shard_mesh(4))  # ShardedSpGEMMPlan
    c2 = sharded.execute(a_vals2, b_vals2)  # same semantics, 4 devices

The numeric phase is device-resident (``repro.spgemm.executor``): value
rebind, the scheduled kernel, and output assembly run against the symbolic
phase's precomputed CSR structure — fused under one ``jax.jit`` for
synchronous executes, and *stage-split* into per-stage jits (H2D +
rebind -> kernel -> assembly -> collect) behind one interface for the
async path.

**Kernel dispatch** is decided once per plan by its resolved backend and
honored on *every* numeric path — single execute, ``execute_batch``, the
pipeline's stage jits, and the per-shard programs inside ``shard_map``::

    backend            x  path          -> scheduled kernel
    -------------------------------------------------------------------
    pallas             execute/pipeline    spgemm_scheduled_impl
                                           (scalar-prefetch Pallas grid)
    pallas             execute_batch /     spgemm_scheduled_batch_impl
                       batched pipeline    (batch-folded grid (bsz, t))
    pallas             sharded (any)       same two, one Pallas program
                                           per shard inside shard_map
    pallas_interpret   all of the above    identical grids, interpret=True
    jnp                all paths           ref.spgemm_scheduled_ref
                                           (segment scatter-add reference)
    auto                                   pallas on TPU, jnp elsewhere

The batch fold iterates the triple dimension innermost, so every element
runs its full schedule in single-grid order: batched, pipelined, and
sharded results are **bitwise-equal** to looped single executes on every
backend (tests/test_pallas_dispatch.py pins this, including a guard that
pallas plans never silently fall back to the jnp reference).

**Output formats & chaining**: a plan's output format is fixed at build
time by ``spgemm_plan(..., output=)``:

* ``output="block"`` (default, bitwise-unchanged): C is the structural
  *block* CSR — every element of every structurally nonzero ``bm x bn``
  block is stored, padding zeros included. Cheapest to assemble and the
  right shape for block-granular consumers.
* ``output="compact"``: C is the element-exact CSR of the structural
  product pattern — per-row counts, prefix-summed ``indptr``, and a
  compacted gather map (:func:`repro.core.schedule.build_compact_map`, a
  strict subset of the block assembly's gather) drop the block-padding
  zeros on **every** dispatch path (execute / batch / pipeline /
  sharded). Same kernels, same bits at every stored coordinate; only
  the output gather changes. Compact plans get their own cache keys
  (``+ ("compact",)``) and persist the compact map beside the block
  map (``casm.*`` arrays), so block artifacts stay byte-identical.

Because C's pattern is value-independent, one plan's structural output
(:meth:`SpGEMMPlan.output_pattern`) can seed the *next* plan without any
host round trip or COO conversion — the graph-workload chaining layer::

    p1 = spgemm_plan(a, b, tile=16, group=2, output="compact")
    chain = p1.then(c)                 # SpGEMMChain; or chain_plans([...])
    out = chain.execute()              # A @ B @ C, intermediates stay
                                       # device-resident (packed values
                                       # feed the next stage's fused jit)
    p2 = plan_from_structural_pattern( # the explicit form: skip COO
        p1.output_pattern(), c)        # conversion + canonicalizing sort

``execute_chain`` results are bitwise-equal to independent per-stage
executes with host round trips between them; chained plans carry their
own ``"chain"``-digest cache keys and persist/rehydrate like any other
plan (``CacheStats.chain_lookups`` counts the composition path). See
``examples/spgemm_chain.py`` (A²-based triangle counting) and
``benchmarks/bench_chain.py``.

**Batch chunking**: ``execute_batch`` fuses many value sets into one
device call only while a set's working bytes stay under a per-backend
budget, and sizes chunks to a per-backend cache target
(``executor.batch_chunk``). Both knobs resolve with precedence
``REPRO_SPGEMM_CHUNK_BYTES`` env var > ``chunk_bytes=`` constructor
argument (the tier a plan's applied ``TunedConfig`` feeds) > the
measured per-backend ``executor._CHUNK_POLICY`` row (calibrated with
``benchmarks.bench_chunk_knee`` /
:func:`repro.core.tuning.measure_chunk_knee`; re-run on new hosts).

**Autotuning** (``repro.spgemm.autotune``): per-pattern config search
over ``(tile, group)`` x ``chunk_bytes`` x pipeline depth, run once and
amortized like the symbolic phase itself. Stage 1 ranks the candidate
grid with the roofline model over each schedule's exact FLOP/traffic
counts (:func:`repro.core.perfmodel.spgemm_schedule_traffic` +
:func:`repro.core.perfmodel.roofline_seconds`) and keeps the top K plus
the requested default; stage 2 measures the survivors with short
interleaved min-of-N ``execute_batch`` probes on synthetic values (the
``measure_chunk_knee`` machinery), then probes pipeline depth on the
winner only. The result — a
:class:`~repro.spgemm.autotune.TunedConfig` with measured values/s for
winner and default, the model's rank of the winner, and the
model-vs-measured ranking agreement — is applied to the plan and
persisted beside the plan artifacts (a versioned ``PlanStore`` sidecar
record *and* inside ``persist_artifacts`` meta), so a warm-restarted
process rehydrates schedule **and** tuned config with **zero** probe
executions (``repro.spgemm.autotune.probe_run_count`` stays flat).
Numerics never change: chunk/depth are bitwise-invariant, and a tuned
(tile, group) plan is bitwise-equal to an untuned plan built directly
at that tile/group. Cookbook::

    from repro.spgemm import spgemm_plan
    from repro.spgemm.autotune import probe_run_count

    plan = spgemm_plan(a, b, tile=64, group=4, autotune=True)
    cfg = plan.tuned_config           # TunedConfig(tile, group,
                                      #   chunk_bytes, pipeline_depth, ...)
    cfg.speedup                       # measured winner/default ratio
    plan.report.config_source         # "tuned" | "persisted" |
                                      # "env-override" | "default"
    # warm restart, same REPRO_SPGEMM_PLAN_DIR: zero probes
    plan = spgemm_plan(a, b, tile=64, group=4, autotune=True)
    assert plan.report.config_source == "persisted"
    assert probe_run_count() == 0

The full exec-config precedence chain, highest first:

1. ``REPRO_SPGEMM_CHUNK_BYTES`` env var — the operator override, always
   wins (``report.config_source == "env-override"``);
2. explicit ``chunk_bytes=`` executor constructor argument / an applied
   ``TunedConfig`` (``plan.apply_tuned_config``, what ``autotune=True``
   and persisted-artifact rehydration do);
3. the measured per-backend ``executor._CHUNK_POLICY`` table row
   (``report.config_source == "default"``).

**Async serving** (``repro.spgemm.pipeline``): ``plan.pipeline(depth)``
returns an :class:`~repro.spgemm.pipeline.SpGEMMPipeline` —
``submit(a_vals, b_vals)`` dispatches a step and returns a ticket
immediately; ``collect(ticket)`` (or ``ticket.result()``) is the only
blocking call. With ``depth`` steps in flight, step s+1's value staging
(H2D + rebind, its own device program) overlaps step s's kernel — the
paper's double-buffered operand fetch at ``depth=2``, each in-flight step
owning its own staged packed A/B buffers on device (per shard on sharded
plans). ``plan.execute_async`` is the one-shot form,
``plan.execute_stream(value_iter, depth=)`` the ordered streaming form
(feed it :meth:`repro.data.pipeline.SpGEMMValueStream.value_iter`).
Pipelined results are **bitwise-equal** to sequential ``execute`` calls on
element, block, batched, and sharded plans. While tickets are in flight
the plan refuses buffer teardown — ``release_values``/``release`` and
explicit cache eviction raise, and LRU eviction skips the plan — so
staged device buffers can never be torn down under a running step.

**Sharded plans** (the mesh-aware path): passing ``mesh=`` partitions the
symbolic panel schedule across the devices of one mesh axis —

* *partitioning policy*: shard boundaries are block-row **group**
  boundaries chosen to balance **triple count** (the numeric work unit,
  not panel count) via :func:`repro.core.schedule.partition_spgemm_schedule`;
  every shard is a contiguous slice of the parent schedule, so shards may
  be ragged or empty and C stays a concatenation of contiguous row ranges;
* *data placement*: packed A blocks / A values are **row-sharded** (each
  shard's contiguous slot/value slice lives on its own device), packed B
  blocks / B values are **replicated** — the paper's shared B-buffer
  scheme lifted to the mesh — and C's packed values come back row-sharded,
  assembled on host with one concatenation along the precomputed indptr
  boundaries;
* *execution*: one ``jax.jit(shard_map(...))`` call per execute, each
  shard running its own padded triple schedule against its own
  :class:`~repro.core.schedule.AssemblyMap` slice with the backend's
  kernel (a per-shard Pallas program on pallas backends — see the
  dispatch matrix above — the scatter-add reference on jnp); the async
  path splits the same computation into per-stage ``shard_map`` programs.

Plans are cached in a **two-tier** cache keyed on ``(pattern hash, tile,
group, backend, mesh key)`` — the mesh key pins the shard axis, shard
count, and device ids, and is ``None`` on the unchanged single-device
path:

* the **memory tier** is a process-wide LRU of live plan objects (count +
  byte budgets, ``PlanCache.stats()`` observability). Serving callers can
  attach a ``pattern_token`` (``spgemm_plan(..., pattern_token="layer3")``)
  — a caller-chosen fast key that resolves warm lookups *without*
  ``to_coo`` canonicalization or the pattern digest (most of the warm
  path's host cost); the token is validated against the digest whenever
  both are present and echoed in ``report.pattern_token``;
* the **disk tier** (opt-in: ``PlanCache(disk_dir=...)``, or point
  ``REPRO_SPGEMM_PLAN_DIR`` at a directory for the process-default cache)
  persists the value-independent symbolic artifacts — triple schedule,
  scatter indices, assembly map, shard bounds — through
  ``repro.spgemm.persist.PlanStore``, so a **warm-restarted** process
  rehydrates its plans (``report.schedule_builds == 0``,
  ``report.load_hits >= 1``) with results bitwise-equal to a cold build.
  Files carry a format-version header, the full cache key, and a payload
  digest; anything stale or corrupt degrades to a silent fresh build.

**Multi-tenant serving gateway** (``repro.spgemm.gateway``): the front
end above per-plan pipelines for many tenants hammering many patterns
concurrently. :class:`~repro.spgemm.gateway.SpGEMMGateway` resolves each
registered pattern through the cache (``pattern_token`` fast key),
micro-batches same-pattern requests arriving within a bounded window
into single ``execute_batch``-semantics pipeline submissions (results
stay bitwise-equal to per-request ``plan.execute``), schedules fairly
across patterns by deficit round-robin over pending **value bytes** on a
bounded pool of live pipelines (pool eviction never tears down a
pipeline with in-flight tickets), and sheds overload as explicit typed
outcomes (:class:`~repro.spgemm.gateway.Outcome`: queue-full, in-flight
byte budget, plan-cache byte pressure, closed) instead of raising from
the executor. Per-pattern queue depth, batch-fill, p50/p99 latency,
throughput, and shed counts are recorded in a
:class:`~repro.runtime.heartbeat.MetricsRegistry` and snapshotted by
``gateway.stats()``::

    gw = SpGEMMGateway(max_pipelines=4, depth=2, max_batch=8,
                       max_inflight_bytes=64 << 20)
    gw.register("tenant0/layer3", a, b, tile=16, group=2)
    ticket = gw.submit("tenant0/layer3", a_vals, b_vals)
    res = ticket.wait()        # typed GatewayResult (never raises on shed)
    gw.close()                 # drains admitted work by default

**Validation & static analysis** (``repro.analysis``): every invariant
the numeric phase relies on — schedule well-formedness, write-only
dummy-pad-panel discipline, assembly coverage (each structural C nnz
gathered exactly once), write-write race freedom of the batch-folded and
stacked-shard Pallas grids, shard-partition exactness — can be checked
statically, on the host, without executing a single kernel::

    from repro.analysis import verify_plan

    report = verify_plan(plan)        # VerifyReport; report.ok / findings
    report.raise_if_failed()          # PlanVerificationError with detail

    plan = spgemm_plan(a, b, tile=16, group=2, validate="deep")

``validate="deep"`` runs the verifier on whatever this call returns —
fresh build, memory hit, or disk rehydrate. Rehydrates are verified
*inside* the loader, so a corrupted-but-digest-valid artifact (the one
corruption class the store's payload digest cannot catch: a consistent
rewrite that re-signs the digest) counts as a ``load_failure`` and falls
back to a clean symbolic rebuild instead of executing. The same checks
back the kernel lint (``repro.analysis.kernel_lint`` — the proof
obligation behind the batch grid's ``("parallel", "arbitrary")``
dimension semantics), the serving stack's lock-order lint
(``repro.analysis.locks``), and the CI gate
``python -m repro.analysis.check --paper-matrices --shards 8``.

``repro.kernels.ops.spgemm`` is a thin compatibility shim over this
package.
"""
from repro.spgemm.autotune import TunedConfig, autotune_plan, probe_run_count
from repro.spgemm.cache import (
    CacheStats,
    PlanCache,
    default_cache,
    pattern_digest,
)
from repro.spgemm.persist import PLAN_DIR_ENV, PlanStore
from repro.spgemm.executor import ShardedSpGEMMExecutor, SpGEMMExecutor
from repro.spgemm.gateway import (
    GatewayResult,
    GatewayShed,
    GatewayTicket,
    Outcome,
    SpGEMMGateway,
)
from repro.spgemm.pipeline import (
    PipelineFullError,
    SpGEMMPipeline,
    SpGEMMTicket,
)
from repro.spgemm.plan import (
    PlanReport,
    ShardedSpGEMMPlan,
    SpGEMMChain,
    SpGEMMPlan,
    StructuralPattern,
    chain_plans,
    execute_chain,
    plan_from_structural_pattern,
    resolve_backend,
    schedule_build_count,
    spgemm_plan,
)

__all__ = [
    "CacheStats",
    "GatewayResult",
    "GatewayShed",
    "GatewayTicket",
    "Outcome",
    "PLAN_DIR_ENV",
    "PipelineFullError",
    "PlanCache",
    "PlanReport",
    "PlanStore",
    "ShardedSpGEMMExecutor",
    "ShardedSpGEMMPlan",
    "SpGEMMChain",
    "SpGEMMExecutor",
    "SpGEMMGateway",
    "SpGEMMPipeline",
    "SpGEMMPlan",
    "SpGEMMTicket",
    "StructuralPattern",
    "TunedConfig",
    "autotune_plan",
    "chain_plans",
    "default_cache",
    "execute_chain",
    "pattern_digest",
    "plan_from_structural_pattern",
    "probe_run_count",
    "resolve_backend",
    "schedule_build_count",
    "spgemm_plan",
]
