"""Per-pattern plan autotuning: model-pruned, probe-measured configs.

FSpGEMM tunes its FPGA design per matrix — the paper picks PE count and
buffer depths per workload and amortizes the choice over every numeric
run that reuses the pattern. This module is that idea as a service knob
for the plan/execute stack: given one sparsity pattern, search the plan
config space

    (tile, group)      — structural: changes the schedule and C blocking
    chunk_bytes        — executor batch-fusion budget (``batch_chunk``)
    pipeline depth     — submit/collect stages for streamed serving

and persist the winner next to the plan artifacts so every later process
serving the same pattern starts tuned, with **zero** probe executions.

Two-stage search (cheap model first, short measurements second):

1. **Model pruning.** Every candidate ``(tile, group)`` builds (or cache-
   hits) its plan — symbolic phase only — and is ranked by the roofline
   estimate :func:`repro.core.perfmodel.roofline_seconds` over the
   schedule's exact FLOP/traffic counts
   (:func:`repro.core.perfmodel.spgemm_schedule_traffic`, fed by the plan
   report's triple/fetch counters). Only the top ``model_top_k`` survive
   — plus the caller's requested config, always, so measurement can never
   do worse than the default by construction (argmax over a set that
   contains it).
2. **Measured probes.** Survivors (crossed with the chunk-bytes
   candidates) run short interleaved min-of-N timed ``execute_batch``
   probes on synthetic small-integer values — the same probe machinery
   as :func:`repro.core.tuning.measure_chunk_knee` (warmup off-clock,
   interleaved repeats so drift lands evenly, min-of-N). The best
   measured config wins; pipeline depth is then probed on the winner
   only (``plan.pipeline(depth).stream`` over a short value stream).

The result is a :class:`TunedConfig` carrying measured values/s for the
winner *and* the requested default, the model's rank of the winner, and
the model-vs-measured ranking agreement (concordant-pair fraction) — the
auditable record of how much the model pruning can be trusted on this
host. ``spgemm_plan(..., autotune=True)`` and
``SpGEMMGateway.register(..., autotune=True)`` run this search and apply
the winner; the config persists through the plan cache's disk tier
(:meth:`PlanCache.tuned_put`, a versioned :class:`PlanStore` sidecar
record) so a warm restart rehydrates schedule **and** tuned config from
disk. Config precedence stays operator-safe: ``REPRO_SPGEMM_CHUNK_BYTES``
still beats any tuned value (see ``resolve_chunk_bytes``).

Numerics are untouched by construction: ``chunk_bytes`` and pipeline
depth are proven bitwise-invariant (chunked/streamed results equal
per-element executes), and a tuned ``(tile, group)`` produces results
bitwise-equal to an untuned plan built directly at that tile/group.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.perfmodel import (
    CPU_XEON_E5_2637,
    DeviceModel,
    TPU_V5E_CHIP,
    roofline_seconds,
    spgemm_schedule_traffic,
)
from repro.core.tuning import interleaved_best_ms
from repro.spgemm.cache import PlanCache, default_cache
from repro.spgemm.plan import (
    SpGEMMPlan,
    _mesh_key,
    _normalize_tile,
    resolve_backend,
    spgemm_plan,
)

__all__ = [
    "TunedConfig",
    "autotune_plan",
    "probe_run_count",
]

# Global count of measured probe executions (one per timed thunk run,
# warmups included). The warm-restart acceptance criterion: loading a
# persisted TunedConfig must leave this counter untouched.
_PROBE_RUNS = 0


def probe_run_count() -> int:
    return _PROBE_RUNS


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """The autotuner's winning plan config plus its measurement record.

    ``values_per_s`` / ``default_values_per_s`` are probe-measured batch
    throughputs (value sets per second) for the winner and for the
    caller's requested config on the same host; their ratio is the
    predicted warm-path speedup. ``model_rank`` is the roofline model's
    0-based rank of the winning (tile, group) among all candidates, and
    ``ranking_agreement`` the concordant-pair fraction between model
    estimates and measured probe times over the survivors — 1.0 means
    the model ordered every measured pair correctly.
    ``source`` records provenance: ``"probed"`` (searched on this host)
    or ``"persisted"`` (rehydrated from the disk sidecar, zero probes).
    """

    tile: Tuple[int, int, int]
    group: int
    chunk_bytes: Optional[int]  # per-set knee budget; None = policy table
    pipeline_depth: int
    values_per_s: float
    default_values_per_s: float
    model_rank: int
    ranking_agreement: float
    probes: int  # timed probe executions this search paid
    source: str = "probed"

    def to_meta(self) -> dict:
        """JSON-able dict for the PlanStore sidecar record. Floats ride
        through ``repr`` (via json) bitwise — round-tripping a persisted
        config reproduces the measured numbers exactly."""
        d = dataclasses.asdict(self)
        d["tile"] = list(self.tile)
        return d

    @classmethod
    def from_meta(cls, meta: dict, *, source: Optional[str] = None) -> "TunedConfig":
        kw = dict(meta)
        kw["tile"] = tuple(int(t) for t in kw["tile"])
        kw["group"] = int(kw["group"])
        cb = kw.get("chunk_bytes")
        kw["chunk_bytes"] = None if cb is None else int(cb)
        kw["pipeline_depth"] = int(kw["pipeline_depth"])
        kw["probes"] = int(kw["probes"])
        kw["model_rank"] = int(kw["model_rank"])
        if source is not None:
            kw["source"] = source
        return cls(**kw)

    @property
    def speedup(self) -> float:
        """Measured winner-over-default throughput ratio."""
        if self.default_values_per_s <= 0:
            return 1.0
        return self.values_per_s / self.default_values_per_s


def _model_device(backend: str) -> DeviceModel:
    """The roofline device for candidate ranking. Ordering is all that
    matters for pruning, so a representative CPU/TPU model suffices."""
    return TPU_V5E_CHIP if backend == "pallas" else CPU_XEON_E5_2637


def _tile_ladder(t: int, floor: int = 8, cap: int = 256) -> List[int]:
    """{t/2, t, 2t} clipped to [floor, cap] — the structural search axis
    around the caller's request."""
    out = []
    for c in (t // 2, t, t * 2):
        c = max(floor, min(cap, int(c)))
        if c not in out:
            out.append(c)
    return out


def _default_candidates(
    tile: Tuple[int, int, int], group: int
) -> List[Tuple[Tuple[int, int, int], int]]:
    """(tile, group) grid: square-tile ladder x group ladder around the
    request. Tiles stay square (bm == bk == bn) unless the caller asked
    for a rectangular tile, in which case the whole tuple scales."""
    bm, bk, bn = tile
    if bm == bk == bn:
        tiles = [(t, t, t) for t in _tile_ladder(bm)]
    else:
        tiles = []
        for s in (0.5, 1.0, 2.0):
            cand = tuple(max(8, min(256, int(d * s))) for d in tile)
            if cand not in tiles:
                tiles.append(cand)
    groups = []
    for g in (max(1, group // 2), group, group * 2):
        if g not in groups:
            groups.append(g)
    return [(t, g) for t in tiles for g in groups]


def _chunk_candidates(backend: str) -> List[Optional[int]]:
    """chunk_bytes (small_set knee) candidates: the policy default
    (``None``) plus a half/double bracket of the backend's table row."""
    from repro.spgemm.executor import _CHUNK_POLICY

    family = "tpu" if backend == "pallas" else "cpu"
    small, _ = _CHUNK_POLICY[family]
    out: List[Optional[int]] = [None]
    for c in (small // 2, small * 2):
        if c > 0 and c not in out:
            out.append(int(c))
    return out


def _synthetic_batch(plan: SpGEMMPlan, batch: int, seed: int):
    """A [batch, ...] pair of small-integer value sets matching the
    plan's numeric-phase contract (element vectors or packed blocks).
    Small ints are exact in f32 — probe runs are bitwise-comparable
    across configs, the same trick as ``tuning._random_int_coo``."""
    rng = np.random.default_rng(seed)
    want_a, want_b = plan.value_shapes()

    def draw(shape, dtype):
        return rng.integers(-3, 4, (batch,) + tuple(shape)).astype(dtype)

    return (
        draw(want_a, plan._a_dtype),
        draw(want_b, plan._b_dtype),
    )


def _ranking_agreement(
    model_s: Sequence[float], measured_ms: Sequence[float]
) -> float:
    """Concordant-pair fraction between the model's and the measured
    ordering (Kendall-style, ties count as half). 1.0 = the model
    ordered every measured pair correctly; 0.5 = no information."""
    n = len(model_s)
    pairs = concordant = 0.0
    for i in range(n):
        for j in range(i + 1, n):
            dm = model_s[i] - model_s[j]
            dt = measured_ms[i] - measured_ms[j]
            pairs += 1
            if dm == 0 or dt == 0:
                concordant += 0.5
            elif (dm > 0) == (dt > 0):
                concordant += 1
    return concordant / pairs if pairs else 1.0


def _probe_batch_fn(
    plan: SpGEMMPlan, a_batch, b_batch, chunk_bytes: Optional[int]
) -> Callable:
    """A probe thunk: one full ``execute_batch`` under a temporarily
    applied chunk budget. The plan's resolved policy is swapped in and
    restored around the call so concurrent (non-probe) users of a shared
    cached plan never see a half-tuned executor for long — and the probe
    still measures the real ``batch_chunk`` path, not a bypass."""

    def run():
        global _PROBE_RUNS
        _PROBE_RUNS += 1
        ex = plan._executor
        if ex is None:
            return np.zeros(1, np.float32)
        saved = ex._chunk_policy
        ex.set_chunk_bytes(chunk_bytes)
        try:
            out = plan.execute_batch(a_batch, b_batch)
        finally:
            ex._chunk_policy = saved
        return out[0].data if out else np.zeros(1, np.float32)

    return run


def _probe_stream_fn(plan: SpGEMMPlan, a_batch, b_batch, depth: int) -> Callable:
    """A pipeline-depth probe thunk: stream the batch through a
    ``depth``-deep submit/collect pipeline (the serving path a gateway
    round takes)."""

    def run():
        global _PROBE_RUNS
        _PROBE_RUNS += 1
        last = None
        for out in plan.execute_stream(
            ((a_batch[i], b_batch[i]) for i in range(a_batch.shape[0])),
            depth=depth,
        ):
            last = out
        return last.data if last is not None else np.zeros(1, np.float32)

    return run


def autotune_plan(
    a,
    b,
    *,
    tile: Union[int, Tuple[int, ...]] = 64,
    group: int = 4,
    backend: str = "auto",
    cache: Optional[PlanCache] = None,
    mesh=None,
    mesh_axis: Optional[str] = None,
    pattern_token: Optional[str] = None,
    candidates: Optional[Sequence[Tuple[Tuple[int, int, int], int]]] = None,
    chunk_candidates: Optional[Sequence[Optional[int]]] = None,
    depth_candidates: Sequence[int] = (1, 2, 4),
    model_top_k: int = 3,
    probe_batch: int = 8,
    repeats: int = 3,
    seed: int = 0,
    timer=None,
    force: bool = False,
) -> SpGEMMPlan:
    """Search the plan config space for ``(a, b)``'s pattern and return
    the winning plan with its :class:`TunedConfig` applied.

    The search key is the *requested* config's plan cache key, so the
    persisted record is found again by any process asking to autotune
    the same pattern at the same starting point. On a sidecar hit the
    tuned plan is rebuilt/fetched directly — **zero probes** — unless
    ``force=True`` re-measures.

    ``timer`` injects a ``perf_counter``-like clock into every
    measurement (exactly two calls per timed run) — the determinism seam
    tuner tests use. All other knobs bound the search: ``candidates``
    overrides the (tile, group) grid, ``model_top_k`` how many survive
    the roofline pruning, ``probe_batch``/``repeats`` the measured-probe
    cost.

    Block-format inputs (BCSV/BCSR) fix the tile/group structurally, so
    the search restricts to ``chunk_bytes`` and pipeline depth.
    """
    global _PROBE_RUNS
    backend = resolve_backend(backend)
    if cache is None:
        cache = default_cache()
    req_tile = _normalize_tile(tile)
    req_group = int(group)

    # The sidecar key = the requested config's standard plan key. Building
    # the requested plan first also gives the digest (and seeds the cache
    # for the default-probe stage).
    base_plan = spgemm_plan(
        a, b, tile=req_tile, group=req_group, backend=backend, cache=cache,
        mesh=mesh, mesh_axis=mesh_axis, pattern_token=pattern_token,
    )
    block_input = base_plan._a_scatter is None or base_plan._b_scatter is None
    if block_input:
        # Block formats fix tile/group structurally (spgemm_plan ignores
        # the args); rebase the search on the plan's real config so the
        # sidecar key and TunedConfig match what was actually built.
        req_tile = tuple(int(t) for t in base_plan.report.tile)
        req_group = int(base_plan.report.group)
    shard_key = _mesh_key(mesh, mesh_axis)
    base_key = (
        base_plan.report.pattern_key, req_tile, req_group, backend, shard_key
    )

    if not force:
        meta = cache.tuned_get(base_key)
        if meta is not None:
            cfg = TunedConfig.from_meta(meta, source="persisted")
            if cfg.tile == req_tile and cfg.group == req_group:
                win = base_plan
            else:
                win = spgemm_plan(
                    a, b, tile=cfg.tile, group=cfg.group, backend=backend,
                    cache=cache, mesh=mesh, mesh_axis=mesh_axis,
                )
            win.apply_tuned_config(cfg)
            return win

    # -- stage 1: model pruning over the (tile, group) grid ---------------
    if block_input:
        grid = [(req_tile, req_group)]
    elif candidates is not None:
        grid = [(_normalize_tile(t), int(g)) for t, g in candidates]
        if (req_tile, req_group) not in grid:
            grid.append((req_tile, req_group))
    else:
        grid = _default_candidates(req_tile, req_group)

    device = _model_device(backend)
    ranked = []  # (model_seconds, tile, group, plan)
    for t, g in grid:
        if (t, g) == (req_tile, req_group):
            p = base_plan
        else:
            p = spgemm_plan(
                a, b, tile=t, group=g, backend=backend, cache=cache,
                mesh=mesh, mesh_axis=mesh_axis,
            )
        r = p.report
        traffic = spgemm_schedule_traffic(
            num_triples=r.num_triples, nnzb_a=r.nnzb_a,
            b_fetches=r.b_fetches, n_panels=r.n_panels,
            tile=t, group=g, dtype_bytes=p._a_dtype.itemsize,
        )
        est = roofline_seconds(traffic["flops"], traffic["bytes"], device)
        ranked.append((est, t, g, p))
    ranked.sort(key=lambda x: (x[0], x[1], x[2]))
    model_rank_of = {
        (t, g): i for i, (_, t, g, _) in enumerate(ranked)
    }
    survivors = ranked[: max(1, int(model_top_k))]
    # The requested config always survives: measurement then cannot pick
    # a config worse than the default (argmax over a set containing it).
    if all((t, g) != (req_tile, req_group) for _, t, g, _ in survivors):
        survivors.append(next(
            x for x in ranked if (x[1], x[2]) == (req_tile, req_group)
        ))

    # -- stage 2: measured probes (interleaved min-of-N) ------------------
    chunks = (
        list(chunk_candidates) if chunk_candidates is not None
        else _chunk_candidates(backend)
    )
    probes_before = _PROBE_RUNS
    entries = []  # (model_s, tile, group, plan, chunk_bytes, fn)
    for est, t, g, p in survivors:
        a_b, b_b = _synthetic_batch(p, probe_batch, seed)
        for cb in chunks:
            entries.append(
                (est, t, g, p, cb, _probe_batch_fn(p, a_b, b_b, cb))
            )
    # Warmup off-clock: first run of each thunk pays compilation/staging.
    for e in entries:
        e[5]()
    times = interleaved_best_ms([e[5] for e in entries], repeats, timer=timer)

    best_i = int(np.argmin(times))
    _, win_t, win_g, win_plan, win_cb, _ = entries[best_i]
    # The default config's measured time: the requested (tile, group) at
    # the policy-table chunk (None) — present by construction.
    default_i = next(
        i for i, e in enumerate(entries)
        if (e[1], e[2]) == (req_tile, req_group) and e[4] is None
    )

    # Model-vs-measured agreement over the per-(tile, group) best times —
    # the quantity the model actually ranked.
    per_cfg: dict = {}
    for e, ms in zip(entries, times):
        k = (e[1], e[2])
        if k not in per_cfg or ms < per_cfg[k][1]:
            per_cfg[k] = (e[0], ms)
    agreement = _ranking_agreement(
        [v[0] for v in per_cfg.values()], [v[1] for v in per_cfg.values()]
    )

    # -- stage 3: pipeline depth, winner only ------------------------------
    depth = 2
    depths = [int(d) for d in depth_candidates if int(d) >= 1]
    if len(depths) > 1:
        a_b, b_b = _synthetic_batch(win_plan, probe_batch, seed)
        fns = [_probe_stream_fn(win_plan, a_b, b_b, d) for d in depths]
        for fn in fns:
            fn()  # warmup off-clock
        d_times = interleaved_best_ms(fns, repeats, timer=timer)
        depth = depths[int(np.argmin(d_times))]
    elif depths:
        depth = depths[0]

    def to_vps(ms: float) -> float:
        if not math.isfinite(ms) or ms <= 0:
            return 0.0
        return probe_batch / (ms * 1e-3)

    cfg = TunedConfig(
        tile=win_t,
        group=win_g,
        chunk_bytes=win_cb,
        pipeline_depth=depth,
        values_per_s=to_vps(times[best_i]),
        default_values_per_s=to_vps(times[default_i]),
        model_rank=model_rank_of[(win_t, win_g)],
        ranking_agreement=agreement,
        probes=_PROBE_RUNS - probes_before,
        source="probed",
    )
    cache.tuned_put(base_key, cfg.to_meta())
    win_plan.apply_tuned_config(cfg)
    return win_plan
