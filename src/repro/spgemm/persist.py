"""Versioned on-disk store for SpGEMM plan artifacts (warm restarts).

FSpGEMM's premise is that pre-processing is host work done *once per
pattern* — but a process-level cache amortizes it only within one process
lifetime. This module is the disk tier behind
:class:`repro.spgemm.cache.PlanCache`: the value-independent symbolic
artifacts (triple schedule, scatter indices, assembly map, shard bounds —
serialized through the flat-array codecs in ``repro.core.schedule``) are
written once per cache key, and a restarted worker rehydrates the plan
instead of re-running the symbolic phase.

Design constraints, in order:

* **Never poison a computation.** Every load is integrity-checked — a
  format-version header, the full cache key echoed back, and a BLAKE2b
  digest over every payload array — and *any* failure (truncated file,
  bit flip, version bump, a foreign file renamed onto this key) returns
  ``None`` so the caller falls back to a fresh symbolic build. Unreadable
  files are best-effort deleted so they cannot fail every restart.
* **Crash-safe writes.** Payloads are written to a same-directory temp
  file, fsynced, and ``os.replace``-d into place (with a directory fsync
  on POSIX so the rename itself is durable); a crash — or a power cut —
  leaves either the old file or a stray ``*.tmp`` (ignored and
  garbage-collected), never a truncated-but-renamed readable entry.
* **Bounded footprint.** ``max_bytes`` evicts oldest-used entries after
  each save (successful loads refresh mtime, so eviction is LRU-ish across
  processes; equal-mtime files tie-break deterministically by name); the
  just-written file is always kept.

Besides plan artifacts the store keeps one tiny versioned index file
(``tokens.index.json``) mapping ``pattern_token`` alias keys to full plan
keys, written with the same atomic tmp+rename+fsync discipline — see
:meth:`PlanStore.alias_put` and the ``token_disk_hits`` counter in
:class:`repro.spgemm.cache.CacheStats`.

The store holds only numpy arrays plus a JSON header (``allow_pickle`` is
never enabled), so a corrupt or malicious cache directory can cause at
worst a rebuild, not code execution.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["FORMAT_VERSION", "PLAN_DIR_ENV", "PlanStore", "plan_file_name"]

# Bump on any incompatible change to the artifact layout; older files are
# ignored (and evicted), not migrated.
FORMAT_VERSION = 1

# Setting this enables the disk tier on the process-default PlanCache.
PLAN_DIR_ENV = "REPRO_SPGEMM_PLAN_DIR"

_SUFFIX = ".plan.npz"
_META_KEY = "__meta__"
_ALIAS_FILE = "tokens.index.json"


def _key_repr(key: Tuple) -> str:
    """Canonical string form of a cache key. Keys are tuples of str / int /
    nested tuples (pattern digest, tile, group, backend, mesh key), so
    ``repr`` is stable across processes and Python builds."""
    return repr(key)


def plan_file_name(key: Tuple) -> str:
    """Filename for a cache key: a digest of the canonical key string.

    The full key is also stored *inside* the file and verified on load, so
    a digest collision (or a file renamed across keys) degrades to a
    rebuild, never to serving the wrong plan."""
    return _file_name_for_repr(_key_repr(key))


def _file_name_for_repr(key_repr: str) -> str:
    """Same as :func:`plan_file_name` but from an already-repr'd key —
    the alias index stores key reprs, so audit/alias lookups can locate
    the target file without ``literal_eval``-ing the repr back."""
    h = hashlib.blake2b(key_repr.encode(), digest_size=16)
    return h.hexdigest() + _SUFFIX


def _payload_digest(arrays: Dict[str, np.ndarray], meta: dict) -> str:
    """BLAKE2b over the meta dict and every array's name, dtype, shape,
    and bytes (both canonically ordered, so dict order never changes the
    digest). Meta is inside the digest so a parseable-but-tampered JSON
    header (a flipped shape digit, say) cannot pass verification and feed
    ``from_artifacts`` wrong geometry."""
    h = hashlib.blake2b(digest_size=16)
    h.update(json.dumps(meta, sort_keys=True).encode())
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class PlanStore:
    """A directory of integrity-checked plan-artifact files.

    ``save``/``load`` speak ``(arrays, meta)``: a flat ``{name: ndarray}``
    payload (the codecs in ``repro.core.schedule`` produce/consume these)
    plus a small JSON-able dict of plan metadata. The store itself is
    plan-agnostic — rehydration lives in ``SpGEMMPlan.from_artifacts``.

    All methods are safe to call concurrently from multiple processes
    pointed at one directory: writes are atomic renames, loads re-verify
    content, and a lost eviction race is at worst a double unlink (ignored).
    """

    def __init__(self, root: str, max_bytes: Optional[int] = None):
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 (or None)")
        self.root = os.path.abspath(root)
        self.max_bytes = max_bytes
        self.evictions = 0  # files this store instance deleted for budget
        self._lock = threading.Lock()
        os.makedirs(self.root, exist_ok=True)
        self._gc_stale_tmps()

    # -- paths / accounting ------------------------------------------------

    def _gc_stale_tmps(self, max_age_s: float = 3600.0) -> None:
        """Delete orphaned ``*.tmp`` files (a writer crashed mid-save).

        Run at store construction — i.e. at every restart, exactly when
        orphans accumulate. The age threshold spares another live
        process's in-flight write; a just-crashed writer's tmp is
        collected by the restart after next (or any store opened an hour
        later)."""
        cutoff = time.time() - max_age_s
        try:
            names = os.listdir(self.root)
        except OSError:
            return
        for n in names:
            if not n.endswith(".tmp"):
                continue
            p = os.path.join(self.root, n)
            try:
                if os.path.getmtime(p) < cutoff:
                    os.unlink(p)
            except OSError:
                continue

    def path_for(self, key: Tuple) -> str:
        return os.path.join(self.root, plan_file_name(key))

    def files(self) -> List[str]:
        """Store entries, oldest-used first (mtime ascending; equal
        mtimes tie-break by name so the eviction order is deterministic
        across processes and filesystems with coarse timestamps)."""
        try:
            names = [
                n for n in os.listdir(self.root) if n.endswith(_SUFFIX)
            ]
        except OSError:
            return []
        paths = []
        for n in names:
            p = os.path.join(self.root, n)
            try:
                paths.append((os.path.getmtime(p), n, p))
            except OSError:  # raced with another process's eviction
                continue
        return [p for _, _, p in sorted(paths)]

    def total_bytes(self) -> int:
        total = 0
        for p in self.files():
            try:
                total += os.path.getsize(p)
            except OSError:
                continue
        return total

    def __len__(self) -> int:
        return len(self.files())

    def __contains__(self, key: Tuple) -> bool:
        return os.path.exists(self.path_for(key))

    # -- save / load -------------------------------------------------------

    def save(
        self, key: Tuple, arrays: Dict[str, np.ndarray], meta: dict
    ) -> Optional[str]:
        """Write one entry atomically. Returns the path, or ``None`` if the
        write failed (persistence is an optimization — a full disk or
        read-only directory must not break plan building)."""
        header = {
            "format_version": FORMAT_VERSION,
            "key": _key_repr(key),
            "digest": _payload_digest(arrays, meta),
            "meta": meta,
        }
        path = self.path_for(key)
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        try:
            payload = dict(arrays)
            payload[_META_KEY] = np.frombuffer(
                json.dumps(header).encode(), np.uint8
            )
            with open(tmp, "wb") as f:
                np.savez(f, **payload)
                # fsync BEFORE the rename: os.replace is atomic for
                # concurrent readers but not against power loss — without
                # the flush a crash can surface a truncated file under the
                # final name, which would then fail (and delete) on every
                # restart's load.
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            self._fsync_dir()
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return None
        if self.max_bytes is not None:
            self._evict(keep=path)
        return path

    def _fsync_dir(self) -> None:
        """Fsync the store directory (POSIX) so a just-renamed entry's
        directory record is durable too. Best effort — platforms that
        cannot open a directory read-only simply skip it."""
        if os.name != "posix":  # pragma: no cover - platform dependent
            return
        try:
            fd = os.open(self.root, os.O_RDONLY)
        except OSError:  # pragma: no cover - unreadable store dir
            return
        try:
            os.fsync(fd)
        except OSError:  # pragma: no cover - fs without dir fsync
            pass
        finally:
            os.close(fd)

    def load(
        self, key: Tuple
    ) -> Optional[Tuple[Dict[str, np.ndarray], dict]]:
        """Read and verify one entry. Returns ``(arrays, meta)``, or
        ``None`` on a miss or *any* verification failure — version
        mismatch, key mismatch, payload-digest mismatch, or an unreadable
        file (which is deleted so it cannot fail every restart)."""
        path = self.path_for(key)
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as npz:
                raw = npz.get(_META_KEY)
                if raw is None:
                    raise ValueError("missing header")
                header = json.loads(bytes(np.asarray(raw)).decode())
                arrays = {
                    n: npz[n] for n in npz.files if n != _META_KEY
                }
            if header.get("format_version") != FORMAT_VERSION:
                raise ValueError(
                    f"format version {header.get('format_version')!r}"
                )
            if header.get("key") != _key_repr(key):
                raise ValueError("key mismatch")
            meta = header.get("meta")
            if not isinstance(meta, dict):
                raise ValueError("bad meta")
            if header.get("digest") != _payload_digest(arrays, meta):
                raise ValueError("payload digest mismatch")
        except Exception:
            # Stale/corrupt/foreign: drop it (best effort) and rebuild.
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        # Refresh recency so cross-process eviction is LRU-ish.
        try:
            os.utime(path)
        except OSError:
            pass
        return arrays, meta

    # -- pattern-token alias index -----------------------------------------
    #
    # One tiny JSON file mapping pattern-token alias keys (their canonical
    # repr) to full plan keys, so a restarted worker resolves
    # ``spgemm_plan(..., pattern_token=)`` straight to a disk load without
    # ever paying the first COO digest. The index is an optimization with
    # last-writer-wins semantics across processes: a lost concurrent
    # update costs one digest on the next restart, never a wrong plan
    # (the aliased entry is still integrity-checked on load).

    def alias_path(self) -> str:
        return os.path.join(self.root, _ALIAS_FILE)

    def _read_aliases(self) -> Dict[str, str]:
        try:
            with open(self.alias_path(), "r", encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return {}
        if (
            not isinstance(doc, dict)
            or doc.get("format_version") != FORMAT_VERSION
            or not isinstance(doc.get("aliases"), dict)
        ):
            return {}  # version bump / corruption degrades to a miss
        return {
            str(t): str(k) for t, k in doc["aliases"].items()
        }

    def alias_get(self, token_repr: str) -> Optional[str]:
        """The full-key repr bound to one token-key repr, or ``None``.

        An alias whose target artifact file no longer exists (evicted or
        deleted out-of-band) is a **miss**, not a dangling pointer: the
        caller would pay a doomed ``store.load`` and then the digest path
        anyway, so resolve straight to the digest path instead. Orphans
        are reported and pruned by :meth:`audit`."""
        key_repr = self._read_aliases().get(token_repr)
        if key_repr is None:
            return None
        target = os.path.join(self.root, _file_name_for_repr(key_repr))
        if not os.path.exists(target):
            return None
        return key_repr

    def _write_aliases_locked(self, aliases: Dict[str, str]) -> bool:
        """Atomically replace the alias index (caller holds ``_lock``)."""
        doc = {"format_version": FORMAT_VERSION, "aliases": aliases}
        path = self.alias_path()
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(doc, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            self._fsync_dir()
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        return True

    def alias_put(self, token_repr: str, key_repr: str) -> bool:
        """Bind (or re-confirm) one token alias; returns False if the
        write failed (persistence is an optimization, never fatal)."""
        with self._lock:
            aliases = self._read_aliases()
            if aliases.get(token_repr) == key_repr:
                return True
            aliases[token_repr] = key_repr
            return self._write_aliases_locked(aliases)

    def audit(self, prune: bool = True) -> dict:
        """Consistency report over the store directory.

        Cross-checks the token-alias index against the artifact files:
        an alias whose target file is gone (``_evict`` unlinks files but
        not their aliases; so does an out-of-band ``rm``) is *orphaned*.
        With ``prune=True`` (the default) orphaned aliases are removed
        from ``tokens.index.json`` in one atomic rewrite.

        Returns ``{"files": int, "bytes": int, "aliases": int,
        "orphaned": [token_repr, ...], "pruned": bool}``; ``pruned`` is
        True only when an orphan was actually removed from disk."""
        with self._lock:
            aliases = self._read_aliases()
            orphaned = [
                tok for tok, key_repr in aliases.items()
                if not os.path.exists(
                    os.path.join(self.root, _file_name_for_repr(key_repr))
                )
            ]
            pruned = False
            if prune and orphaned:
                for tok in orphaned:
                    aliases.pop(tok, None)
                pruned = self._write_aliases_locked(aliases)
        return {
            "files": len(self.files()),
            "bytes": self.total_bytes(),
            "aliases": len(aliases),
            "orphaned": sorted(orphaned),
            "pruned": pruned,
        }

    # -- eviction ----------------------------------------------------------

    def _evict(self, keep: Optional[str] = None) -> None:
        """Delete oldest-used entries until under ``max_bytes``; ``keep``
        (the just-written file) is never deleted."""
        if self.max_bytes is None:
            return
        with self._lock:
            entries = []
            for p in self.files():
                try:
                    entries.append((p, os.path.getsize(p)))
                except OSError:
                    continue
            total = sum(s for _, s in entries)
            for p, size in entries:
                if total <= self.max_bytes:
                    break
                if p == keep:
                    continue
                try:
                    os.unlink(p)
                except OSError:
                    continue
                total -= size
                self.evictions += 1

    def clear(self) -> None:
        """Delete every entry (plans and the token-alias index),
        including orphaned temp files."""
        for p in self.files():
            try:
                os.unlink(p)
            except OSError:
                pass
        try:
            os.unlink(self.alias_path())
        except OSError:
            pass
        self._gc_stale_tmps(max_age_s=-1.0)  # all tmps, even fresh ones
