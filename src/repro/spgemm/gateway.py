"""Multi-tenant SpGEMM serving gateway: micro-batching, fair scheduling,
backpressure, and per-pattern metrics over the plan/execute stack.

FSpGEMM amortizes per-matrix preprocessing so the steady state is a
stream of numeric executes against a fixed pattern; at fleet scale that
stream is *many* tenants hammering *many* recurring patterns
concurrently. :class:`SpGEMMGateway` is the front end above per-plan
pipelines that admits that traffic:

* **Submit/collect per request.** ``submit(pattern_token, a_vals,
  b_vals)`` returns a :class:`GatewayTicket` immediately; redeem with
  ``ticket.wait()`` (a typed :class:`GatewayResult`) or
  ``ticket.result()`` (the CSR, raising on shed/failure). Patterns are
  named by the ``pattern_token`` fast key (PR 5): :meth:`register`
  resolves the plan once through :class:`~repro.spgemm.cache.PlanCache`
  and every subsequent request is numeric-only.
* **Micro-batching.** Same-pattern requests arriving within
  ``batch_window`` seconds (or piling up to ``max_batch``) are stacked
  into ONE batched pipeline submission — ``execute_batch`` semantics, so
  each request's result is **bitwise-equal** to a direct
  ``plan.execute`` of its values.
* **Fair scheduling.** Dispatch is deficit round-robin by pending
  **value bytes** over a bounded pool of at most ``max_pipelines`` live
  :class:`~repro.spgemm.pipeline.SpGEMMPipeline` objects: each ripe
  pattern earns an equal byte quantum per round, so one hot tenant can
  queue a million requests without starving the rest. Pool eviction only
  ever closes an *idle* pipeline (``in_flight == 0``) — the PR-5 pin
  guard means a pipeline with outstanding tickets is never torn down.
* **Admission control / backpressure.** Overload produces explicit typed
  outcomes (:class:`Outcome`), never executor exceptions: a full
  per-pattern queue sheds ``SHED_QUEUE_FULL``, exceeding the gateway's
  total in-flight byte budget sheds ``SHED_BYTES``, a
  :class:`~repro.spgemm.cache.PlanCache` over its byte budget sheds
  ``SHED_CACHE_PRESSURE``, and a closing gateway sheds ``SHED_CLOSED``.
  Shed tickets resolve immediately; admitted work that fails on device
  resolves ``FAILED`` with the error attached.
* **Metrics.** Per-pattern queue depth, batch-fill ratio, p50/p99
  latency, throughput, and shed counts are recorded in a
  :class:`~repro.runtime.heartbeat.MetricsRegistry` (pass your own to
  share it with a :class:`~repro.runtime.heartbeat.Heartbeat` exporter);
  :meth:`stats` snapshots everything, including ``PlanCache.stats()``.

Threading model: ``submit`` is safe from any number of threads; one
dispatcher thread forms batches and dispatches them (JAX async — nothing
blocks), one collector thread blocks on D2H and resolves tickets. A
pattern's pipeline keeps up to ``depth`` batches in flight, so staging
for batch ``k+1`` overlaps batch ``k``'s kernel exactly as in
:mod:`repro.spgemm.pipeline`.
"""
from __future__ import annotations

import dataclasses
import enum
import queue
import threading
import time
from collections import OrderedDict, deque
from typing import Dict, Optional, Tuple

import numpy as np

from repro.runtime.heartbeat import MetricsRegistry
from repro.spgemm.cache import PlanCache, default_cache
from repro.spgemm.pipeline import SpGEMMPipeline
from repro.spgemm.plan import SpGEMMPlan, spgemm_plan

__all__ = [
    "GatewayResult",
    "GatewayShed",
    "GatewayTicket",
    "Outcome",
    "SpGEMMGateway",
]


class Outcome(enum.Enum):
    """Terminal state of one gateway request."""

    OK = "ok"
    SHED_QUEUE_FULL = "shed_queue_full"  # per-pattern queue at max_queue
    SHED_BYTES = "shed_bytes"  # gateway in-flight byte budget exceeded
    SHED_CACHE_PRESSURE = "shed_cache_pressure"  # PlanCache over byte budget
    SHED_CLOSED = "shed_closed"  # gateway draining or closed
    FAILED = "failed"  # admitted, but dispatch/device execution errored

    @property
    def shed(self) -> bool:
        return self.value.startswith("shed_")


@dataclasses.dataclass
class GatewayResult:
    """Typed outcome of one request (what ``ticket.wait()`` returns).

    ``value`` is the CSR result for ``OK``, ``error`` the stored exception
    for ``FAILED``; sheds carry neither. ``latency_s`` is submit-to-resolve
    wall time; ``seq`` is the gateway-wide completion sequence number
    (sheds resolve with ``seq=0`` — they never enter the scheduler)."""

    outcome: Outcome
    pattern: str
    value: object = None
    error: Optional[BaseException] = None
    latency_s: float = 0.0
    seq: int = 0


class GatewayShed(RuntimeError):
    """Raised by ``ticket.result()`` for a shed request (callers that
    prefer typed outcomes use ``ticket.wait()`` instead)."""

    def __init__(self, outcome: Outcome, pattern: str):
        super().__init__(
            f"request for pattern {pattern!r} was shed: {outcome.value}"
        )
        self.outcome = outcome
        self.pattern = pattern


class GatewayTicket:
    """Future-like handle for one submitted request."""

    __slots__ = ("pattern", "_event", "_result")

    def __init__(self, pattern: str):
        self.pattern = pattern
        self._event = threading.Event()
        self._result: Optional[GatewayResult] = None

    def _resolve(self, result: GatewayResult) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> GatewayResult:
        """Block until resolved; returns the typed :class:`GatewayResult`
        (never raises for sheds/failures). Raises ``TimeoutError`` if the
        request is still pending after ``timeout`` seconds."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request for pattern {self.pattern!r} still pending after "
                f"{timeout}s"
            )
        return self._result

    def result(self, timeout: Optional[float] = None):
        """Block and return the CSR; raises :class:`GatewayShed` for shed
        requests and re-raises the stored error for failed ones."""
        r = self.wait(timeout)
        if r.outcome is Outcome.OK:
            return r.value
        if r.outcome is Outcome.FAILED:
            raise r.error
        raise GatewayShed(r.outcome, self.pattern)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        state = self._result.outcome.value if self._event.is_set() else "pending"
        return f"GatewayTicket(pattern={self.pattern!r}, {state})"


class _Request:
    __slots__ = ("a", "b", "nbytes", "ticket", "t_submit")

    def __init__(self, a, b, nbytes, ticket, t_submit):
        self.a = a
        self.b = b
        self.nbytes = nbytes
        self.ticket = ticket
        self.t_submit = t_submit


class _PatternState:
    """One registered pattern: its plan, queue, scheduler state, and
    metric instruments."""

    def __init__(
        self, token: str, plan: SpGEMMPlan, reg: MetricsRegistry,
        depth: int = 2,
    ):
        self.token = token
        self.plan = plan
        self.depth = depth  # pipeline depth for this pattern (tuned or
        # the gateway default), resolved once at registration
        self.queue: deque = deque()  # admitted, not yet dispatched
        self.pending_bytes = 0  # queued + dispatched-not-resolved
        self.deficit = 0.0  # DRR byte credit
        self.pipeline: Optional[SpGEMMPipeline] = None
        self.last_active = 0.0  # pool-eviction LRU key
        self.first_admit: Optional[float] = None
        p = f"gateway.{token}"
        self.m_submitted = reg.counter(f"{p}.submitted")
        self.m_completed = reg.counter(f"{p}.completed")
        self.m_failed = reg.counter(f"{p}.failed")
        self.m_dispatches = reg.counter(f"{p}.dispatches")
        self.m_batched = reg.counter(f"{p}.batched_requests")
        self.m_queue_depth = reg.gauge(f"{p}.queue_depth")
        self.m_pending_bytes = reg.gauge(f"{p}.pending_bytes")
        self.m_latency = reg.summary(f"{p}.latency_s")
        self._reg = reg
        self._shed: Dict[str, object] = {}

    def shed_counter(self, outcome: Outcome):
        c = self._shed.get(outcome.value)
        if c is None:
            c = self._reg.counter(f"gateway.{self.token}.{outcome.value}")
            self._shed[outcome.value] = c
        return c


# Dispatcher poll when ripe work is blocked on pipeline slots (the
# collector's notify usually wakes it sooner).
_BLOCKED_POLL_S = 0.005


class SpGEMMGateway:
    """Serving front end over many concurrently-hammered sparsity
    patterns. See the module docstring for the design; typical use::

        gw = SpGEMMGateway(max_pipelines=4, depth=2, max_batch=8,
                           max_inflight_bytes=64 << 20)
        gw.register("tenant0/layer3", a_coo, b_coo, tile=16, group=2)
        t = gw.submit("tenant0/layer3", a_vals, b_vals)
        res = t.wait()            # typed GatewayResult
        if res.outcome is Outcome.OK:
            consume(res.value)    # CSR, bitwise == plan.execute(a, b)
        gw.close()                # drains by default

    Constructor parameters:

    * ``cache`` — the :class:`PlanCache` plans resolve through (default:
      the process cache). Its byte budget is an admission signal:
      ``cache.over_budget`` sheds ``SHED_CACHE_PRESSURE``.
    * ``max_pipelines`` — bound on live pipelines (device-buffer pool);
      ``depth`` — in-flight batches per pipeline (2 = the paper's double
      buffer).
    * ``max_batch`` / ``batch_window`` — micro-batch bounds: dispatch
      when ``max_batch`` same-pattern requests are queued or the oldest
      has waited ``batch_window`` seconds.
    * ``max_queue`` — per-pattern admitted-queue bound
      (``SHED_QUEUE_FULL`` past it); ``max_inflight_bytes`` — total
      value bytes admitted and not yet resolved (``SHED_BYTES`` past it;
      ``None`` = unbounded).
    * ``quantum_bytes`` — DRR byte quantum per pattern per round
      (default: sized so every pattern can dispatch one full batch per
      round).
    * ``metrics`` — a shared :class:`MetricsRegistry` (e.g. one also
      carried by a :class:`~repro.runtime.heartbeat.Heartbeat`).
    * ``start=False`` defers the scheduler threads until :meth:`start`
      — submissions queue (and shed rules apply) but nothing dispatches.
    """

    def __init__(
        self,
        *,
        cache: Optional[PlanCache] = None,
        metrics: Optional[MetricsRegistry] = None,
        max_pipelines: int = 4,
        depth: int = 2,
        max_batch: int = 8,
        batch_window: float = 0.002,
        max_queue: int = 256,
        max_inflight_bytes: Optional[int] = None,
        quantum_bytes: Optional[int] = None,
        start: bool = True,
    ):
        if max_pipelines < 1:
            raise ValueError(f"max_pipelines must be >= 1, got {max_pipelines}")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if batch_window < 0:
            raise ValueError(f"batch_window must be >= 0, got {batch_window}")
        self.cache = cache if cache is not None else default_cache()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.max_pipelines = int(max_pipelines)
        self.depth = int(depth)
        self.max_batch = int(max_batch)
        self.batch_window = float(batch_window)
        self.max_queue = int(max_queue)
        self.max_inflight_bytes = max_inflight_bytes
        self.quantum_bytes = quantum_bytes
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._states: "OrderedDict[str, _PatternState]" = OrderedDict()
        self._collectq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._inflight_bytes = 0  # admitted and not yet resolved
        self._pipelines_live = 0
        self._pipeline_evictions = 0
        self._seq = 0  # completion sequence (fairness observability)
        self._rr = 0  # round-robin rotation
        self._draining = False
        self._closed = False
        self._started = False
        self._t0 = time.perf_counter()
        self._dispatcher: Optional[threading.Thread] = None
        self._collector: Optional[threading.Thread] = None
        self.m_inflight_bytes = self.metrics.gauge("gateway.inflight_bytes")
        self.m_pipelines_live = self.metrics.gauge("gateway.pipelines_live")
        if start:
            self.start()

    # -- control plane -----------------------------------------------------

    def register(
        self,
        pattern_token: str,
        a,
        b,
        *,
        tile=64,
        group: int = 4,
        backend: str = "auto",
        mesh=None,
        mesh_axis=None,
        autotune=None,
    ) -> SpGEMMPlan:
        """Resolve (build or fetch) the plan for one pattern and open it
        for ``submit``. All symbolic work happens here, once; warm
        re-registrations hit the ``pattern_token`` fast key and pay
        neither ``to_coo`` nor the pattern digest.

        ``autotune=True`` (or a dict of
        :func:`repro.spgemm.autotune.autotune_plan` overrides) applies
        the per-pattern tuned config — searched once, persisted with the
        plan artifacts, loaded probe-free on a warm restart. A tuned
        pipeline depth overrides the gateway's default ``depth`` for
        this pattern only; ``stats()`` reports the provenance."""
        plan = spgemm_plan(
            a, b, tile=tile, group=group, backend=backend, cache=self.cache,
            mesh=mesh, mesh_axis=mesh_axis, pattern_token=pattern_token,
            autotune=autotune,
        )
        return self.register_plan(pattern_token, plan)

    def register_plan(self, pattern_token: str, plan: SpGEMMPlan) -> SpGEMMPlan:
        """Open an already-built plan for ``submit`` under ``pattern_token``
        (the seam for sharded/externally-cached plans)."""
        token = str(pattern_token)
        with self._lock:
            if self._closed:
                raise RuntimeError("gateway is closed")
            state = self._states.get(token)
            if state is not None:
                if state.plan is not plan:
                    raise ValueError(
                        f"pattern_token {token!r} is already registered "
                        f"with a different plan"
                    )
                return plan
            # Pipeline depth: the plan's tuned depth when an autotuner
            # config is applied, else the gateway default.
            depth = (
                plan._default_depth()
                if getattr(plan, "tuned_config", None) is not None
                else self.depth
            )
            self._states[token] = _PatternState(
                token, plan, self.metrics, depth=depth
            )
        return plan

    def patterns(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._states)

    def start(self) -> None:
        """Start the dispatcher/collector threads (idempotent)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("gateway is closed")
            if self._started:
                return
            self._started = True
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="spgemm-gateway-dispatch",
            daemon=True,
        )
        self._collector = threading.Thread(
            target=self._collect_loop, name="spgemm-gateway-collect",
            daemon=True,
        )
        self._dispatcher.start()
        self._collector.start()

    # -- data plane --------------------------------------------------------

    def submit(self, pattern_token: str, a_vals, b_vals) -> GatewayTicket:
        """Admit one request for a registered pattern.

        Always returns a ticket: admission failures resolve it
        *immediately* with a typed shed outcome (``ticket.done()`` is
        already True) — overload is data, not an exception. Programming
        errors still raise here: an unregistered token is ``KeyError``,
        operand shapes not matching ``plan.value_shapes()`` are
        ``ValueError``.
        """
        token = str(pattern_token)
        with self._lock:
            state = self._states.get(token)
        if state is None:
            raise KeyError(
                f"pattern_token {token!r} is not registered; call "
                f"register(token, a, b) first"
            )
        want_a, want_b = state.plan.value_shapes()
        a = np.asarray(a_vals)
        b = np.asarray(b_vals)
        if a.shape != want_a or b.shape != want_b:
            raise ValueError(
                f"pattern {token!r}: expected a_vals {want_a} / b_vals "
                f"{want_b} (one request per submit), got {a.shape} / "
                f"{b.shape}"
            )
        nbytes = a.nbytes + b.nbytes
        ticket = GatewayTicket(token)
        now = time.perf_counter()
        with self._cond:
            outcome = None
            if self._closed or self._draining:
                outcome = Outcome.SHED_CLOSED
            elif len(state.queue) >= self.max_queue:
                outcome = Outcome.SHED_QUEUE_FULL
            elif (
                self.max_inflight_bytes is not None
                and self._inflight_bytes + nbytes > self.max_inflight_bytes
            ):
                outcome = Outcome.SHED_BYTES
            elif self.cache.over_budget:
                outcome = Outcome.SHED_CACHE_PRESSURE
            if outcome is not None:
                state.shed_counter(outcome).inc()
                ticket._resolve(GatewayResult(outcome, token))
                return ticket
            state.queue.append(_Request(a, b, nbytes, ticket, now))
            state.pending_bytes += nbytes
            self._inflight_bytes += nbytes
            if state.first_admit is None:
                state.first_admit = now
            state.m_submitted.inc()
            state.m_queue_depth.set(len(state.queue))
            state.m_pending_bytes.set(state.pending_bytes)
            self.m_inflight_bytes.set(self._inflight_bytes)
            self._cond.notify_all()
        return ticket

    # -- scheduler (dispatcher thread) -------------------------------------

    def _ripe_locked(self, state: _PatternState, now: float) -> bool:
        if not state.queue:
            return False
        if self._draining or len(state.queue) >= self.max_batch:
            return True
        return (now - state.queue[0].t_submit) >= self.batch_window

    def _wait_time_locked(self, now: float) -> Optional[float]:
        """Seconds until the next pattern ripens: 0.0 when one is ripe
        now, ``None`` when every queue is empty (sleep until notified)."""
        soonest = None
        for state in self._states.values():
            if not state.queue:
                continue
            if self._ripe_locked(state, now):
                return 0.0
            w = self.batch_window - (now - state.queue[0].t_submit)
            soonest = w if soonest is None else min(soonest, w)
        return soonest

    def _quantum_locked(self) -> float:
        """DRR byte credit added per ripe pattern per round. Default:
        large enough that every pattern can dispatch one full micro-batch
        per round — so under contention each round moves ~equal bytes per
        pattern, whatever each tenant's backlog is."""
        if self.quantum_bytes is not None:
            return float(self.quantum_bytes)
        head = max(
            (s.queue[0].nbytes for s in self._states.values() if s.queue),
            default=1,
        )
        return float(head * self.max_batch)

    def _acquire_pipeline_locked(self, state: _PatternState, planned, actions):
        """Ensure ``state`` can take one more in-flight batch; returns
        True and records create/evict actions (performed outside the
        lock) if so.

        Eviction honors the pin guard: only pipelines with zero in-flight
        tickets are candidates — a busy pipeline is never torn down, the
        requesting pattern just waits for the collector to free one."""
        if state.pipeline is not None:
            return state.pipeline.free_slots - planned.get(state.token, 0) > 0
        if ("create", state) in actions:  # planned earlier this round
            return planned.get(state.token, 0) < state.depth
        if self._pipelines_live < self.max_pipelines:
            self._pipelines_live += 1
            actions.append(("create", state))
            return True
        # Pool full: evict the least-recently-active idle pipeline,
        # preferring one with no queued work.
        victims = [
            s for s in self._states.values()
            if s.pipeline is not None and s.pipeline.in_flight == 0
            and planned.get(s.token, 0) == 0
        ]
        if not victims:
            return False
        idle = [s for s in victims if not s.queue]
        pool = idle if idle else victims
        victim = min(pool, key=lambda s: s.last_active)
        actions.append(("close", victim.pipeline))
        victim.pipeline = None
        self._pipeline_evictions += 1
        actions.append(("create", state))
        return True

    def _plan_round_locked(self, now: float):
        """One DRR round: pick per-pattern micro-batches (popped from the
        queues) plus the pipeline create/close actions they need."""
        states = list(self._states.values())
        if not states:
            return [], []
        batches = []  # (state, [requests])
        actions = []  # ("create", state) | ("close", pipeline)
        planned: Dict[str, int] = {}  # batches planned per token this round
        quantum = self._quantum_locked()
        n = len(states)
        for i in range(n):
            state = states[(self._rr + i) % n]
            if not state.queue:
                state.deficit = 0.0  # classic DRR: credit dies with backlog
                continue
            if not self._ripe_locked(state, now):
                continue
            if not self._acquire_pipeline_locked(state, planned, actions):
                continue
            state.deficit += quantum
            while state.queue and self._ripe_locked(state, now):
                k = min(len(state.queue), self.max_batch)
                nbytes = sum(state.queue[j].nbytes for j in range(k))
                if nbytes > state.deficit:
                    break  # spend next round's credit, not this one's
                if not self._acquire_pipeline_locked(state, planned, actions):
                    break
                reqs = [state.queue.popleft() for _ in range(k)]
                state.deficit -= nbytes
                planned[state.token] = planned.get(state.token, 0) + 1
                batches.append((state, reqs))
            state.m_queue_depth.set(len(state.queue))
        self._rr = (self._rr + 1) % n
        return batches, actions

    def _run_round(self, batches, actions) -> None:
        """Perform a planned round outside the gateway lock: pool
        mutations, then one pipeline submission per micro-batch (JAX
        async dispatch — nothing here blocks on device work)."""
        for kind, obj in actions:
            if kind == "close":
                obj.close()  # idle by construction: nothing discarded
            else:  # "create"
                obj.pipeline = SpGEMMPipeline(obj.plan, depth=obj.depth)
        now = time.perf_counter()
        for state, reqs in batches:
            state.last_active = now
            a = np.stack([r.a for r in reqs])
            b = np.stack([r.b for r in reqs])
            try:
                ticket = state.pipeline.submit(a, b)
            except Exception as e:
                self._resolve_batch(state, reqs, None, e)
                continue
            state.m_dispatches.inc()
            state.m_batched.inc(len(reqs))
            self._collectq.put((state, state.pipeline, ticket, reqs))
        with self._lock:
            self.m_pipelines_live.set(self._pipelines_live)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    return
                now = time.perf_counter()
                batches, actions = self._plan_round_locked(now)
                if not batches and not actions:
                    w = self._wait_time_locked(time.perf_counter())
                    # w == 0.0: ripe but blocked on pipeline slots — the
                    # collector's notify (or the poll) retries the round.
                    self._cond.wait(
                        timeout=_BLOCKED_POLL_S if w == 0.0 else w
                    )
                    continue
            self._run_round(batches, actions)

    # -- collector thread --------------------------------------------------

    def _resolve_batch(self, state, reqs, outs, error) -> None:
        now = time.perf_counter()
        with self._cond:
            for i, r in enumerate(reqs):
                self._seq += 1
                if error is None:
                    res = GatewayResult(
                        Outcome.OK, state.token, value=outs[i],
                        latency_s=now - r.t_submit, seq=self._seq,
                    )
                    state.m_completed.inc()
                    state.m_latency.record(res.latency_s)
                else:
                    res = GatewayResult(
                        Outcome.FAILED, state.token, error=error,
                        latency_s=now - r.t_submit, seq=self._seq,
                    )
                    state.m_failed.inc()
                state.pending_bytes -= r.nbytes
                self._inflight_bytes -= r.nbytes
                r.ticket._resolve(res)
            state.m_pending_bytes.set(state.pending_bytes)
            self.m_inflight_bytes.set(self._inflight_bytes)
            self._cond.notify_all()  # wakes drain() and a blocked dispatcher

    def _collect_loop(self) -> None:
        while True:
            item = self._collectq.get()
            if item is None:
                return
            state, pipe, ticket, reqs = item
            try:
                outs = pipe.collect(ticket)  # the only blocking D2H
                error = None
            except Exception as e:
                outs, error = None, e
            self._resolve_batch(state, reqs, outs, error)

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Point-in-time snapshot: per-pattern serving metrics plus
        gateway- and cache-level counters (all plain values, JSON-safe
        modulo the cache's path strings)."""
        now = time.perf_counter()
        with self._lock:
            states = list(self._states.values())
            inflight = self._inflight_bytes
            live = self._pipelines_live
            evictions = self._pipeline_evictions
        patterns = {}
        for s in states:
            dispatches = s.m_dispatches.value
            batched = s.m_batched.value
            completed = s.m_completed.value
            elapsed = (now - s.first_admit) if s.first_admit else 0.0
            shed = {k: c.value for k, c in s._shed.items()}
            patterns[s.token] = {
                "queued": len(s.queue),
                "pending_bytes": s.pending_bytes,
                "submitted": s.m_submitted.value,
                "completed": completed,
                "failed": s.m_failed.value,
                "shed": shed,
                "shed_total": sum(shed.values()),
                "dispatches": dispatches,
                "batched_requests": batched,
                "batch_fill": (batched / dispatches) if dispatches else 0.0,
                "throughput_rps": (completed / elapsed) if elapsed > 0 else 0.0,
                "latency_s": s.m_latency.snapshot(),
                # Exec-config provenance: which tier is active ("default",
                # "tuned", "persisted", "env-override") plus the applied
                # TunedConfig record (probe count, measured values/s,
                # model agreement) when the pattern was autotuned.
                "config_source": s.plan.report.config_source,
                "tuned": s.plan.report.tuned,
                "pipeline_depth": s.depth,
            }
        return {
            "patterns": patterns,
            "inflight_bytes": inflight,
            "pipelines_live": live,
            "pipeline_evictions": evictions,
            "cache": self.cache.stats(),
        }

    # -- teardown ----------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every admitted request has resolved. Requires the
        scheduler to be running. Raises ``TimeoutError`` if work is still
        in flight after ``timeout`` seconds."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while self._inflight_bytes > 0 or any(
                s.queue for s in self._states.values()
            ):
                if not self._started:
                    raise RuntimeError(
                        "cannot drain: the gateway scheduler is not running"
                    )
                remaining = (
                    None if deadline is None
                    else deadline - time.perf_counter()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"gateway still has {self._inflight_bytes} bytes in "
                        f"flight after {timeout}s"
                    )
                self._cond.wait(timeout=remaining)

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the gateway. ``drain=True`` (default) finishes all
        admitted work first; ``drain=False`` sheds everything still
        queued (``SHED_CLOSED``) but still resolves already-dispatched
        batches. New submissions shed ``SHED_CLOSED`` from the moment
        close begins. Idempotent."""
        with self._cond:
            if self._closed:
                return
            self._draining = True
            self._cond.notify_all()
        if drain and self._started:
            self.drain(timeout)
        with self._cond:
            self._closed = True
            for state in self._states.values():
                while state.queue:  # drain=False (or never-started) path
                    r = state.queue.popleft()
                    state.pending_bytes -= r.nbytes
                    self._inflight_bytes -= r.nbytes
                    state.shed_counter(Outcome.SHED_CLOSED).inc()
                    r.ticket._resolve(
                        GatewayResult(Outcome.SHED_CLOSED, state.token)
                    )
                state.m_queue_depth.set(0)
            self._cond.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join()
        # Dispatcher is done pushing; the sentinel lands after its last
        # batch, so the collector resolves everything already dispatched
        # before exiting.
        self._collectq.put(None)
        if self._collector is not None:
            self._collector.join()
        with self._lock:
            states = list(self._states.values())
        for state in states:
            if state.pipeline is not None:
                state.pipeline.close()
                state.pipeline = None
        with self._lock:
            self._pipelines_live = 0
            self.m_pipelines_live.set(0)

    def __enter__(self) -> "SpGEMMGateway":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
