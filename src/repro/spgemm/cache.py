"""Two-tier plan cache + sparsity-pattern fingerprinting.

The paper's host program converts inputs "once" (Sec. 4.3); the serving
north-star multiplies one sparsity pattern with fresh values millions of
times. The cache makes that amortization automatic — and, with the disk
tier, *durable*: plans are keyed on ``(pattern hash, tile, group, backend,
mesh key)`` so any caller presenting a pattern-equal input gets the
already-built plan object back, paying only the numeric phase.

Tiers, checked in order:

1. **memory** — a thread-safe LRU of live plan objects (count +
   ``max_bytes`` budgets), exactly the pre-persistence behavior;
2. **disk** (opt-in: ``PlanCache(disk_dir=...)``, or
   ``REPRO_SPGEMM_PLAN_DIR`` for the process-default cache) — the
   value-independent symbolic artifacts in a
   :class:`~repro.spgemm.persist.PlanStore`. A memory miss tries a
   verified disk load (rehydrated through the caller's ``loader``); any
   load failure silently falls back to a fresh symbolic build, and fresh
   builds are written back so the *next* process starts warm.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
import threading
import weakref
from collections import OrderedDict
from typing import Callable, Optional, Tuple

import numpy as np

from repro.spgemm.persist import PLAN_DIR_ENV, PlanStore

__all__ = ["CacheStats", "PlanCache", "default_cache", "pattern_digest"]


def pattern_digest(*arrays: np.ndarray, meta: Tuple = ()) -> str:
    """Stable hex digest of a sparsity pattern (index arrays + shape meta).

    Values are deliberately excluded — two inputs with the same nonzero
    support but different values hash identically, which is exactly the
    plan-reuse contract.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(meta).encode())
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    """Live counters of one :class:`PlanCache`.

    Exposed as the ``PlanCache.stats`` attribute; *calling* it
    (``cache.stats()``) snapshots everything — counters, derived rates,
    and residency — into a plain dict (the form surfaced through
    ``PlanReport.as_dict()`` and the benchmark output).
    """

    hits: int = 0  # memory-tier hits
    misses: int = 0  # memory-tier misses (may still hit disk)
    token_hits: int = 0  # hits served through a pattern-token alias
    # (no to_coo / digest paid; also counted in ``hits``)
    evictions: int = 0
    resident_plans: int = 0  # plans currently held
    resident_bytes: int = 0  # insert-time host_nbytes() of held plans
    # Disk tier (all zero when the tier is disabled).
    disk_hits: int = 0  # memory misses served by a verified disk load
    disk_misses: int = 0  # memory misses with no usable disk entry
    loads: int = 0  # successful plan rehydrations (== disk_hits)
    load_failures: int = 0  # well-formed files the loader rejected
    stores: int = 0  # fresh builds written back to disk
    token_disk_hits: int = 0  # token lookups resolved through the
    # persisted alias index (a restarted worker's token_get hitting disk
    # without ever paying the first COO digest)
    # Tuned-config sidecar records (the autotuner's persistence tier).
    tuned_hits: int = 0  # tuned-config lookups served (memory or disk)
    tuned_misses: int = 0  # lookups with no tuned record anywhere
    tuned_stores: int = 0  # tuned configs written to the disk sidecar
    # Plan-composition lookups (plan_from_structural_pattern): plans
    # keyed off a prior plan's structural output pattern rather than a
    # COO digest. Also counted in hits/misses like any other lookup.
    chain_lookups: int = 0
    # The owning cache's PlanStore (snapshot source only, not a counter).
    store: Optional[PlanStore] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def __call__(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "token_hits": self.token_hits,
            "evictions": self.evictions,
            "resident_plans": self.resident_plans,
            "resident_bytes": self.resident_bytes,
            "lookups": self.lookups,
            "hit_rate": self.hit_rate,
            "disk_hits": self.disk_hits,
            "disk_misses": self.disk_misses,
            "loads": self.loads,
            "load_failures": self.load_failures,
            "stores": self.stores,
            "token_disk_hits": self.token_disk_hits,
            "tuned_hits": self.tuned_hits,
            "tuned_misses": self.tuned_misses,
            "tuned_stores": self.tuned_stores,
            "chain_lookups": self.chain_lookups,
            **(
                {
                    "disk_dir": self.store.root,
                    "disk_files": len(self.store),
                    "disk_bytes": self.store.total_bytes(),
                    "disk_evictions": self.store.evictions,
                }
                if self.store is not None
                else {}
            ),
        }


class PlanCache:
    """Thread-safe LRU cache of built :class:`~repro.spgemm.plan.SpGEMMPlan`.

    Keys are ``(pattern_hash, tile, group, backend, mesh_key)`` tuples
    (``mesh_key`` is ``None`` for single-device plans; sharded plans pin
    the mesh axis, shard count, and device ids — see
    ``repro.spgemm.plan._mesh_key``). ``get_or_build`` returns
    ``(plan, hit)`` so callers can attribute the lookup in their reports;
    ``stats``/``stats()`` expose live counters / a snapshot dict.

    Eviction is LRU under two caps: ``capacity`` (plan count) and, when set,
    ``max_bytes`` — a budget on the host memory the cached plans retain
    (each plan sized once at insert via its ``host_nbytes()``), so
    large-operand one-shot workloads cannot pin unbounded host memory. The
    most recently inserted plan is always kept, even when it alone exceeds
    the byte budget.

    ``disk_dir`` enables the disk tier (see the module docstring): memory
    misses try a verified :class:`~repro.spgemm.persist.PlanStore` load
    before building, fresh builds are written back, and ``disk_max_bytes``
    bounds the directory (oldest-used files evicted after each save).

    Serving extras: ``token_get``/``token_bind`` maintain caller-supplied
    pattern-token aliases (the ``spgemm_plan(..., pattern_token=)`` fast
    path), and ``evict(key)`` drops one plan explicitly. Teardown is
    pipeline-safe — both explicit and LRU eviction refuse (raise / skip)
    plans with in-flight pipeline steps.
    """

    def __init__(
        self,
        capacity: int = 64,
        max_bytes: Optional[int] = None,
        disk_dir: Optional[str] = None,
        disk_max_bytes: Optional[int] = None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 (or None)")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.store = (
            PlanStore(disk_dir, max_bytes=disk_max_bytes)
            if disk_dir else None
        )
        self.stats = CacheStats(store=self.store)
        self._lock = threading.Lock()
        self._plans: OrderedDict = OrderedDict()
        self._sizes: dict = {}
        self._bytes = 0
        # Pattern-token aliases: caller-supplied fast keys -> full plan
        # keys. An alias outlives its plan (a rebuilt plan under the same
        # full key revives it); lookups simply miss while the plan is out.
        self._tokens: dict = {}
        # Tuned-config sidecar records: tuned_key -> TunedConfig meta dict
        # (the memory tier above the PlanStore sidecar entries).
        self._tuned: dict = {}

    @property
    def total_bytes(self) -> int:
        """Bytes currently charged against ``max_bytes`` (insert-time
        sizes; a plan's later ``release_values()`` is not re-measured)."""
        with self._lock:
            return self._bytes

    @property
    def over_budget(self) -> bool:
        """True when resident plan bytes exceed ``max_bytes`` — possible
        because the newest plan is always kept and plans with in-flight
        pipeline steps are pinned against LRU eviction. Always False
        without a byte budget. This is the cache-pressure admission
        signal serving front ends (the gateway) shed on."""
        with self._lock:
            return self.max_bytes is not None and self._bytes > self.max_bytes

    def _plan_size(self, plan) -> int:
        size = getattr(plan, "host_nbytes", None)
        return int(size()) if callable(size) else 0

    def _drop(self, key) -> None:
        """Remove one entry (lock held)."""
        del self._plans[key]
        self._bytes -= self._sizes.pop(key, 0)
        self.stats.evictions += 1
        self._sync_resident()

    def _pop_lru(self) -> bool:
        """Evict the least-recently-used *evictable* plan (lock held).

        Plans with in-flight pipeline steps are skipped — their staged
        device buffers are still being read, so teardown must wait — and
        the most recently inserted plan is never evicted. Returns False
        when nothing is evictable (the caller stops; budgets are
        temporarily exceeded rather than corrupted)."""
        keys = list(self._plans)
        for key in keys[:-1]:  # never the just-inserted (newest) plan
            if getattr(self._plans[key], "in_flight", 0):
                continue
            self._drop(key)
            return True
        return False

    def _sync_resident(self) -> None:
        self.stats.resident_plans = len(self._plans)
        self.stats.resident_bytes = self._bytes

    def get_or_build(
        self,
        key: Tuple,
        builder: Callable,
        loader: Optional[Callable] = None,
    ):
        """Fetch or build the plan for ``key``; returns ``(plan, hit)``.

        ``hit`` is True only for memory-tier hits (the caller rebinds its
        values into the shared live object on that path). ``loader`` is the
        disk-tier rehydrator — ``loader(arrays, meta) -> plan`` — invoked
        on a memory miss when the disk tier holds a verified entry for
        ``key``; if it raises, the entry is treated as unusable and the
        plan is rebuilt from scratch (the store deletes files that fail
        verification itself). Loaded plans carry the caller's values
        already, so they return with ``hit=False``.
        """
        with self._lock:
            if key in self._plans:
                self.stats.hits += 1
                self._plans.move_to_end(key)
                return self._plans[key], True
            self.stats.misses += 1
        # Load / build outside the lock (the symbolic phase can be
        # expensive); a rare duplicate build under contention is benign —
        # last writer wins.
        plan = None
        if self.store is not None and loader is not None:
            payload = self.store.load(key)
            if payload is None:
                with self._lock:
                    self.stats.disk_misses += 1
            else:
                try:
                    plan = loader(*payload)
                    with self._lock:
                        self.stats.disk_hits += 1
                        self.stats.loads += 1
                except Exception:
                    # Verified file, unusable content (e.g. a future plan
                    # kind): fall back to a fresh symbolic build.
                    with self._lock:
                        self.stats.load_failures += 1
                    plan = None
        if plan is None:
            plan = builder()
            if self.store is not None:
                art = getattr(plan, "persist_artifacts", None)
                if callable(art):
                    try:
                        arrays, meta = art()
                        stored = self.store.save(key, arrays, meta)
                        if stored is not None:
                            with self._lock:
                                self.stats.stores += 1
                    except Exception:
                        pass  # persistence is an optimization, never fatal
        self._insert_plan(key, plan)
        return plan, False

    def _insert_plan(self, key: Tuple, plan) -> None:
        """Insert one plan under its full key (LRU + budget bookkeeping)."""
        size = self._plan_size(plan)
        # Back-reference for self-eviction: plan.release() uses this to
        # drop its own (now dead) entry so the key cannot keep serving a
        # released plan. Weak so the cache's lifetime is unaffected.
        try:
            plan._cache_ref = (weakref.ref(self), key)
        except AttributeError:  # pragma: no cover - exotic plan objects
            pass
        with self._lock:
            if key in self._plans:  # lost a build race: replace, re-charge
                self._bytes -= self._sizes.pop(key, 0)
            self._plans[key] = plan
            self._plans.move_to_end(key)
            self._sizes[key] = size
            self._bytes += size
            while len(self._plans) > self.capacity:
                if not self._pop_lru():
                    break
            if self.max_bytes is not None:
                while self._bytes > self.max_bytes and len(self._plans) > 1:
                    if not self._pop_lru():
                        break
            self._sync_resident()

    # -- pattern-token aliases (the serving warm path's fast key) ----------

    def token_get(self, token_key: Tuple):
        """Resolve a pattern-token alias to its live plan, or ``None``.

        A hit skips everything the digest path pays (``to_coo``,
        canonicalization, the pattern digest) — counted in
        ``stats.token_hits`` as well as ``stats.hits``. A miss (unknown
        token, or its plan was evicted) returns ``None`` and the caller
        falls back to the full digest path, which re-binds the alias."""
        with self._lock:
            key = self._tokens.get(token_key)
            if key is None or key not in self._plans:
                return None
            self.stats.hits += 1
            self.stats.token_hits += 1
            self._plans.move_to_end(key)
            return self._plans[key]

    def token_bind(self, token_key: Tuple, key: Tuple) -> None:
        """Bind a pattern token to a full plan key.

        A token is a caller's claim that two inputs share a sparsity
        pattern; binding validates it against the digest whenever both
        are present — re-binding a token to a *different* full key (a
        different pattern digest, tile, group, backend, or mesh) raises
        rather than silently serving the wrong plan.

        With the disk tier enabled, fresh bindings are also persisted in
        the store's token-alias index so a *restarted* worker resolves
        the token straight to a disk load (see :meth:`token_disk_get`)."""
        with self._lock:
            old = self._tokens.get(token_key)
            if old is not None and old != key:
                raise ValueError(
                    f"pattern token {token_key[1]!r} is already bound to a "
                    f"different plan key (pattern digest/config mismatch); "
                    f"tokens must uniquely name one sparsity pattern"
                )
            fresh = old is None
            self._tokens[token_key] = key
        if fresh and self.store is not None:
            self.store.alias_put(repr(token_key), repr(key))

    def token_disk_get(self, token_key: Tuple, loader: Callable):
        """Resolve a pattern-token alias through the store's persisted
        index — the warm-*restart* fast key, where the in-memory token
        map is gone but the alias (and usually the plan) survive on disk.

        Returns ``(plan, fresh)``:

        * ``(plan, True)`` — the aliased full key was rehydrated from
          disk via ``loader(key, arrays, meta)``; the plan already
          carries the caller's values and the alias was re-bound in
          memory. The whole resolution paid **no pattern digest** —
          counted in ``stats.token_disk_hits``.
        * ``(plan, False)`` — the aliased plan was still resident in
          memory under its full key (only the token map was cleared);
          the caller rebinds values exactly as for a ``token_get`` hit.
        * ``(None, False)`` — no disk tier, no alias, an unparseable or
          stale alias, or a failed load; the caller falls back to the
          digest path, which re-binds the alias.

        The alias is a *pointer*, never trusted content: the entry it
        names is still integrity-checked by the store and validated by
        the loader, so a lying or stale index degrades to a digest-path
        build, not a wrong plan.
        """
        if self.store is None:
            return None, False
        rep = self.store.alias_get(repr(token_key))
        if rep is None:
            return None, False
        try:
            key = ast.literal_eval(rep)
        except (ValueError, SyntaxError):
            return None, False
        if not isinstance(key, tuple):
            return None, False
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                # Resident under the full key (e.g. built digest-path
                # before this token was first presented): revive the
                # memory alias and serve as a token hit.
                self._tokens.setdefault(token_key, key)
                self.stats.hits += 1
                self.stats.token_hits += 1
                self.stats.token_disk_hits += 1
                self._plans.move_to_end(key)
                return plan, False
            self.stats.misses += 1
        payload = self.store.load(key)
        if payload is None:
            with self._lock:
                self.stats.disk_misses += 1
            return None, False
        try:
            plan = loader(key, *payload)
        except Exception:
            with self._lock:
                self.stats.load_failures += 1
            return None, False
        with self._lock:
            self.stats.disk_hits += 1
            self.stats.loads += 1
            self.stats.token_hits += 1
            self.stats.token_disk_hits += 1
            self._tokens.setdefault(token_key, key)
        self._insert_plan(key, plan)
        return plan, True

    # -- tuned-config sidecar (the autotuner's persistence tier) -----------

    @staticmethod
    def tuned_key(base_key: Tuple) -> Tuple:
        """The sidecar key for a plan key's tuned config. Namespaced so a
        tuned record can never collide with a plan artifact file."""
        return ("tuned",) + tuple(base_key)

    def tuned_get(self, base_key: Tuple) -> Optional[dict]:
        """The persisted :class:`~repro.spgemm.autotune.TunedConfig` meta
        dict for ``base_key`` (memory first, then the disk sidecar), or
        ``None``. A hit is what lets a warm restart apply the winning
        config with **zero** probe executions."""
        tkey = self.tuned_key(base_key)
        with self._lock:
            meta = self._tuned.get(tkey)
            if meta is not None:
                self.stats.tuned_hits += 1
                return dict(meta)
        if self.store is not None:
            payload = self.store.load(tkey)
            if payload is not None:
                meta = payload[1]
                with self._lock:
                    self._tuned[tkey] = dict(meta)
                    self.stats.tuned_hits += 1
                return dict(meta)
        with self._lock:
            self.stats.tuned_misses += 1
        return None

    def tuned_put(self, base_key: Tuple, meta: dict) -> None:
        """Record the winning config for ``base_key`` (memory + the disk
        sidecar when enabled). The sidecar record rides the same
        versioned/integrity-checked format as plan artifacts — an
        arrays-free entry whose header digest covers the meta dict."""
        tkey = self.tuned_key(base_key)
        with self._lock:
            self._tuned[tkey] = dict(meta)
        if self.store is not None:
            if self.store.save(tkey, {}, dict(meta)) is not None:
                with self._lock:
                    self.stats.tuned_stores += 1

    def evict(self, key: Tuple, only=None) -> bool:
        """Explicitly drop one plan from the memory tier.

        Returns False if the key is not resident. Raises RuntimeError if
        the plan has in-flight pipeline steps — its staged device buffers
        are still being read; collect or close the pipeline first.

        ``only`` pins identity: the entry is dropped only if the resident
        plan *is* that object (``SpGEMMPlan.release`` self-evicts with
        this, so releasing a stale plan whose key was since evicted and
        rebuilt can neither drop nor complain about the new live plan)."""
        with self._lock:
            plan = self._plans.get(key)
            if plan is None or (only is not None and plan is not only):
                return False
            n = getattr(plan, "in_flight", 0)
            if n:
                raise RuntimeError(
                    f"cannot evict plan {key[0]!r}: {n} in-flight pipeline "
                    f"step(s); collect the tickets or close the pipeline "
                    f"first"
                )
            self._drop(key)
            return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def __contains__(self, key: Tuple) -> bool:
        with self._lock:
            return key in self._plans

    def clear(self) -> None:
        """Drop the memory tier (disk entries, if any, are kept — they are
        exactly the state a restart would see)."""
        with self._lock:
            self._plans.clear()
            self._sizes.clear()
            self._tokens.clear()
            self._tuned.clear()
            self._bytes = 0
            self.stats = CacheStats(store=self.store)


_DEFAULT_CACHE: Optional[PlanCache] = None
_DEFAULT_LOCK = threading.Lock()


def default_cache() -> PlanCache:
    """The process-level cache used when no explicit cache is passed.

    Created lazily so ``REPRO_SPGEMM_PLAN_DIR`` (set by the launcher
    before the first plan build) enables the disk tier without any code
    change — the warm-restart path for serving fleets."""
    global _DEFAULT_CACHE
    with _DEFAULT_LOCK:
        if _DEFAULT_CACHE is None:
            _DEFAULT_CACHE = PlanCache(
                disk_dir=os.environ.get(PLAN_DIR_ENV) or None
            )
        return _DEFAULT_CACHE
