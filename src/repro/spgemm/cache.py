"""Process-level plan cache + sparsity-pattern fingerprinting.

The paper's host program converts inputs "once" (Sec. 4.3); the serving
north-star multiplies one sparsity pattern with fresh values millions of
times. The cache makes that amortization automatic: plans are keyed on
``(pattern hash, tile, group, backend)`` so any caller presenting a
pattern-equal input gets the already-built plan object back, paying only
the numeric phase.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Callable, Optional, Tuple

import numpy as np

__all__ = ["CacheStats", "PlanCache", "default_cache", "pattern_digest"]


def pattern_digest(*arrays: np.ndarray, meta: Tuple = ()) -> str:
    """Stable hex digest of a sparsity pattern (index arrays + shape meta).

    Values are deliberately excluded — two inputs with the same nonzero
    support but different values hash identically, which is exactly the
    plan-reuse contract.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(meta).encode())
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    """Live counters of one :class:`PlanCache`.

    Exposed as the ``PlanCache.stats`` attribute; *calling* it
    (``cache.stats()``) snapshots everything — counters, derived rates,
    and residency — into a plain dict (the form surfaced through
    ``PlanReport.as_dict()`` and the benchmark output).
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    resident_plans: int = 0  # plans currently held
    resident_bytes: int = 0  # insert-time host_nbytes() of held plans

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def __call__(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "resident_plans": self.resident_plans,
            "resident_bytes": self.resident_bytes,
            "lookups": self.lookups,
            "hit_rate": self.hit_rate,
        }


class PlanCache:
    """Thread-safe LRU cache of built :class:`~repro.spgemm.plan.SpGEMMPlan`.

    Keys are ``(pattern_hash, tile, group, backend, mesh_key)`` tuples
    (``mesh_key`` is ``None`` for single-device plans; sharded plans pin
    the mesh axis, shard count, and device ids — see
    ``repro.spgemm.plan._mesh_key``). ``get_or_build`` returns
    ``(plan, hit)`` so callers can attribute the lookup in their reports;
    ``stats``/``stats()`` expose live counters / a snapshot dict.

    Eviction is LRU under two caps: ``capacity`` (plan count) and, when set,
    ``max_bytes`` — a budget on the host memory the cached plans retain
    (each plan sized once at insert via its ``host_nbytes()``), so
    large-operand one-shot workloads cannot pin unbounded host memory. The
    most recently inserted plan is always kept, even when it alone exceeds
    the byte budget.
    """

    def __init__(self, capacity: int = 64, max_bytes: Optional[int] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 (or None)")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._plans: OrderedDict = OrderedDict()
        self._sizes: dict = {}
        self._bytes = 0

    @property
    def total_bytes(self) -> int:
        """Bytes currently charged against ``max_bytes`` (insert-time
        sizes; a plan's later ``release_values()`` is not re-measured)."""
        with self._lock:
            return self._bytes

    def _plan_size(self, plan) -> int:
        size = getattr(plan, "host_nbytes", None)
        return int(size()) if callable(size) else 0

    def _pop_lru(self) -> None:
        key, _ = self._plans.popitem(last=False)
        self._bytes -= self._sizes.pop(key, 0)
        self.stats.evictions += 1
        self._sync_resident()

    def _sync_resident(self) -> None:
        self.stats.resident_plans = len(self._plans)
        self.stats.resident_bytes = self._bytes

    def get_or_build(self, key: Tuple, builder: Callable):
        with self._lock:
            if key in self._plans:
                self.stats.hits += 1
                self._plans.move_to_end(key)
                return self._plans[key], True
            self.stats.misses += 1
        # Build outside the lock (symbolic phase can be expensive); a rare
        # duplicate build under contention is benign — last writer wins.
        plan = builder()
        size = self._plan_size(plan)
        with self._lock:
            if key in self._plans:  # lost a build race: replace, re-charge
                self._bytes -= self._sizes.pop(key, 0)
            self._plans[key] = plan
            self._plans.move_to_end(key)
            self._sizes[key] = size
            self._bytes += size
            while len(self._plans) > self.capacity:
                self._pop_lru()
            if self.max_bytes is not None:
                while self._bytes > self.max_bytes and len(self._plans) > 1:
                    self._pop_lru()
            self._sync_resident()
        return plan, False

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def __contains__(self, key: Tuple) -> bool:
        with self._lock:
            return key in self._plans

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._sizes.clear()
            self._bytes = 0
            self.stats = CacheStats()


_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-level cache used when no explicit cache is passed."""
    return _DEFAULT_CACHE
