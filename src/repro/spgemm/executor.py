"""Device-resident SpGEMM numeric executor.

FSpGEMM's throughput claim (PAPER Sec. 4) rests on the numeric phase being a
pure streaming pipeline once host pre-processing is done. This module is
that pipeline as a *functional core*: a pure, jittable function

    (packed A blocks, packed B blocks) -> packed C values

chaining three device-side stages under one ``jax.jit``:

1. **value rebind** (optional, element plans): scatter fresh ``[nnz]`` value
   vectors into the packed block arrays at the plan's precomputed scatter
   indices;
2. **the scheduled kernel**: the Pallas block-Gustavson kernel
   (:func:`repro.kernels.gustavson_spgemm.spgemm_scheduled_impl`) or the
   pure-jnp path (:func:`repro.kernels.ref.spgemm_scheduled_ref`);
3. **output assembly**: one static gather through the symbolic phase's
   :class:`~repro.core.schedule.AssemblyMap` — no data-dependent ``nonzero``,
   no per-panel host loop.

Because every stage is shape-static, the core batches over a leading value
axis (:func:`numeric_core_batch`, the engine behind
``SpGEMMPlan.execute_batch``): semantically ``jax.vmap`` of the core,
lowered by folding the batch into the triple schedule so XLA sees the same
op shapes as the single-set path. The jitted entry points are module-level
with static config arguments, so plans sharing shapes share executables;
:class:`SpGEMMExecutor` wraps them with a plan's device-resident constants
(schedule arrays, scatter indices, gather map — shipped to device once).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import AssemblyMap, SpGEMMSchedule
from repro.kernels import ref
from repro.kernels.gustavson_spgemm import (
    pad_schedule_arrays,
    spgemm_scheduled_impl,
)

__all__ = ["SpGEMMExecutor", "numeric_core", "numeric_core_batch"]

_STATICS = ("n_panels", "group", "backend", "interpret")


def _run_schedule(
    a_blocks, b_blocks, sched, *, n_panels, group, backend, interpret
):
    """Dispatch the scheduled kernel. ``sched`` is the backend's device
    tuple: (a_slot, b_slot, panel, sub_row, start) padded for pallas,
    (a_slot, b_slot, panel, sub_row) raw for jnp."""
    if backend in ("pallas", "pallas_interpret"):
        a_slot, b_slot, panel, sub_row, start = sched
        return spgemm_scheduled_impl(
            a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, start,
            n_panels=n_panels, group=group, interpret=interpret,
        )
    a_slot, b_slot, panel, sub_row = sched
    return ref.spgemm_scheduled_ref(
        a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, n_panels, group
    )


def _invert_scatter(scatter: np.ndarray, size: int) -> np.ndarray:
    """Turn flat scatter indices (``blocks.flat[scatter] = vals``) into a
    gather map (``blocks.flat = vals_padded[inv]``), with index ``nnz``
    pointing at a zero pad slot. XLA lowers gathers far better than
    scatters on CPU, and the inverse is value-independent — computed once
    at executor build."""
    inv = np.full(size, scatter.shape[0], np.int32)
    inv[scatter] = np.arange(scatter.shape[0], dtype=np.int32)
    return inv


def _bind(vals, inv, shape):
    """Device-side value rebind as one gather through the precomputed
    scatter inverse. Positions outside the pattern read the zero pad."""
    pad = jnp.concatenate([vals, jnp.zeros(1, vals.dtype)])
    return pad[inv].reshape(shape)


@functools.partial(jax.jit, static_argnames=_STATICS)
def numeric_core(
    a_blocks, b_blocks, sched, gather, *, n_panels, group, backend, interpret
):
    """Functional numeric phase: packed blocks -> packed C values."""
    panels = _run_schedule(
        a_blocks, b_blocks, sched,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )
    return panels.reshape(-1)[gather]


@functools.partial(
    jax.jit, static_argnames=_STATICS + ("a_shape", "b_shape")
)
def numeric_core_values(
    a_vals, b_vals, a_inv, b_inv, sched, gather, *,
    a_shape, b_shape, n_panels, group, backend, interpret,
):
    """Numeric phase from [nnz] value vectors: rebind + kernel + assembly."""
    a_blocks = _bind(a_vals, a_inv, a_shape)
    b_blocks = _bind(b_vals, b_inv, b_shape)
    return numeric_core(
        a_blocks, b_blocks, sched, gather,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )


def _bind_batch(vals, inv, shape):
    """Batched value rebind: one gather per batch row through the shared
    scatter inverse, stacked along the slot axis."""
    bsz = vals.shape[0]
    pad = jnp.concatenate([vals, jnp.zeros((bsz, 1), vals.dtype)], axis=1)
    return pad[:, inv].reshape((bsz * shape[0],) + tuple(shape[1:]))


@functools.partial(
    jax.jit,
    static_argnames=("a_shape", "b_shape", "rebind", "n_panels", "group"),
)
def numeric_core_batch(
    a_vals, b_vals, a_inv, b_inv, sched, gather, *,
    a_shape, b_shape, rebind, n_panels, group,
):
    """Batched numeric phase over a leading value axis.

    Semantically ``jax.vmap`` of the functional core, lowered by *folding
    the batch into the triple schedule*: the packed operands of all batch
    elements are stacked along the slot axis and the slot/panel indices are
    offset per element, so the batch executes as one ``batch * T``-triple
    schedule over ``batch * n_panels`` panels. This keeps every op shape
    identical to the single-set jnp path (one long sorted scatter instead
    of a batched scatter, which XLA lowers poorly on CPU) and preserves
    each element's accumulation order exactly — batch results are bitwise
    equal to single jnp executes.

    ``rebind=True`` takes [batch, nnz] value vectors (element plans);
    ``rebind=False`` takes batched packed block arrays (block plans).
    """
    bsz = a_vals.shape[0]
    if rebind:
        a_blocks = _bind_batch(a_vals, a_inv, a_shape)
        b_blocks = _bind_batch(b_vals, b_inv, b_shape)
    else:
        a_blocks = a_vals.reshape((bsz * a_shape[0],) + tuple(a_shape[1:]))
        b_blocks = b_vals.reshape((bsz * b_shape[0],) + tuple(b_shape[1:]))
    a_slot, b_slot, panel, sub_row = sched
    off = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    a_slot_b = (off * a_shape[0] + a_slot[None, :]).reshape(-1)
    b_slot_b = (off * b_shape[0] + b_slot[None, :]).reshape(-1)
    panel_b = (off * n_panels + panel[None, :]).reshape(-1)
    sub_row_b = jnp.tile(sub_row, bsz)
    panels = ref.spgemm_scheduled_ref(
        a_blocks, b_blocks, a_slot_b, b_slot_b, panel_b, sub_row_b,
        bsz * n_panels, group,
    )
    return panels.reshape(bsz, -1)[:, gather]


class SpGEMMExecutor:
    """A plan's numeric phase with device-resident constants.

    Stages the triple schedule, the scatter indices, and the assembly gather
    map on device once; ``run``/``run_values``/``run_batch`` then call the
    module-level jitted cores (shared executables across same-shaped plans)
    with zero per-call host work beyond operand transfer.

    ``run_batch`` always executes on the jnp (pure-XLA) kernel path: the
    Pallas scalar-prefetch grid has no batching rule, and XLA batches the
    einsum/scatter pipeline natively. Single-shot ``run``/``run_values``
    honor the plan's backend.
    """

    def __init__(
        self,
        *,
        schedule: SpGEMMSchedule,
        assembly: AssemblyMap,
        backend: str,
        a_scatter: Optional[np.ndarray] = None,
        b_scatter: Optional[np.ndarray] = None,
        a_shape: Tuple[int, ...] = (),
        b_shape: Tuple[int, ...] = (),
    ):
        self.backend = backend
        self.n_panels = schedule.n_panels
        self.group = schedule.group
        self.a_shape = tuple(a_shape)
        self.b_shape = tuple(b_shape)
        self._interpret = (
            backend == "pallas_interpret" or jax.default_backend() != "tpu"
        )
        # Per-set f32 rows the batched schedule touches (panel accumulator
        # + einsum products) — the working-set basis for batch_chunk().
        bm = a_shape[1] if len(a_shape) == 3 else 0
        self._bn = b_shape[2] if len(b_shape) == 3 else 0
        self._per_set_rows = (
            schedule.n_panels * schedule.group + schedule.num_triples
        ) * bm
        self._gather = jnp.asarray(assembly.gather)
        # The jnp schedule tuple is kept for every backend: it is the batch
        # path's kernel even on pallas plans.
        self._sched_jnp = tuple(
            jnp.asarray(x) for x in (
                schedule.a_slot, schedule.b_slot, schedule.panel,
                schedule.sub_row,
            )
        )
        if backend in ("pallas", "pallas_interpret"):
            a_slot, b_slot, panel, sub_row, start, _ = pad_schedule_arrays(
                schedule.a_slot, schedule.b_slot, schedule.panel,
                schedule.sub_row, schedule.start, schedule.n_panels,
            )
            self._sched = tuple(
                jnp.asarray(x) for x in (a_slot, b_slot, panel, sub_row, start)
            )
        else:
            self._sched = self._sched_jnp
        # Rebind maps: scatter indices inverted to gather form at build.
        self._a_inv = (
            jnp.asarray(_invert_scatter(a_scatter, int(np.prod(a_shape))))
            if a_scatter is not None else None
        )
        self._b_inv = (
            jnp.asarray(_invert_scatter(b_scatter, int(np.prod(b_shape))))
            if b_scatter is not None else None
        )

    @property
    def can_rebind(self) -> bool:
        return self._a_inv is not None and self._b_inv is not None

    def batch_chunk(
        self,
        small_set_bytes: int = (5 << 20) // 4,
        cache_bytes: int = 8 << 20,
    ) -> int:
        """Max batch elements per fused device call (empirical CPU policy).

        Fusing pays only when one set's working bytes (panel accumulator +
        einsum intermediates, ``4 * per_set_rows * bn``) are small: chunks
        sized to keep ``chunk * per_set`` under ``cache_bytes`` then cut
        per-set cost 1.3-1.7x by amortizing dispatch. Above
        ``small_set_bytes`` per set, measured mid-size chunks *regress*
        (the fused scatter's accumulator leaves cache, 2-3x per-set), so
        larger problems run one set per call — matching a single
        ``execute()`` minus its host rebind/staging work. Revisit for TPU:
        the knee is a host-cache property (see ROADMAP).
        """
        per_set = 4 * self._per_set_rows * self._bn
        if per_set <= small_set_bytes:
            return max(1, cache_bytes // max(per_set, 1))
        return 1

    def run(self, a_blocks, b_blocks) -> jax.Array:
        """Packed blocks -> packed C values (plan's backend)."""
        return numeric_core(
            a_blocks, b_blocks, self._sched, self._gather,
            n_panels=self.n_panels, group=self.group, backend=self.backend,
            interpret=self._interpret,
        )

    def run_values(self, a_vals, b_vals) -> jax.Array:
        """[nnz] value vectors -> packed C values, rebind included."""
        return numeric_core_values(
            a_vals, b_vals, self._a_inv, self._b_inv,
            self._sched, self._gather,
            a_shape=self.a_shape, b_shape=self.b_shape,
            n_panels=self.n_panels, group=self.group, backend=self.backend,
            interpret=self._interpret,
        )

    def run_batch(self, a_vals, b_vals, *, rebind: bool) -> jax.Array:
        """Batched values -> packed C values [batch, nnz_c] (jnp path)."""
        return numeric_core_batch(
            a_vals, b_vals, self._a_inv, self._b_inv,
            self._sched_jnp, self._gather,
            a_shape=self.a_shape, b_shape=self.b_shape, rebind=rebind,
            n_panels=self.n_panels, group=self.group,
        )
