"""Device-resident SpGEMM numeric executor.

FSpGEMM's throughput claim (PAPER Sec. 4) rests on the numeric phase being a
pure streaming pipeline once host pre-processing is done. This module is
that pipeline as a *functional core*: a pure, jittable function

    (packed A blocks, packed B blocks) -> packed C values

chaining three device-side stages under one ``jax.jit``:

1. **value rebind** (optional, element plans): scatter fresh ``[nnz]`` value
   vectors into the packed block arrays at the plan's precomputed scatter
   indices;
2. **the scheduled kernel**: the Pallas block-Gustavson kernel
   (:func:`repro.kernels.gustavson_spgemm.spgemm_scheduled_impl`) or the
   pure-jnp path (:func:`repro.kernels.ref.spgemm_scheduled_ref`);
3. **output assembly**: one static gather through the symbolic phase's
   :class:`~repro.core.schedule.AssemblyMap` — no data-dependent ``nonzero``,
   no per-panel host loop.

Because every stage is shape-static, the core batches over a leading value
axis (:func:`numeric_core_batch`, the engine behind
``SpGEMMPlan.execute_batch``): semantically ``jax.vmap`` of the core,
lowered by folding the batch into the triple schedule — on pallas backends
the batch becomes the leading dimension of one scalar-prefetch Pallas grid
(:func:`~repro.kernels.gustavson_spgemm.spgemm_scheduled_batch_impl`), on
jnp an offset-folded schedule so XLA sees the same op shapes as the
single-set path. The jitted entry points are module-level with static
config arguments, so plans sharing shapes share executables;
:class:`SpGEMMExecutor` wraps them with a plan's device-resident constants
(schedule arrays, scatter indices, gather map — shipped to device once).

The same shape-static property is what makes the phase meshable:
:class:`ShardedSpGEMMExecutor` (the numeric phase of
``repro.spgemm.plan.ShardedSpGEMMPlan``) stacks per-shard padded copies of
those constants along a leading shard axis, lays them out over one mesh
axis, and runs all three stages under a single ``shard_map`` — A
row-sharded, B replicated, C row-sharded and concatenated on host.

**Stage-split pipeline surface.** Next to the fused cores, each stage is
also exposed as its own module-level jit (``bind_core`` /
``kernel_core`` / ``assemble_core`` plus batched variants) and both
executors carry a four-step pipeline protocol over them::

    staged = ex.pipe_stage(a, b, mode=...)   # H2D + value rebind dispatch
    panels = ex.pipe_kernel(staged, mode)    # scheduled kernel dispatch
    packed = ex.pipe_assemble(panels, mode)  # output-assembly gather
    out    = ex.pipe_collect(packed, mode)   # the ONLY blocking call (D2H)

Every step but ``pipe_collect`` merely *dispatches* device work (JAX
async dispatch returns immediately), so a driver that stages step
``s + 1`` before collecting step ``s`` overlaps ``s + 1``'s H2D copy and
rebind with ``s``'s kernel — the paper's double-buffered operand fetch,
expressed functionally: each in-flight step owns its own staged packed
A/B block arrays on device (per shard on the sharded executor), so a
pipeline of depth *d* is a *d*-deep operand buffer ring.
:class:`repro.spgemm.pipeline.SpGEMMPipeline` is that driver. The split
stages run exactly the ops of the fused cores (shared helper functions,
same schedules), so pipelined results are bitwise-equal to the
synchronous path on both kernel backends.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.schedule import (
    AssemblyMap,
    ScheduleShard,
    SpGEMMSchedule,
    stack_shard_schedules,
)
from repro.kernels import ref
from repro.kernels.gustavson_spgemm import (
    compact_csr_indptr_impl,
    pad_schedule_arrays,
    spgemm_scheduled_batch_impl,
    spgemm_scheduled_impl,
)
from repro.launch.sharding import (
    leading_sharding,
    replicated_sharding,
    shard_map,
)

__all__ = [
    "CHUNK_BYTES_ENV",
    "ShardedSpGEMMExecutor",
    "SpGEMMExecutor",
    "assemble_batch_core",
    "assemble_core",
    "bind_batch_core",
    "bind_core",
    "kernel_batch_core",
    "kernel_core",
    "numeric_core",
    "numeric_core_batch",
    "resolve_chunk_bytes",
]

# Per-backend working-set budget for fusing batch elements into one device
# call: (per_set_budget_bytes, target_cache_bytes). The per-set budget is
# the knee where a fused chunk's accumulator working set leaves the fast
# memory tier; the calibration probe (repro.core.tuning.measure_chunk_knee,
# runnable as `python -m benchmarks.bench_chunk_knee` or the "Chunk-fusion
# knee calibration" bench section) is the measurement path for every row,
# and the env knob overrides any row without a code change.
#
# * cpu — measured by the probe on the CI-class container (2026-08, jnp
#   plans, batch 8): fused run_batch wins x1.1-2.0 per set up to
#   ~0.58 MiB/set and regresses from ~1.1 MiB/set (x0.86, collapsing to
#   x0.5 by 4 MiB), so the budget splits that bracket at 0.75 MiB; the
#   chunk sweep improved monotonically through chunk=8, keeping the 8 MiB
#   L3-class chunk cap.
# * tpu — probe methodology applied to the VMEM hierarchy pending an
#   on-device run: the batch-folded Pallas grid holds one (G*bm, bn) panel
#   + A/B tiles in VMEM per step regardless of batch, so the knee tracks a
#   set's panel-array footprint vs. usable VMEM
#   (repro.core.tuning.TPU_V5E.vmem_bytes = 16 MiB), HBM-side chunk cap 4x.
# * gpu — same methodology against an A100-class 40 MiB L2: budget L2/8,
#   chunk cap the full L2.
CHUNK_BYTES_ENV = "REPRO_SPGEMM_CHUNK_BYTES"
_CHUNK_POLICY = {
    "cpu": ((3 << 20) // 4, 8 << 20),
    "tpu": (16 << 20, 64 << 20),
    "gpu": (5 << 20, 40 << 20),
}


def resolve_chunk_bytes(chunk_bytes: Optional[int] = None) -> Tuple[int, int]:
    """Resolve the batch-fusion working-set budget.

    Precedence: ``REPRO_SPGEMM_CHUNK_BYTES`` env var > explicit
    ``chunk_bytes`` (constructor arg) > the per-backend default table.
    Returns ``(per_set_budget, cache_bytes)``; the cache target scales with
    an overridden budget so chunk sizing keeps its shape.
    """
    backend = jax.default_backend()
    default_set, default_cache = _CHUNK_POLICY.get(
        backend, _CHUNK_POLICY["cpu"]
    )
    env = os.environ.get(CHUNK_BYTES_ENV)
    if env is not None:
        per_set = int(env)
    elif chunk_bytes is not None:
        per_set = int(chunk_bytes)
    else:
        return default_set, default_cache
    if per_set < 1:
        raise ValueError(f"chunk bytes must be >= 1, got {per_set}")
    scale = per_set / max(default_set, 1)
    return per_set, max(per_set, int(default_cache * scale))

_STATICS = ("n_panels", "group", "backend", "interpret")


def _run_schedule(
    a_blocks, b_blocks, sched, *, n_panels, group, backend, interpret
):
    """Dispatch the scheduled kernel. ``sched`` is the backend's device
    tuple: (a_slot, b_slot, panel, sub_row, start) padded for pallas,
    (a_slot, b_slot, panel, sub_row) raw for jnp."""
    if backend in ("pallas", "pallas_interpret"):
        a_slot, b_slot, panel, sub_row, start = sched
        return spgemm_scheduled_impl(
            a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, start,
            n_panels=n_panels, group=group, interpret=interpret,
        )
    a_slot, b_slot, panel, sub_row = sched
    return ref.spgemm_scheduled_ref(
        a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, n_panels, group
    )


def _invert_scatter(scatter: np.ndarray, size: int) -> np.ndarray:
    """Turn flat scatter indices (``blocks.flat[scatter] = vals``) into a
    gather map (``blocks.flat = vals_padded[inv]``), with index ``nnz``
    pointing at a zero pad slot. XLA lowers gathers far better than
    scatters on CPU, and the inverse is value-independent — computed once
    at executor build."""
    inv = np.full(size, scatter.shape[0], np.int32)
    inv[scatter] = np.arange(scatter.shape[0], dtype=np.int32)
    return inv


def _bind(vals, inv, shape):
    """Device-side value rebind as one gather through the precomputed
    scatter inverse. Positions outside the pattern read the zero pad."""
    pad = jnp.concatenate([vals, jnp.zeros(1, vals.dtype)])
    return pad[inv].reshape(shape)


@functools.partial(jax.jit, static_argnames=_STATICS)
def numeric_core(
    a_blocks, b_blocks, sched, gather, *, n_panels, group, backend, interpret
):
    """Functional numeric phase: packed blocks -> packed C values."""
    panels = _run_schedule(
        a_blocks, b_blocks, sched,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )
    return panels.reshape(-1)[gather]


@functools.partial(
    jax.jit, static_argnames=_STATICS + ("a_shape", "b_shape")
)
def numeric_core_values(
    a_vals, b_vals, a_inv, b_inv, sched, gather, *,
    a_shape, b_shape, n_panels, group, backend, interpret,
):
    """Numeric phase from [nnz] value vectors: rebind + kernel + assembly."""
    a_blocks = _bind(a_vals, a_inv, a_shape)
    b_blocks = _bind(b_vals, b_inv, b_shape)
    return numeric_core(
        a_blocks, b_blocks, sched, gather,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )


def _bind_batch(vals, inv, shape):
    """Batched value rebind: one gather per batch row through the shared
    scatter inverse, stacked along the slot axis."""
    bsz = vals.shape[0]
    pad = jnp.concatenate([vals, jnp.zeros((bsz, 1), vals.dtype)], axis=1)
    return pad[:, inv].reshape((bsz * shape[0],) + tuple(shape[1:]))


def _fold_schedule(sched, bsz, a_slots, b_slots, n_panels):
    """Fold a value batch into the triple schedule (jnp path): slot/panel
    indices of all batch elements offset per element, so the batch executes
    as one ``batch * T``-triple schedule over ``batch * n_panels`` panels
    while preserving each element's accumulation order exactly."""
    a_slot, b_slot, panel, sub_row = sched
    off = jnp.arange(bsz, dtype=jnp.int32)[:, None]
    return (
        (off * a_slots + a_slot[None, :]).reshape(-1),
        (off * b_slots + b_slot[None, :]).reshape(-1),
        (off * n_panels + panel[None, :]).reshape(-1),
        jnp.tile(sub_row, bsz),
    )


def _run_schedule_batch(
    a_blocks, b_blocks, sched, bsz, a_slots, b_slots,
    *, n_panels, group, backend, interpret,
):
    """Dispatch the batch-folded scheduled kernel over stacked blocks
    (``[bsz * slots, ...]``). On ``pallas``/``pallas_interpret`` the fold
    is the grid itself (:func:`spgemm_scheduled_batch_impl`, grid
    ``(bsz, t_pad)`` over the padded schedule); on ``jnp`` it is the
    offset-folded schedule through the scatter-add reference. Both return
    panels ``[bsz * n_panels, group*bm, bn]`` with identical per-element
    accumulation order."""
    if backend in ("pallas", "pallas_interpret"):
        a_slot, b_slot, panel, sub_row, start = sched
        panels = spgemm_scheduled_batch_impl(
            a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, start,
            bsz=bsz, n_panels=n_panels, group=group, interpret=interpret,
        )
        return panels.reshape((bsz * n_panels,) + panels.shape[2:])
    a_slot_b, b_slot_b, panel_b, sub_row_b = _fold_schedule(
        sched, bsz, a_slots, b_slots, n_panels
    )
    return ref.spgemm_scheduled_ref(
        a_blocks, b_blocks, a_slot_b, b_slot_b, panel_b, sub_row_b,
        bsz * n_panels, group,
    )


@functools.partial(
    jax.jit,
    static_argnames=("a_shape", "b_shape", "rebind") + _STATICS,
)
def numeric_core_batch(
    a_vals, b_vals, a_inv, b_inv, sched, gather, *,
    a_shape, b_shape, rebind, n_panels, group, backend, interpret,
):
    """Batched numeric phase over a leading value axis.

    Semantically ``jax.vmap`` of the functional core, lowered by *folding
    the batch into the triple schedule* (:func:`_run_schedule_batch`): on
    pallas backends the batch becomes the leading grid dimension of one
    scalar-prefetch Pallas call; on jnp the schedule indices are offset per
    element into one long sorted scatter (which XLA lowers far better than
    a batched scatter on CPU). Both preserve each element's accumulation
    order exactly — batch results are bitwise equal to single executes on
    the same backend.

    ``rebind=True`` takes [batch, nnz] value vectors (element plans);
    ``rebind=False`` takes batched packed block arrays (block plans).
    """
    bsz = a_vals.shape[0]
    if rebind:
        a_blocks = _bind_batch(a_vals, a_inv, a_shape)
        b_blocks = _bind_batch(b_vals, b_inv, b_shape)
    else:
        a_blocks = a_vals.reshape((bsz * a_shape[0],) + tuple(a_shape[1:]))
        b_blocks = b_vals.reshape((bsz * b_shape[0],) + tuple(b_shape[1:]))
    panels = _run_schedule_batch(
        a_blocks, b_blocks, sched, bsz, a_shape[0], b_shape[0],
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )
    return panels.reshape(bsz, -1)[:, gather]


# -- stage-split cores (the pipeline protocol's jits) ----------------------
#
# Module-level like the fused cores, so same-shaped plans share the stage
# executables too. Each stage runs exactly the ops its slice of the fused
# core runs (shared helpers, same schedule arrays), which is what keeps
# pipelined results bitwise-equal to synchronous executes.


@functools.partial(jax.jit, static_argnames=("shape",))
def bind_core(vals, inv, *, shape):
    """Stage 1 (element plans): [nnz] values -> packed blocks on device."""
    return _bind(vals, inv, shape)


@functools.partial(jax.jit, static_argnames=("shape",))
def bind_batch_core(vals, inv, *, shape):
    """Stage 1, batched: [batch, nnz] values -> stacked packed blocks."""
    return _bind_batch(vals, inv, shape)


@functools.partial(jax.jit, static_argnames=_STATICS)
def kernel_core(
    a_blocks, b_blocks, sched, *, n_panels, group, backend, interpret
):
    """Stage 2: packed blocks -> output panels (the scheduled kernel)."""
    return _run_schedule(
        a_blocks, b_blocks, sched,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("a_slots", "b_slots") + _STATICS,
)
def kernel_batch_core(
    a_blocks, b_blocks, sched, *, a_slots, b_slots, n_panels, group,
    backend, interpret,
):
    """Stage 2, batched: the batch-folded scheduled kernel over stacked
    blocks (``[batch * slots, ...]``, as produced by stage 1) — the
    plan-backend dispatch of :func:`_run_schedule_batch`."""
    bsz = a_blocks.shape[0] // a_slots
    return _run_schedule_batch(
        a_blocks, b_blocks, sched, bsz, a_slots, b_slots,
        n_panels=n_panels, group=group, backend=backend, interpret=interpret,
    )


@jax.jit
def assemble_core(panels, gather):
    """Stage 3: output panels -> packed C values (one static gather)."""
    return panels.reshape(-1)[gather]


@functools.partial(jax.jit, static_argnames=("n_panels",))
def assemble_batch_core(panels, gather, *, n_panels):
    """Stage 3, batched: per-element gather through the shared map."""
    bsz = panels.shape[0] // n_panels
    return panels.reshape(bsz, -1)[:, gather]


class SpGEMMExecutor:
    """A plan's numeric phase with device-resident constants.

    Stages the triple schedule, the scatter indices, and the assembly gather
    map on device once; ``run``/``run_values``/``run_batch`` then call the
    module-level jitted cores (shared executables across same-shaped plans)
    with zero per-call host work beyond operand transfer.

    Every entry point honors the plan's backend: single-shot calls run the
    scalar-prefetch Pallas grid on pallas plans, and ``run_batch`` runs its
    batch-folded variant (:func:`~repro.kernels.gustavson_spgemm.
    spgemm_scheduled_batch_impl` — the batch is a leading grid dimension,
    so pallas plans never leave the MXU path when batched). The jnp
    (pure-XLA) kernel serves ``backend="jnp"`` plans on every path.
    """

    def __init__(
        self,
        *,
        schedule: SpGEMMSchedule,
        assembly: AssemblyMap,
        backend: str,
        a_scatter: Optional[np.ndarray] = None,
        b_scatter: Optional[np.ndarray] = None,
        a_shape: Tuple[int, ...] = (),
        b_shape: Tuple[int, ...] = (),
        chunk_bytes: Optional[int] = None,
    ):
        self.backend = backend
        self._chunk_policy = resolve_chunk_bytes(chunk_bytes)
        self.n_panels = schedule.n_panels
        self.group = schedule.group
        self.a_shape = tuple(a_shape)
        self.b_shape = tuple(b_shape)
        self._interpret = (
            backend == "pallas_interpret" or jax.default_backend() != "tpu"
        )
        # Per-set f32 rows the batched schedule touches (panel accumulator
        # + einsum products) — the working-set basis for batch_chunk().
        bm = a_shape[1] if len(a_shape) == 3 else 0
        self._bn = b_shape[2] if len(b_shape) == 3 else 0
        self._per_set_rows = (
            schedule.n_panels * schedule.group + schedule.num_triples
        ) * bm
        # The assembly map is the *active* output map: the plan passes its
        # block-structural map for output="block" and the element-exact
        # compact map for output="compact" — every path below is a gather
        # through it, so the compaction is fused into assembly for free.
        self._gather = jnp.asarray(assembly.gather)
        self._out_rows = int(assembly.shape[0])
        self._indptr_host = np.asarray(assembly.indptr)
        self._row_ids: Optional[jax.Array] = None
        # The raw (unpadded) schedule tuple serves jnp plans on every path;
        # pallas plans get the padded 5-tuple below, shared by the single
        # and batch-folded grids.
        self._sched_jnp = tuple(
            jnp.asarray(x) for x in (
                schedule.a_slot, schedule.b_slot, schedule.panel,
                schedule.sub_row,
            )
        )
        if backend in ("pallas", "pallas_interpret"):
            a_slot, b_slot, panel, sub_row, start, _ = pad_schedule_arrays(
                schedule.a_slot, schedule.b_slot, schedule.panel,
                schedule.sub_row, schedule.start, schedule.n_panels,
            )
            self._sched = tuple(
                jnp.asarray(x) for x in (a_slot, b_slot, panel, sub_row, start)
            )
        else:
            self._sched = self._sched_jnp
        # Rebind maps: scatter indices inverted to gather form at build.
        self._a_inv = (
            jnp.asarray(_invert_scatter(a_scatter, int(np.prod(a_shape))))
            if a_scatter is not None else None
        )
        self._b_inv = (
            jnp.asarray(_invert_scatter(b_scatter, int(np.prod(b_shape))))
            if b_scatter is not None else None
        )

    @property
    def can_rebind(self) -> bool:
        return self._a_inv is not None and self._b_inv is not None

    def set_chunk_bytes(self, chunk_bytes: Optional[int]) -> None:
        """Re-resolve the chunk policy with a new per-set budget.

        The autotuner applies its winning ``chunk_bytes`` here after the
        executor is built; ``REPRO_SPGEMM_CHUNK_BYTES`` still wins inside
        :func:`resolve_chunk_bytes`, so an operator env override always
        beats a tuned (or constructor) value.
        """
        self._chunk_policy = resolve_chunk_bytes(chunk_bytes)

    def batch_chunk(
        self,
        small_set_bytes: Optional[int] = None,
        cache_bytes: Optional[int] = None,
    ) -> int:
        """Max batch elements per fused device call.

        Fusing pays only when one set's working bytes (panel accumulator +
        einsum intermediates, ``4 * per_set_rows * bn``) are small: chunks
        sized to keep ``chunk * per_set`` under ``cache_bytes`` then cut
        per-set cost 1.1-2x by amortizing dispatch (probe-measured, CPU).
        Above ``small_set_bytes`` per set, measured chunks *regress* (the
        fused accumulator leaves cache: x0.86 at 1.1 MiB/set falling to
        x0.5 by 4 MiB on the calibration container), so larger problems
        run one set per call — matching a single ``execute()`` minus its
        host rebind/staging work.

        Both knobs default to the resolved per-backend policy (constructor
        ``chunk_bytes`` arg, overridden by ``REPRO_SPGEMM_CHUNK_BYTES``):
        the CPU knee is an L2/L3 property and wrong for VMEM, so TPU/GPU
        backends get their own table rows. All rows are re-measured with
        :func:`repro.core.tuning.measure_chunk_knee` (see the
        ``_CHUNK_POLICY`` provenance note).
        """
        if small_set_bytes is None:
            small_set_bytes = self._chunk_policy[0]
        if cache_bytes is None:
            cache_bytes = self._chunk_policy[1]
        per_set = 4 * self._per_set_rows * self._bn
        if per_set <= small_set_bytes:
            return max(1, cache_bytes // max(per_set, 1))
        return 1

    def device_indptr(self) -> jax.Array:
        """Device-resident CSR ``indptr`` of the active output map.

        The device half of the compaction bookkeeping: segment-sum row
        counts + ``jnp.cumsum`` prefix over the map's static row-id stream
        (:func:`~repro.kernels.gustavson_spgemm.compact_csr_indptr_impl`).
        Together with the packed values a ``run*`` call returns, this is a
        complete CSR replica of C on device — the handoff structure
        ``execute_chain`` keeps resident between stages. Must agree
        elementwise with the plan's host-precomputed ``indptr`` (a test
        invariant)."""
        if self._row_ids is None:
            self._row_ids = jnp.asarray(np.repeat(
                np.arange(self._out_rows, dtype=np.int32),
                np.diff(self._indptr_host),
            ))
        return compact_csr_indptr_impl(self._row_ids, m=self._out_rows)

    def run(self, a_blocks, b_blocks) -> jax.Array:
        """Packed blocks -> packed C values (plan's backend)."""
        return numeric_core(
            a_blocks, b_blocks, self._sched, self._gather,
            n_panels=self.n_panels, group=self.group, backend=self.backend,
            interpret=self._interpret,
        )

    def run_values(self, a_vals, b_vals) -> jax.Array:
        """[nnz] value vectors -> packed C values, rebind included."""
        return numeric_core_values(
            a_vals, b_vals, self._a_inv, self._b_inv,
            self._sched, self._gather,
            a_shape=self.a_shape, b_shape=self.b_shape,
            n_panels=self.n_panels, group=self.group, backend=self.backend,
            interpret=self._interpret,
        )

    def run_batch(self, a_vals, b_vals, *, rebind: bool) -> jax.Array:
        """Batched values -> packed C values [batch, nnz_c] (plan's
        backend: the batch-folded Pallas grid on pallas plans)."""
        return numeric_core_batch(
            jnp.asarray(a_vals), jnp.asarray(b_vals),
            self._a_inv, self._b_inv,
            self._sched, self._gather,
            a_shape=self.a_shape, b_shape=self.b_shape, rebind=rebind,
            n_panels=self.n_panels, group=self.group, backend=self.backend,
            interpret=self._interpret,
        )

    # -- pipeline protocol (stage-split, non-blocking until collect) -------
    #
    # ``mode`` for pipe_stage: "values" ([nnz] vectors, element plans),
    # "batch_values" ([batch, nnz]), "batch_blocks" ([batch, slots, ...]
    # packed blocks). Single-shot block operands are staged by the plan's
    # ``_stage_a``/``_stage_b`` hooks and enter at pipe_kernel directly.
    # ``mode`` for kernel/assemble/collect: "single" or "batch". Both
    # dispatch on the plan's backend (like ``run``/``run_batch``): pallas
    # plans run the scalar-prefetch grid, batch-folded in batch mode.

    def pipe_stage(self, a, b, *, mode: str):
        """H2D transfer + value-rebind dispatch; returns staged device
        packed blocks without blocking."""
        if mode == "values":
            return (
                bind_core(jax.device_put(a), self._a_inv,
                          shape=self.a_shape),
                bind_core(jax.device_put(b), self._b_inv,
                          shape=self.b_shape),
            )
        if mode == "batch_values":
            return (
                bind_batch_core(jax.device_put(a), self._a_inv,
                                shape=self.a_shape),
                bind_batch_core(jax.device_put(b), self._b_inv,
                                shape=self.b_shape),
            )
        if mode == "batch_blocks":
            return (
                jnp.asarray(a).reshape((-1,) + self.a_shape[1:]),
                jnp.asarray(b).reshape((-1,) + self.b_shape[1:]),
            )
        raise ValueError(f"unknown stage mode {mode!r}")  # pragma: no cover

    def pipe_kernel(self, staged, *, mode: str):
        """Scheduled-kernel dispatch over staged blocks; non-blocking."""
        a_blocks, b_blocks = staged
        if mode == "single":
            return kernel_core(
                a_blocks, b_blocks, self._sched,
                n_panels=self.n_panels, group=self.group,
                backend=self.backend, interpret=self._interpret,
            )
        return kernel_batch_core(
            a_blocks, b_blocks, self._sched,
            a_slots=self.a_shape[0], b_slots=self.b_shape[0],
            n_panels=self.n_panels, group=self.group,
            backend=self.backend, interpret=self._interpret,
        )

    def pipe_assemble(self, panels, *, mode: str):
        """Output-assembly gather dispatch; non-blocking."""
        if mode == "single":
            return assemble_core(panels, self._gather)
        return assemble_batch_core(panels, self._gather,
                                   n_panels=self.n_panels)

    def pipe_collect(self, packed, *, mode: str) -> np.ndarray:
        """Materialize packed C values on host (the only blocking step)."""
        return np.asarray(packed)


class ShardedSpGEMMExecutor:
    """Numeric phase of a mesh-partitioned plan: one ``shard_map`` call.

    Drop-in for :class:`SpGEMMExecutor` on the plan side (same
    ``run``/``run_values``/``run_batch``/``batch_chunk`` surface), but the
    device-resident constants are *stacked per shard and laid out on the
    mesh*: every per-shard array (``[n_shards, ...]``, padded to the
    largest shard) is sharded over one mesh axis, B-side arrays are
    replicated, and the numeric phase runs under a single
    ``jax.jit(shard_map(...))`` — each device executes its own (padded)
    triple schedule against its own A blocks and the replicated B blocks,
    and emits its own packed C segment through its shard's
    :class:`~repro.core.schedule.AssemblyMap` gather.

    Layout contract (the tentpole's sharding policy):

    * A values / packed A blocks — **row-sharded**: shard ``i`` holds the
      slots ``[a_lo_i, a_hi_i)`` (elements ``[e_lo_i, e_hi_i)``), which are
      contiguous because BCSV packs blocks group-major;
    * B values / packed B blocks — **replicated** (the paper's shared
      B-buffer scheme lifted to the mesh);
    * C — **row-sharded**: the final CSR data is one host concatenation of
      the per-shard segments along the precomputed indptr boundaries.

    The kernel inside ``shard_map`` honors the plan's backend: every
    shard's rebased schedule is a contiguous standalone program, so on
    pallas plans each device runs its own scalar-prefetch Pallas grid over
    its padded schedule slice (batch-folded in the batched kinds) —
    ``shard_map`` is told ``check_vma=False`` for those programs since
    ``pallas_call`` carries no replication rule. The jnp (pure-XLA) path
    serves ``backend="jnp"``. On either backend, padding triples write to
    a dummy panel and padded gather slots are trimmed on host, so ragged
    and empty shards are handled by construction.
    """

    def __init__(
        self,
        *,
        shards: Sequence[ScheduleShard],
        assemblies: Sequence[AssemblyMap],
        mesh: Mesh,
        axis: str,
        backend: str,
        a_scatter: Optional[np.ndarray] = None,
        b_scatter: Optional[np.ndarray] = None,
        a_shape: Tuple[int, ...] = (),
        b_shape: Tuple[int, ...] = (),
        a_val_bounds: Optional[np.ndarray] = None,
        chunk_bytes: Optional[int] = None,
    ):
        if len(shards) != int(mesh.shape[axis]):
            raise ValueError(
                f"{len(shards)} shards for mesh axis {axis!r} of size "
                f"{mesh.shape[axis]}"
            )
        self.backend = backend
        self.mesh = mesh
        self.axis = axis
        self.a_shape = tuple(a_shape)
        self.b_shape = tuple(b_shape)
        self._interpret = (
            backend == "pallas_interpret" or jax.default_backend() != "tpu"
        )
        self._chunk_policy = resolve_chunk_bytes(chunk_bytes)
        self._shards = list(shards)
        s0 = shards[0].schedule
        self.group = s0.group
        self._s = len(shards)
        bm, bk = a_shape[1], a_shape[2]
        self._bm, self._bn = bm, b_shape[2]
        self._t_max = max(1, max(s.num_triples for s in shards))
        self._p_max = max(1, max(s.n_panels for s in shards))
        self._a_max = max(1, max(s.a_hi - s.a_lo for s in shards))
        self._assemblies = list(assemblies)
        self._nnz_c = [asm.nnz for asm in assemblies]
        self._c_max = max(1, max(self._nnz_c))
        self._row_ids: Optional[jax.Array] = None
        # Per-shard working set mirrors SpGEMMExecutor's basis, taken over
        # the *largest* shard (each device only holds its own panels).
        self._per_set_rows = (
            (self._p_max + 1) * self.group + self._t_max
        ) * bm

        self._sep = leading_sharding(mesh, axis)
        self._rep = replicated_sharding(mesh)

        def put(arr, sharding):
            return jax.device_put(np.ascontiguousarray(arr), sharding)

        # Stacked, padded schedule [n_shards, t_max] incl. per-shard start
        # flags (stack_shard_schedules): pads execute a real (block 0) x
        # (block 0) matmul into the dummy panel p_max, which no gather
        # reads; start=1 on pads keeps the pallas accumulator clean.
        self._sched = tuple(
            put(x, self._sep)
            for x in stack_shard_schedules(shards, self._t_max, self._p_max)
        )
        gdtype = np.result_type(*(asm.gather.dtype for asm in assemblies))
        gather = np.zeros((self._s, self._c_max), gdtype)
        for i, asm in enumerate(assemblies):
            gather[i, : asm.nnz] = asm.gather
        self._gather = put(gather, self._sep)

        # Rebind maps (element plans): per-shard scatter inverses into the
        # shard's padded value slice; index e_max is the zero pad slot.
        self._a_inv = self._b_inv = None
        self._e_bounds: Optional[np.ndarray] = None
        self._e_max = 1
        if a_scatter is not None and b_scatter is not None:
            if a_val_bounds is None:
                raise ValueError("element shards need a_val_bounds")
            self._e_bounds = np.asarray(a_val_bounds, np.int64)
            self._e_max = max(1, int(np.diff(self._e_bounds).max(initial=0)))
            self._nnz_b = int(b_scatter.shape[0])
            flat_a = self._a_max * bm * bk
            a_inv = np.full((self._s, flat_a), self._e_max, np.int32)
            for i, sh in enumerate(shards):
                e_lo, e_hi = int(self._e_bounds[i]), int(self._e_bounds[i + 1])
                pos = a_scatter[e_lo:e_hi] - sh.a_lo * bm * bk
                # Elements of A blocks outside the shard's slot range never
                # feed a triple (no matching B block) — skip them.
                sel = (pos >= 0) & (pos < (sh.a_hi - sh.a_lo) * bm * bk)
                a_inv[i, pos[sel]] = np.arange(e_hi - e_lo, dtype=np.int32)[sel]
            self._a_inv = put(a_inv, self._sep)
            self._b_inv = put(
                _invert_scatter(b_scatter, int(np.prod(b_shape))), self._rep
            )
        self._fns: dict = {}

    # -- layout helpers (host side) ---------------------------------------

    @property
    def can_rebind(self) -> bool:
        return self._a_inv is not None and self._b_inv is not None

    def set_chunk_bytes(self, chunk_bytes: Optional[int]) -> None:
        """Re-resolve the chunk policy with a new per-set budget.

        The autotuner applies its winning ``chunk_bytes`` here after the
        executor is built; ``REPRO_SPGEMM_CHUNK_BYTES`` still wins inside
        :func:`resolve_chunk_bytes`, so an operator env override always
        beats a tuned (or constructor) value.
        """
        self._chunk_policy = resolve_chunk_bytes(chunk_bytes)

    def batch_chunk(
        self,
        small_set_bytes: Optional[int] = None,
        cache_bytes: Optional[int] = None,
    ) -> int:
        """Same policy as :meth:`SpGEMMExecutor.batch_chunk`, applied to
        the largest shard's per-device working set."""
        if small_set_bytes is None:
            small_set_bytes = self._chunk_policy[0]
        if cache_bytes is None:
            cache_bytes = self._chunk_policy[1]
        per_set = 4 * self._per_set_rows * self._bn
        if per_set <= small_set_bytes:
            return max(1, cache_bytes // max(per_set, 1))
        return 1

    def device_indptr(self) -> jax.Array:
        """Plan-wide device CSR ``indptr`` (see
        :meth:`SpGEMMExecutor.device_indptr`). Shard row ranges are
        contiguous and ascending, so the plan-wide row-id stream is the
        offset concatenation of the per-shard assembly streams — the same
        order :meth:`_concat` emits values in."""
        if self._row_ids is None:
            ids, off = [], 0
            for asm in self._assemblies:
                rows = int(asm.shape[0])
                ids.append(off + np.repeat(
                    np.arange(rows, dtype=np.int32),
                    np.diff(np.asarray(asm.indptr)),
                ).astype(np.int32))
                off += rows
            self._out_rows = off
            self._row_ids = jnp.asarray(
                np.concatenate(ids) if ids
                else np.zeros(0, np.int32)
            )
        return compact_csr_indptr_impl(self._row_ids, m=self._out_rows)

    def _concat(self, out: np.ndarray) -> np.ndarray:
        """Trim per-shard pads and concatenate along the shard axis (the
        CSR data order: shard row ranges are contiguous and ascending)."""
        return np.concatenate(
            [out[i, ..., : self._nnz_c[i]] for i in range(self._s)], axis=-1
        )

    def stage_a(self, blocks: np.ndarray) -> jax.Array:
        """Full packed A blocks -> stacked per-shard device layout."""
        return jax.device_put(self._stack_a(np.asarray(blocks)), self._sep)

    def stage_b(self, blocks: np.ndarray) -> jax.Array:
        """Full packed B blocks -> replicated device layout."""
        return jax.device_put(np.asarray(blocks), self._rep)

    def _stack_a(self, blocks: np.ndarray) -> np.ndarray:
        """Full packed A ([..batch..], nnzb_a, bm, bk) -> per-shard slot
        slices stacked and padded: (n_shards, [..batch..], a_max, bm, bk)."""
        lead = blocks.shape[:-3]
        out = np.zeros(
            (self._s,) + lead + (self._a_max,) + blocks.shape[-2:],
            blocks.dtype,
        )
        for i, sh in enumerate(self._shards):
            out[i, ..., : sh.a_hi - sh.a_lo, :, :] = (
                blocks[..., sh.a_lo: sh.a_hi, :, :]
            )
        return out

    def _slice_a_vals(self, vals: np.ndarray) -> np.ndarray:
        """[.., nnz_a] values -> [n_shards, .., e_max] padded slices."""
        lead = vals.shape[:-1]
        out = np.zeros((self._s,) + lead + (self._e_max,), vals.dtype)
        for i in range(self._s):
            e_lo, e_hi = int(self._e_bounds[i]), int(self._e_bounds[i + 1])
            out[i, ..., : e_hi - e_lo] = vals[..., e_lo:e_hi]
        return out

    # -- shard_map cores ---------------------------------------------------

    def _fn(self, kind: str):
        if kind in self._fns:
            return self._fns[kind]
        ax, group = self.axis, self.group
        a_max, p_max = self._a_max, self._p_max
        bm, bk = self.a_shape[1], self.a_shape[2]
        b_shape = self.b_shape
        backend, interpret = self.backend, self._interpret
        # Every shard-local schedule is padded to (t_max, p_max), so on
        # pallas backends each device runs its own scalar-prefetch grid
        # over p_max + 1 panels — the same panel count the jnp reference
        # produces, keeping stage outputs shape-identical across backends.
        # The shard's own dummy triples target panel p_max (never gathered);
        # the impl-level dummy p_max + 1 is stripped inside the call.

        def sched_kernel(a_blocks, b_blocks, a_slot, b_slot, panel, sub_row,
                         strt):
            if backend in ("pallas", "pallas_interpret"):
                return spgemm_scheduled_impl(
                    a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, strt,
                    n_panels=p_max + 1, group=group, interpret=interpret,
                )
            return ref.spgemm_scheduled_ref(
                a_blocks, b_blocks, a_slot, b_slot, panel, sub_row,
                p_max + 1, group,
            )

        def sched_kernel_batch(a_blocks, b_blocks, a_slot, b_slot, panel,
                               sub_row, strt, bsz):
            return _run_schedule_batch(
                a_blocks, b_blocks,
                (a_slot, b_slot, panel, sub_row, strt)
                if backend in ("pallas", "pallas_interpret")
                else (a_slot, b_slot, panel, sub_row),
                bsz, a_max, b_shape[0],
                n_panels=p_max + 1, group=group, backend=backend,
                interpret=interpret,
            )

        def kernel(a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, strt,
                   gth):
            panels = sched_kernel(
                a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, strt
            )
            return panels.reshape(-1)[gth]

        def kernel_batch(a_blocks, b_blocks, a_slot, b_slot, panel, sub_row,
                         strt, gth, bsz):
            panels = sched_kernel_batch(
                a_blocks, b_blocks, a_slot, b_slot, panel, sub_row, strt, bsz
            )
            return panels.reshape(bsz, -1)[:, gth]

        out = P(ax)
        # pallas_call has no shard_map replication rule, so the programs
        # that contain the kernel disable the replication check on pallas
        # backends; bind/assemble programs keep the jax default.
        vma: Optional[bool] = None
        if kind == "run":
            def body(a_bl, b_bl, a_slot, b_slot, panel, sub_row, strt, gth):
                return kernel(a_bl[0], b_bl, a_slot[0], b_slot[0], panel[0],
                              sub_row[0], strt[0], gth[0])[None]
            specs = (P(ax), P(), P(ax), P(ax), P(ax), P(ax), P(ax), P(ax))
            vma = False
        elif kind == "run_values":
            def body(a_vals, b_vals, a_inv, b_inv, a_slot, b_slot, panel,
                     sub_row, strt, gth):
                a_bl = _bind(a_vals[0], a_inv[0], (a_max, bm, bk))
                b_bl = _bind(b_vals, b_inv, b_shape)
                return kernel(a_bl, b_bl, a_slot[0], b_slot[0], panel[0],
                              sub_row[0], strt[0], gth[0])[None]
            specs = (P(ax), P(), P(ax), P(), P(ax), P(ax), P(ax), P(ax),
                     P(ax), P(ax))
            vma = False
        elif kind == "batch_values":
            def body(a_vals, b_vals, a_inv, b_inv, a_slot, b_slot, panel,
                     sub_row, strt, gth):
                bsz = a_vals.shape[1]
                a_bl = _bind_batch(a_vals[0], a_inv[0], (a_max, bm, bk))
                b_bl = _bind_batch(b_vals, b_inv, b_shape)
                return kernel_batch(a_bl, b_bl, a_slot[0], b_slot[0],
                                    panel[0], sub_row[0], strt[0], gth[0],
                                    bsz)[None]
            specs = (P(ax), P(), P(ax), P(), P(ax), P(ax), P(ax), P(ax),
                     P(ax), P(ax))
            vma = False
        elif kind == "batch_blocks":
            def body(a_vals, b_vals, a_slot, b_slot, panel, sub_row, strt,
                     gth):
                bsz = a_vals.shape[1]
                a_bl = a_vals[0].reshape((bsz * a_max, bm, bk))
                b_bl = b_vals.reshape(
                    (bsz * b_shape[0],) + tuple(b_shape[1:]))
                return kernel_batch(a_bl, b_bl, a_slot[0], b_slot[0],
                                    panel[0], sub_row[0], strt[0], gth[0],
                                    bsz)[None]
            specs = (P(ax), P(), P(ax), P(ax), P(ax), P(ax), P(ax), P(ax))
            vma = False
        # -- stage-split kinds (the pipeline protocol): same ops as the
        # fused bodies above, one shard_map program per stage so staging
        # step s+1 dispatches independently of step s's kernel.
        elif kind == "bind":
            def body(a_vals, b_vals, a_inv, b_inv):
                a_bl = _bind(a_vals[0], a_inv[0], (a_max, bm, bk))
                b_bl = _bind(b_vals, b_inv, b_shape)
                return a_bl[None], b_bl
            specs = (P(ax), P(), P(ax), P())
            out = (P(ax), P())
        elif kind == "bind_batch":
            def body(a_vals, b_vals, a_inv, b_inv):
                bsz = a_vals.shape[1]
                a_bl = _bind_batch(a_vals[0], a_inv[0], (a_max, bm, bk))
                b_bl = _bind_batch(b_vals, b_inv, b_shape)
                return (
                    a_bl.reshape((bsz, a_max, bm, bk))[None],
                    b_bl.reshape((bsz,) + tuple(b_shape)),
                )
            specs = (P(ax), P(), P(ax), P())
            out = (P(ax), P())
        elif kind == "kernel":
            def body(a_bl, b_bl, a_slot, b_slot, panel, sub_row, strt):
                return sched_kernel(
                    a_bl[0], b_bl, a_slot[0], b_slot[0], panel[0],
                    sub_row[0], strt[0],
                )[None]
            specs = (P(ax), P(), P(ax), P(ax), P(ax), P(ax), P(ax))
            vma = False
        elif kind == "kernel_batch":
            def body(a_bl, b_bl, a_slot, b_slot, panel, sub_row, strt):
                bsz = a_bl.shape[1]
                return sched_kernel_batch(
                    a_bl[0].reshape((bsz * a_max, bm, bk)),
                    b_bl.reshape((bsz * b_shape[0],) + tuple(b_shape[1:])),
                    a_slot[0], b_slot[0], panel[0], sub_row[0], strt[0],
                    bsz,
                )[None]
            specs = (P(ax), P(), P(ax), P(ax), P(ax), P(ax), P(ax))
            vma = False
        elif kind == "assemble":
            def body(panels, gth):
                return panels[0].reshape(-1)[gth[0]][None]
            specs = (P(ax), P(ax))
        elif kind == "assemble_batch":
            def body(panels, gth):
                bsz = panels.shape[1] // (p_max + 1)
                return panels[0].reshape(bsz, -1)[:, gth[0]][None]
            specs = (P(ax), P(ax))
        else:  # pragma: no cover - internal
            raise ValueError(kind)

        if backend not in ("pallas", "pallas_interpret"):
            vma = None
        fn = jax.jit(shard_map(
            body, mesh=self.mesh, in_specs=specs, out_specs=out,
            check_vma=vma,
        ))
        self._fns[kind] = fn
        return fn

    # -- public surface (SpGEMMExecutor drop-in) ---------------------------

    def run(self, a_staged, b_staged) -> np.ndarray:
        """Staged (stacked/replicated) packed blocks -> packed C values.

        ``a_staged``/``b_staged`` come from :meth:`stage_a`/:meth:`stage_b`
        (the sharded plan's device staging hooks).
        """
        out = np.asarray(
            self._fn("run")(a_staged, b_staged, *self._sched, self._gather)
        )
        return self._concat(out)

    def run_values(self, a_vals, b_vals) -> np.ndarray:
        """[nnz] value vectors -> packed C values; A row-sharded on the
        mesh, B replicated, rebind + kernel + assembly inside shard_map."""
        a_sh = jax.device_put(
            self._slice_a_vals(np.asarray(a_vals)), self._sep)
        b_d = jax.device_put(np.asarray(b_vals), self._rep)
        out = np.asarray(self._fn("run_values")(
            a_sh, b_d, self._a_inv, self._b_inv, *self._sched, self._gather
        ))
        return self._concat(out)

    def run_batch(self, a_vals, b_vals, *, rebind: bool) -> np.ndarray:
        """Batched values -> packed C values [batch, nnz_c]; the batch is
        folded into each shard's triple schedule (exact vmap semantics,
        like the unsharded batch path) inside the one shard_map call."""
        a_vals = np.asarray(a_vals)
        b_vals = np.asarray(b_vals)
        if rebind:
            a_sh = jax.device_put(self._slice_a_vals(a_vals), self._sep)
            b_d = jax.device_put(b_vals, self._rep)
            out = np.asarray(self._fn("batch_values")(
                a_sh, b_d, self._a_inv, self._b_inv, *self._sched,
                self._gather,
            ))
        else:
            a_sh = jax.device_put(self._stack_a(a_vals), self._sep)
            b_d = jax.device_put(b_vals, self._rep)
            out = np.asarray(self._fn("batch_blocks")(
                a_sh, b_d, *self._sched, self._gather
            ))
        return self._concat(out)

    # -- pipeline protocol (same surface as SpGEMMExecutor) ----------------

    def pipe_stage(self, a, b, *, mode: str):
        """Mesh layout + H2D + per-shard rebind dispatch; non-blocking.

        A values are host-sliced per shard and placed on the shard axis, B
        replicated; the rebind runs as its own ``shard_map`` program so it
        dispatches independently of the previous step's kernel."""
        if mode == "values":
            a_sh = jax.device_put(
                self._slice_a_vals(np.asarray(a)), self._sep)
            b_d = jax.device_put(np.asarray(b), self._rep)
            return self._fn("bind")(a_sh, b_d, self._a_inv, self._b_inv)
        if mode == "batch_values":
            a_sh = jax.device_put(
                self._slice_a_vals(np.asarray(a)), self._sep)
            b_d = jax.device_put(np.asarray(b), self._rep)
            return self._fn("bind_batch")(a_sh, b_d, self._a_inv,
                                          self._b_inv)
        if mode == "batch_blocks":
            return (
                jax.device_put(self._stack_a(np.asarray(a)), self._sep),
                jax.device_put(np.asarray(b), self._rep),
            )
        raise ValueError(f"unknown stage mode {mode!r}")  # pragma: no cover

    def pipe_kernel(self, staged, *, mode: str):
        """Per-shard scheduled-kernel dispatch (one shard_map program)."""
        a_bl, b_bl = staged
        kind = "kernel" if mode == "single" else "kernel_batch"
        return self._fn(kind)(a_bl, b_bl, *self._sched)

    def pipe_assemble(self, panels, *, mode: str):
        """Per-shard output-assembly gather dispatch."""
        kind = "assemble" if mode == "single" else "assemble_batch"
        return self._fn(kind)(panels, self._gather)

    def pipe_collect(self, packed, *, mode: str) -> np.ndarray:
        """Blocking D2H + per-shard pad trim + host concatenation."""
        return self._concat(np.asarray(packed))
