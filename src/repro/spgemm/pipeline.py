"""Pipelined async SpGEMM serving: submit/collect over the stage-split
executor.

FSpGEMM's throughput trick (PAPER Sec. 4) is operand double-buffering:
while one partial product computes, the next rows' operands are already
streaming into on-chip buffers, so the multiply pipeline never stalls on
data movement. The synchronous ``SpGEMMPlan.execute`` is exactly that
stall in host form — rebind, H2D, kernel, assembly, and D2H serialized
per step. :class:`SpGEMMPipeline` removes it:

* ``submit(a_vals, b_vals)`` *dispatches* a step — H2D staging + value
  rebind, the scheduled kernel, and output assembly, each its own device
  program (``repro.spgemm.executor``'s ``pipe_*`` protocol) — and returns
  a :class:`SpGEMMTicket` immediately. Nothing blocks: JAX async dispatch
  queues the programs, so step ``s + 1``'s staging overlaps step ``s``'s
  kernel, and each in-flight step owns its own staged packed A/B block
  arrays on device (per shard on sharded plans) — a ``depth``-deep
  operand buffer ring, the paper's double buffer at ``depth=2``.
* ``collect(ticket)`` materializes that step's CSR (the only blocking
  call, D2H). Tickets may be collected out of submission order;
  ``collect()`` with no argument takes the oldest outstanding.
* in-flight work is bounded by ``depth``: a ``submit`` past the bound
  raises :class:`PipelineFullError` (explicit backpressure), and
  ``stream(value_iter)`` / ``__iter__`` manage the bound for you,
  yielding ordered results.

Results are **bitwise-equal** to sequential ``execute`` calls: the stage
jits run exactly the fused cores' ops, and submission is stateless with
respect to the plan's staged values (like ``execute_batch``), so a
pipelined stream of N steps reproduces N synchronous executes exactly —
on element, block, batched, and sharded plans.

Error handling: a step whose dispatch or device execution fails stores
the exception on its ticket; ``collect`` of that ticket re-raises it
while every other in-flight step stays collectable. While any ticket is
in flight the owning plan refuses buffer teardown
(``release_values``/``release``/cache eviction raise) — close or drain
the pipeline first. ``SpGEMMPipeline`` is a context manager; exiting
discards anything still in flight.
"""
from __future__ import annotations

import threading
import weakref
from typing import Iterable, Iterator, Optional, Tuple, Union

__all__ = [
    "PipelineFullError",
    "SpGEMMPipeline",
    "SpGEMMTicket",
]


class PipelineFullError(RuntimeError):
    """``submit`` past the pipeline's in-flight ``depth`` bound."""


class _Prepared:
    """A validated, host-side-prepared submission (built by
    ``SpGEMMPlan._pipe_check``): execution mode, operands (cast host
    arrays for value modes, staged device arrays for block mode), batch
    size (``None`` single-shot), and the executes-counter increment."""

    __slots__ = ("mode", "a", "b", "batch", "n_execs")

    def __init__(self, mode, a, b, batch, n_execs):
        self.mode = mode
        self.a = a
        self.b = b
        self.batch = batch
        self.n_execs = n_execs


class _Step:
    """One in-flight pipeline step: its dispatched device result (packed C
    values; a list of chunk arrays for batch submissions) or the error
    its dispatch raised."""

    __slots__ = ("prep", "packed", "error")

    def __init__(self, prep):
        self.prep = prep
        self.packed = None
        self.error: Optional[BaseException] = None


class SpGEMMTicket:
    """Ordered handle for one submitted step; redeem with
    :meth:`result` (or ``pipeline.collect(ticket)``)."""

    __slots__ = ("_pipe", "index", "batch")

    def __init__(self, pipe: "SpGEMMPipeline", index: int,
                 batch: Optional[int]):
        self._pipe = pipe
        self.index = index
        self.batch = batch  # None for single-shot, batch size otherwise

    def result(self):
        """Block until this step's C is on host and return it (a CSR, or
        a list of CSRs for a batched submission)."""
        return self._pipe.collect(self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"SpGEMMTicket(index={self.index}"
                + (f", batch={self.batch}" if self.batch else "") + ")")


def _discard_outstanding(plan, steps: dict, lock: threading.Lock) -> None:
    """Drop every outstanding step and balance the plan's in-flight
    count. Module-level (no pipeline reference) so ``weakref.finalize``
    can run it after the pipeline itself is collected."""
    with lock:
        n = len(steps)
        steps.clear()
    for _ in range(n):
        plan._pipe_end()


ValueItem = Union[Tuple, dict]


class SpGEMMPipeline:
    """Bounded-depth async serving pipeline over one
    :class:`~repro.spgemm.plan.SpGEMMPlan`.

    ``depth`` bounds in-flight steps (2 = the paper's double buffer:
    one step staging while one computes). Construct directly or via
    ``plan.pipeline(depth=...)``; typical streaming use::

        with plan.pipeline(depth=2) as pipe:
            for c in pipe.stream(stream.value_iter(steps=100)):
                consume(c)

    or explicit submit/collect::

        t0 = pipe.submit(a0, b0)
        t1 = pipe.submit(a1, b1)   # overlaps t0's kernel
        c0 = pipe.collect(t0)      # or collect(t1) first: out-of-order OK
        c1 = t1.result()

    Thread-safe; a single pipeline's submissions are ordered by ticket
    index. Submission is stateless w.r.t. the plan's staged values (the
    no-arg ``submit()`` reuses them, like no-arg ``execute``).
    """

    def __init__(self, plan, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.plan = plan
        self.depth = int(depth)
        self._lock = threading.Lock()
        self._steps: dict = {}  # index -> _Step (outstanding only)
        self._next = 0
        self._closed = False
        # Abandonment guard: a pipeline (or a lone execute_async ticket)
        # dropped with outstanding steps must not pin the plan's
        # in-flight count forever. The finalizer discards whatever is
        # still outstanding when the pipeline is garbage-collected;
        # close() runs the same discard eagerly (finalize is call-once,
        # so the two never double-release).
        self._finalizer = weakref.finalize(
            self, _discard_outstanding, plan, self._steps, self._lock)

    # -- introspection -----------------------------------------------------

    @property
    def in_flight(self) -> int:
        """Outstanding (submitted, not yet collected) steps."""
        with self._lock:
            return len(self._steps)

    @property
    def free_slots(self) -> int:
        """Submissions currently possible without
        :class:`PipelineFullError` (0 once closed).

        Advisory under concurrency in general, but exact for a
        single-submitter arrangement (the gateway's dispatcher): collects
        only *free* slots, so the value cannot shrink between a check and
        that submitter's next ``submit``."""
        with self._lock:
            if self._closed:
                return 0
            return max(0, self.depth - len(self._steps))

    def __len__(self) -> int:
        return self.in_flight

    # -- submit / collect --------------------------------------------------

    def submit(self, a_vals=None, b_vals=None) -> SpGEMMTicket:
        """Dispatch one step; returns immediately with a ticket.

        Operand shapes follow ``execute``/``execute_batch``: ``[nnz]``
        value vectors (element plans) or packed block arrays (block
        plans), with an optional leading batch axis (the ticket then
        redeems to a list of CSRs, exactly ``execute_batch``'s output).
        Passing neither reuses the plan's staged values. Raises
        :class:`PipelineFullError` when ``depth`` steps are already in
        flight — collect one first (``stream`` does this for you).
        Invalid operands raise here, without consuming a slot; failures
        *after* validation (dispatch or device errors) are stored on the
        ticket and re-raised by ``collect``.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("pipeline is closed")
            if len(self._steps) >= self.depth:
                raise PipelineFullError(
                    f"pipeline depth {self.depth} exhausted "
                    f"({len(self._steps)} step(s) in flight); collect a "
                    f"result before submitting more"
                )
            prep = self.plan._pipe_check(a_vals, b_vals)
            self.plan._pipe_begin(prep.n_execs)
            index = self._next
            self._next += 1
            step = _Step(prep)
            try:
                step.packed = self.plan._pipe_dispatch(prep)
            except Exception as e:
                # Poisoned step: the slot is held (collect re-raises and
                # frees it); other in-flight steps are unaffected.
                step.error = e
            except BaseException:
                # KeyboardInterrupt/SystemExit must propagate, not hide
                # in a ticket; undo the in-flight accounting first.
                self.plan._pipe_end()
                raise
            self._steps[index] = step
            return SpGEMMTicket(self, index, prep.batch)

    def collect(self, ticket: Optional[SpGEMMTicket] = None):
        """Materialize one step's result (blocking D2H).

        ``ticket=None`` collects the oldest outstanding step. Returns a
        CSR (single-shot) or a list of CSRs (batched submission) sharing
        the plan's precomputed ``indptr``/``indices``. Re-raises the
        step's stored error, if any; the ticket's slot is freed either
        way.
        """
        with self._lock:
            if ticket is None:
                if not self._steps:
                    raise ValueError("nothing in flight to collect")
                index = min(self._steps)
            else:
                if ticket._pipe is not self:
                    raise ValueError(
                        "ticket belongs to a different pipeline")
                index = ticket.index
                if index not in self._steps:
                    raise ValueError(
                        f"ticket {index} was already collected")
            step = self._steps.pop(index)
        try:
            if step.error is not None:
                raise step.error
            return self.plan._pipe_collect(step.prep, step.packed)
        finally:
            self.plan._pipe_end()

    # -- streaming ---------------------------------------------------------

    def __iter__(self) -> Iterator:
        """Drain: collect every outstanding step, oldest first."""
        while True:
            with self._lock:
                if not self._steps:
                    return
            yield self.collect()

    def stream(self, value_iter: Iterable[ValueItem]) -> Iterator:
        """Pump ``value_iter`` through the pipeline at full depth,
        yielding ordered results.

        Items are ``(a_vals, b_vals)`` tuples or ``{"a_vals": ...,
        "b_vals": ...}`` dicts (what ``SpGEMMValueStream.iter`` /
        ``value_iter`` produce). Keeps ``depth`` steps in flight —
        submitting step ``s + depth`` before collecting step ``s`` — so
        staging overlaps compute throughout; results come back in
        submission order. Abandoning the iterator mid-stream discards
        whatever is still in flight (the plan's in-flight count returns
        to zero).
        """
        try:
            for item in value_iter:
                a_vals, b_vals = self._coerce(item)
                while self.in_flight >= self.depth:
                    yield self.collect()
                self.submit(a_vals, b_vals)
            yield from self
        finally:
            if self.in_flight:  # abandoned mid-stream
                self.close()

    @staticmethod
    def _coerce(item: ValueItem):
        if isinstance(item, dict):
            return item["a_vals"], item["b_vals"]
        a_vals, b_vals = item
        return a_vals, b_vals

    # -- teardown ----------------------------------------------------------

    def close(self) -> None:
        """Discard all outstanding steps (their device work is abandoned,
        results never materialize on host) and refuse further submits.
        Releases the plan's in-flight accounting, so buffer teardown
        (``release_values`` etc.) becomes legal again."""
        with self._lock:
            self._closed = True
        _discard_outstanding(self.plan, self._steps, self._lock)

    def __enter__(self) -> "SpGEMMPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
