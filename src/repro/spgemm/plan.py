"""Plan/execute SpGEMM: symbolic phase once, device-resident numeric phase.

FSpGEMM's host-side claim (Sec. 4.3) is that CSV pre-processing "only needs
to be performed once". This module is that claim as an API, in the
descriptor/setup-execute shape of cuSPARSE-style two-phase SpGEMM and the
symbolic/numeric split of Nagasaka et al. — with the numeric phase a pure
streaming pipeline, as on the paper's FPGA:

* :func:`spgemm_plan` runs every amortizable step once — sparse-native
  format conversion (COO -> BCSV/BCSR with value-scatter indices), the
  symbolic block-Gustavson phase (C structure + static triple schedule +
  the :class:`~repro.core.schedule.AssemblyMap` output-scatter structure),
  schedule padding, and device staging — and returns a :class:`SpGEMMPlan`.
* The numeric phase is the *functional core* of
  :class:`~repro.spgemm.executor.SpGEMMExecutor`: value rebind, the
  scheduled kernel, and output assembly fused under one ``jax.jit``;
  C's CSR pattern is precomputed, so assembly is a single static device
  gather — no host ``nonzero`` scan, no per-panel Python loop.
* :meth:`SpGEMMPlan.execute` is a thin stateful wrapper over that core: it
  keeps the lock / host-value staging / copy-on-stage semantics (no-arg
  ``execute()`` reuses staged values; plans are shared cache objects) and
  wraps the packed C values in the precomputed CSR structure.
* :meth:`SpGEMMPlan.execute_batch` vmaps the functional core over a leading
  value-batch axis — the serving workload, fed by the batch mode of
  :class:`repro.data.pipeline.SpGEMMValueStream`.
* Plans are cached process-wide (``repro.spgemm.cache``) keyed on
  ``(pattern hash, tile, group, backend)``, with optional byte-budget
  eviction — the serving path where one sparsity pattern meets millions of
  fresh value sets pays the symbolic phase exactly once.

Output convention: C's CSR pattern is *structural* (every element of every
structurally nonzero C block, trimmed to the true shape), so values that
compute to exact zero are stored explicitly — the pattern is
value-independent, which is what makes assembly jittable and batchable.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.schedule import (
    AssemblyMap,
    ScheduleShard,
    SpGEMMSchedule,
    assembly_from_arrays,
    assembly_to_arrays,
    build_assembly_map,
    build_compact_map,
    build_spgemm_schedule,
    partition_spgemm_schedule,
    schedule_from_arrays,
    schedule_to_arrays,
    shards_from_bounds,
    shards_to_bounds,
    structural_product_pattern,
)
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo, to_coo
from repro.sparse.formats import BCSR, BCSV, COO, CSR
from repro.spgemm.cache import PlanCache, default_cache, pattern_digest
from repro.spgemm.executor import (
    CHUNK_BYTES_ENV,
    ShardedSpGEMMExecutor,
    SpGEMMExecutor,
)
from repro.spgemm.pipeline import SpGEMMPipeline, SpGEMMTicket, _Prepared

__all__ = [
    "PlanReport",
    "ShardedSpGEMMPlan",
    "SpGEMMChain",
    "SpGEMMPlan",
    "StructuralPattern",
    "chain_plans",
    "execute_chain",
    "plan_from_structural_pattern",
    "spgemm_plan",
    "resolve_backend",
    "schedule_build_count",
]

# Global count of symbolic-phase runs (schedule constructions). Tests and
# the acceptance criteria assert this stays flat across cached re-executes.
_SCHEDULE_BUILDS = 0


def schedule_build_count() -> int:
    return _SCHEDULE_BUILDS


def resolve_backend(backend: str = "auto") -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "pallas_interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


_REPORT_FIELDS = (
    "pattern_key", "pattern_token", "tile", "group", "backend", "shape",
    "nnz_a", "nnz_b", "nnzb_a", "nnzb_b", "nnzb_c", "num_triples",
    "n_panels", "b_fetches", "block_omar", "schedule_builds", "cache_hits",
    "executes", "loads", "load_hits", "cache_stats", "config_source",
    "tuned",
)


class PlanReport:
    """Structured statistics of one plan: what was built, what it costs,
    and how often it has been reused.

    ``pattern_key``, ``nnz_a``, and ``nnz_b`` may be supplied as zero-arg
    callables: they resolve (and memoize) on first access, so plan paths
    whose report nobody reads — the uncached ``ops.spgemm(..., schedule=)``
    shim — never pay the pattern digest or the ``count_nonzero`` scans.
    """

    def __init__(
        self,
        pattern_key: Union[str, Callable[[], str]],
        tile: Tuple[int, int, int],
        group: int,
        backend: str,
        shape: Tuple[int, int],  # output C shape
        nnz_a: Union[int, Callable[[], int]],
        nnz_b: Union[int, Callable[[], int]],
        nnzb_a: int,
        nnzb_b: int,
        nnzb_c: int,
        num_triples: int,
        n_panels: int,
        b_fetches: int,
        block_omar: float,
        schedule_builds: int = 1,  # symbolic-phase runs for this plan (0
        # when a pre-built schedule was supplied or the plan was loaded
        # from the disk tier, else 1)
        cache_hits: int = 0,  # times this plan was served from a PlanCache
        executes: int = 0,  # numeric-phase runs (value sets, for batches)
        loads: int = 0,  # disk-tier deserializations that built this plan
        # object (1 on a warm restart, 0 on a cold build)
        load_hits: int = 0,  # plan-cache lookups this plan satisfied from
        # the disk tier (the warm-restart acceptance counter)
        cache_stats: Optional[dict] = None,  # serving PlanCache.stats()
        # snapshot, refreshed on every spgemm_plan lookup for this plan
        pattern_token: Optional[str] = None,  # caller-supplied fast cache
        # key (spgemm_plan(..., pattern_token=)); echoed so serving
        # callers can audit which token a plan answers to
        config_source: str = "default",  # where the active exec config
        # came from: "default" (policy table), "tuned" (probed this
        # process), "persisted" (tuned record loaded from disk), or
        # "env-override" (REPRO_SPGEMM_CHUNK_BYTES wins regardless)
        tuned: Optional[dict] = None,  # TunedConfig.to_meta() snapshot of
        # the applied tuned config (None when untuned)
    ):
        self._pattern_key = pattern_key
        self._nnz_a = nnz_a
        self._nnz_b = nnz_b
        self.tile = tuple(tile)
        self.group = group
        self.backend = backend
        self.shape = tuple(shape)
        self.nnzb_a = nnzb_a
        self.nnzb_b = nnzb_b
        self.nnzb_c = nnzb_c
        self.num_triples = num_triples
        self.n_panels = n_panels
        self.b_fetches = b_fetches
        self.block_omar = block_omar
        self.schedule_builds = schedule_builds
        self.cache_hits = cache_hits
        self.executes = executes
        self.loads = loads
        self.load_hits = load_hits
        self.cache_stats = cache_stats
        self.pattern_token = pattern_token
        self.config_source = config_source
        self.tuned = tuned

    @property
    def pattern_key(self) -> str:
        if callable(self._pattern_key):
            self._pattern_key = self._pattern_key()
        return self._pattern_key

    @property
    def nnz_a(self) -> int:
        if callable(self._nnz_a):
            self._nnz_a = self._nnz_a()
        return self._nnz_a

    @property
    def nnz_b(self) -> int:
        if callable(self._nnz_b):
            self._nnz_b = self._nnz_b()
        return self._nnz_b

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in _REPORT_FIELDS}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        lazies = ", ".join(
            f for f, v in (("pattern_key", self._pattern_key),
                           ("nnz_a", self._nnz_a), ("nnz_b", self._nnz_b))
            if callable(v)
        )
        return (f"PlanReport(shape={self.shape}, triples={self.num_triples},"
                f" executes={self.executes}"
                + (f", unresolved=[{lazies}]" if lazies else "") + ")")


class SpGEMMPlan:
    """A fully pre-processed SpGEMM: symbolic phase done, numeric phase
    repeatable — single-shot or batched — with fresh values.

    Build through :func:`spgemm_plan` (cached) or
    :meth:`SpGEMMPlan.from_blocks` (explicit). ``execute`` / ``__call__``
    accept new value sets bound to the *same* sparsity pattern:

    * element plans (built from COO/CSR/dense inputs): ``a_vals`` is a
      ``[nnz_a]`` vector aligned with ``plan.a_pattern`` (canonical
      row-major deduplicated order), likewise ``b_vals``;
    * block plans (built from BCSV/BCSR inputs): ``a_vals`` is a packed
      ``[nnzb_a, bm, bk]`` block array, likewise ``b_vals``.

    Passing ``None`` reuses the values staged at build / last execute.
    ``execute_batch`` takes the same per-set shapes with a leading batch
    axis and runs the whole batch in one vmapped device call.

    Results returned by one plan share the precomputed CSR ``indptr`` /
    ``indices`` arrays (treat them as read-only).
    """

    def __init__(
        self,
        *,
        schedule: SpGEMMSchedule,
        a_blocks: np.ndarray,
        b_blocks: np.ndarray,
        backend: str,
        out_shape: Tuple[int, int],
        report: PlanReport,
        a_scatter: Optional[np.ndarray] = None,
        b_scatter: Optional[np.ndarray] = None,
        a_pattern: Optional[COO] = None,
        b_pattern: Optional[COO] = None,
        assembly: Optional[AssemblyMap] = None,
        output: str = "block",
        compact: Optional[AssemblyMap] = None,
    ):
        if output not in ("block", "compact"):
            raise ValueError(
                f"output must be 'block' or 'compact', got {output!r}"
            )
        self.schedule = schedule
        self.backend = backend
        self.report = report
        self.a_pattern = a_pattern
        self.b_pattern = b_pattern
        self._a_scatter = a_scatter
        self._b_scatter = b_scatter
        self._a_blocks: Optional[np.ndarray] = a_blocks
        self._b_blocks: Optional[np.ndarray] = b_blocks
        # Packed-array geometry survives release_values(): rebinds validate
        # against (and reallocate to) these.
        self._a_shape = tuple(a_blocks.shape)
        self._b_shape = tuple(b_blocks.shape)
        self._a_dtype = a_blocks.dtype
        self._b_dtype = b_blocks.dtype
        self._m, self._n = out_shape
        self._group = schedule.group
        self._bm = int(a_blocks.shape[1]) if a_blocks.ndim == 3 else 0
        self._bn = int(b_blocks.shape[2]) if b_blocks.ndim == 3 else 0
        # Symbolic output structure: C's CSR pattern + the panels->CSR
        # gather map. Computed here (plan build) unless rehydrated from
        # persisted artifacts, consumed on device by the executor — the
        # numeric phase never scans values for structure.
        self.assembly: AssemblyMap = (
            assembly if assembly is not None
            else build_assembly_map(schedule, (self._bm, self._bn), out_shape)
        )
        # Output mode + the element-exact compact map (tentpole). The plan
        # always keeps the block-structural map above (its coverage /
        # race-freedom proofs anchor the verifier); ``output="compact"``
        # additionally precomputes the nnz-exact subset map the executor
        # gathers through instead — explicit zero *block fill* never
        # reaches C. Block plans have no element patterns, so their
        # "element-exact" pattern is the block fill itself: compact
        # degenerates to the block map (documented; the savings come from
        # element plans, where the pattern is real).
        self.output = output
        self.compact: Optional[AssemblyMap] = compact
        if output == "compact" and self.compact is None:
            if a_pattern is not None and b_pattern is not None:
                rows, cols = structural_product_pattern(
                    a_pattern.row, a_pattern.col,
                    b_pattern.row, b_pattern.col,
                    a_pattern.shape, b_pattern.shape,
                )
                self.compact = build_compact_map(self.assembly, rows, cols)
            else:
                self.compact = self.assembly
        # Device-resident numeric executor: schedule + scatter + gather
        # staged to device once; runs the fused rebind/kernel/assembly jit.
        # ``_make_executor`` is the subclass seam — ShardedSpGEMMPlan
        # replaces it with the mesh-partitioned executor.
        self._executor = (
            self._make_executor()
            if schedule.num_triples and self.assembly.nnz
            else None
        )
        # Device block values are staged lazily (first execute) so building
        # a plan never pays H2D for values that are immediately rebound.
        self._a_dev = None
        self._b_dev = None
        # Guards value rebinds + report counters: plans are shared objects
        # (PlanCache returns the same instance to every pattern-equal
        # caller), so concurrent executes must each see a consistent
        # (values, device array) pair.
        self._lock = threading.Lock()
        # Pipeline accounting: steps submitted but not yet collected (or
        # discarded). While nonzero, buffer teardown (release_values /
        # release / cache eviction) refuses — an in-flight step's device
        # work still reads staged constants.
        self._inflight = 0
        self._released = False
        # (weakref-to-cache, key) set by PlanCache on insert; release()
        # evicts through it so a dead plan never stays resident.
        self._cache_ref = None
        # TunedConfig applied by the autotuner (None = policy defaults).
        # Changes only the executor chunk budget and default pipeline
        # depth — never numerics.
        self.tuned_config = None
        # A persisted TunedConfig whose tile/group no longer matches this
        # plan (artifact drift). Recorded instead of raising — the plan
        # runs on policy defaults and the verifier surfaces a finding.
        self._stale_tuned = None
        # Device copy of B's element values, staged lazily by chained
        # executes (stage s >= 2 reuses the plan's own B values against the
        # previous stage's device-resident C values).
        self._b_vals_dev = None

    def _active(self) -> AssemblyMap:
        """The output map results are wrapped in (and the executor gathers
        through): the compact map under ``output="compact"``, else the
        block-structural map."""
        return self.compact if self.output == "compact" else self.assembly

    def _make_executor(self):
        """Build the numeric executor (called once, at plan build)."""
        return SpGEMMExecutor(
            schedule=self.schedule,
            assembly=self._active(),
            backend=self.backend,
            a_scatter=self._a_scatter,
            b_scatter=self._b_scatter,
            a_shape=self._a_shape,
            b_shape=self._b_shape,
        )

    def apply_tuned_config(self, cfg) -> None:
        """Apply an autotuner :class:`~repro.spgemm.autotune.TunedConfig`:
        set the executor's chunk budget and make ``cfg.pipeline_depth``
        the default for :meth:`pipeline` / :meth:`execute_stream`.

        Numerics are untouched — chunk/depth are bitwise-invariant knobs,
        and a config tuned at a different (tile, group) is applied to the
        plan *built at that tile/group* by the autotuner, never here.
        Report provenance: ``config_source`` becomes ``cfg.source``
        (``"tuned"``/``"persisted"``) unless ``REPRO_SPGEMM_CHUNK_BYTES``
        is set, which always wins and keeps ``"env-override"``.

        A config whose (tile, group) does not match this plan is *stale* —
        a persisted sidecar that drifted from the artifact it rode with.
        Drift is not an execution error (the plan is correct on policy
        defaults), so it is recorded instead of raised: the config is
        ignored, ``report.config_source`` becomes ``"stale-tuned"``, and
        :func:`repro.analysis.verify.verify_plan` surfaces a
        ``tuned.stale-config`` finding.
        """
        if tuple(cfg.tile) != tuple(self.report.tile) or (
            int(cfg.group) != int(self.report.group)
        ):
            with self._lock:
                self._stale_tuned = cfg
                self.tuned_config = None
                self.report.tuned = None
                if not os.environ.get(CHUNK_BYTES_ENV):
                    self.report.config_source = "stale-tuned"
            return
        with self._lock:
            self.tuned_config = cfg
            self.report.tuned = cfg.to_meta()
            if os.environ.get(CHUNK_BYTES_ENV):
                self.report.config_source = "env-override"
            else:
                self.report.config_source = (
                    "persisted" if cfg.source == "persisted" else "tuned"
                )
            if self._executor is not None:
                self._executor.set_chunk_bytes(cfg.chunk_bytes)

    def _default_depth(self) -> int:
        cfg = self.tuned_config
        return int(cfg.pipeline_depth) if cfg is not None else 2

    def _stage_a(self, blocks: np.ndarray):
        """Host packed A blocks -> device layout for ``executor.run``.

        copy=True: on CPU backends jnp.asarray may alias the numpy scratch
        buffer, and a later rebind would mutate an earlier caller's staged
        values mid-flight.
        """
        return jnp.array(blocks, copy=True)

    def _stage_b(self, blocks: np.ndarray):
        return jnp.array(blocks, copy=True)

    # -- construction -----------------------------------------------------

    @classmethod
    def from_blocks(
        cls,
        a: BCSV,
        b: BCSR,
        *,
        backend: str = "auto",
        schedule: Optional[SpGEMMSchedule] = None,
        pattern_key: str = "",
        mesh: Optional[Mesh] = None,
        mesh_axis: Optional[str] = None,
        output: str = "block",
    ) -> "SpGEMMPlan":
        """Plan from pre-converted block formats (the ops.spgemm shim path).

        When ``schedule`` is supplied the symbolic phase is skipped entirely
        (and not counted as a build). Report identity/population fields
        (pattern digest, element nnz counts) are lazy — computed only if
        the report is actually read. The thunks pin no operand-sized
        memory: the digest closes over the (small) index arrays only, and
        the nnz counts read the plan's *currently staged* blocks (so they
        raise if resolved after ``release_values``).
        """
        global _SCHEDULE_BUILDS
        backend = resolve_backend(backend)
        built = 0
        if schedule is None:
            schedule = build_spgemm_schedule(a, b)
            _SCHEDULE_BUILDS += 1
            built = 1
        if not pattern_key:
            idx = (a.brow, a.bcol, a.group_ptr, b.indptr, b.indices)
            meta = ("blocks", a.shape, b.shape, a.block_shape,
                    b.block_shape, a.group, str(a.blocks.dtype),
                    str(b.blocks.dtype))

            def pattern_key(idx=idx, meta=meta):
                return pattern_digest(*idx, meta=meta)
        report = _make_report(
            pattern_key,
            (a.block_shape[0], a.block_shape[1], b.block_shape[1]),
            a.group, backend, (a.shape[0], b.shape[1]),
            0, 0,  # placeholders; bound to staged blocks below
            a.nnzb, b.nnzb, schedule,
        )
        report.schedule_builds = built
        plan_cls, extra = _resolve_plan_cls(mesh, mesh_axis)
        plan = plan_cls(
            schedule=schedule,
            a_blocks=a.blocks,
            b_blocks=b.blocks,
            backend=backend,
            out_shape=(a.shape[0], b.shape[1]),
            report=report,
            output=output,
            **extra,
        )
        report._nnz_a = _staged_nnz(plan, "_a_blocks", "nnz_a")
        report._nnz_b = _staged_nnz(plan, "_b_blocks", "nnz_b")
        return plan

    # -- persistence (the disk tier's codec endpoints) --------------------

    def persist_artifacts(self) -> Tuple[dict, dict]:
        """The plan's value-independent symbolic artifacts as
        ``(arrays, meta)`` — the payload the disk tier
        (:class:`repro.spgemm.persist.PlanStore`) writes once per cache
        key.

        ``arrays`` holds only what the symbolic phase computed: the triple
        schedule, the assembly map, and the value-scatter indices (element
        plans; :class:`ShardedSpGEMMPlan` adds its shard bounds). ``meta``
        holds the padding/geometry scalars (packed block-array shapes and
        dtypes, true output shape, tile/group, backend). Values are
        deliberately excluded — a warm restart brings its own.
        """
        arrays = {}
        arrays.update(schedule_to_arrays(self.schedule))
        arrays.update(assembly_to_arrays(self.assembly))
        if self.output == "compact":
            # The compact map rides the same AssemblyMap codec under its
            # own prefix; block artifacts keep their pre-compaction byte
            # layout exactly.
            arrays.update(assembly_to_arrays(self.compact, prefix="casm."))
        if self._a_scatter is not None:
            arrays["a_scatter"] = self._a_scatter
        if self._b_scatter is not None:
            arrays["b_scatter"] = self._b_scatter
        element = self._a_scatter is not None and self._b_scatter is not None
        meta = {
            "kind": "element" if element else "block",
            "output": self.output,
            "backend": self.backend,
            "out_shape": [self._m, self._n],
            "a_shape": list(self._a_shape),
            "b_shape": list(self._b_shape),
            "a_dtype": str(self._a_dtype),
            "b_dtype": str(self._b_dtype),
            "tile": list(self.report.tile),
            "group": self.report.group,
        }
        if self.tuned_config is not None:
            # The tuned exec config rides inside the plan artifact too (in
            # addition to the cache's sidecar record), so a copied/shared
            # artifact file rehydrates fully tuned on its own.
            meta["tuned_config"] = self.tuned_config.to_meta()
        return arrays, meta

    @classmethod
    def from_artifacts(
        cls,
        arrays: dict,
        meta: dict,
        *,
        backend: str,
        pattern_key: Union[str, Callable[[], str]] = "",
        a_vals=None,
        b_vals=None,
        a_blocks: Optional[np.ndarray] = None,
        b_blocks: Optional[np.ndarray] = None,
        a_pattern: Optional[COO] = None,
        b_pattern: Optional[COO] = None,
        mesh: Optional[Mesh] = None,
        mesh_axis: Optional[str] = None,
        output: str = "block",
    ) -> "SpGEMMPlan":
        """Rehydrate a plan from persisted artifacts + this call's values.

        The inverse of :meth:`persist_artifacts`: the symbolic phase is
        **not** re-run (``report.schedule_builds == 0``); the packed block
        arrays are rebuilt by scattering the caller's ``a_vals``/``b_vals``
        through the persisted scatter indices (element plans) or taken
        directly from ``a_blocks``/``b_blocks`` (block plans). Any
        inconsistency between artifacts and inputs raises — the cache
        treats that as an unusable entry and falls back to a cold build.
        """
        backend = resolve_backend(backend)
        kind = meta.get("kind")
        if kind not in ("element", "block"):
            raise ValueError(f"unknown persisted plan kind {kind!r}")
        if meta.get("backend") != backend:
            raise ValueError(
                f"persisted backend {meta.get('backend')!r} != {backend!r}"
            )
        if meta.get("output", "block") != output:
            raise ValueError(
                f"persisted output {meta.get('output', 'block')!r} != "
                f"{output!r}"
            )
        schedule = schedule_from_arrays(arrays)
        assembly = assembly_from_arrays(arrays)
        compact = (
            assembly_from_arrays(arrays, prefix="casm.")
            if output == "compact" else None
        )
        a_shape = tuple(int(x) for x in meta["a_shape"])
        b_shape = tuple(int(x) for x in meta["b_shape"])
        a_dtype = np.dtype(meta["a_dtype"])
        b_dtype = np.dtype(meta["b_dtype"])
        out_shape = tuple(int(x) for x in meta["out_shape"])
        tile = tuple(int(x) for x in meta["tile"])
        group = int(meta["group"])
        a_scatter = arrays.get("a_scatter")
        b_scatter = arrays.get("b_scatter")

        def rebuild(vals, scatter, shape, dtype, name):
            if scatter is None:
                raise ValueError(f"{name}: persisted scatter missing")
            vals = np.asarray(vals)
            scatter = np.asarray(scatter)
            if vals.shape != (int(scatter.shape[0]),):
                raise ValueError(
                    f"{name}: {vals.shape} values vs persisted scatter "
                    f"of {int(scatter.shape[0])}"
                )
            blocks = np.zeros(shape, dtype)
            blocks.reshape(-1)[scatter] = vals.astype(dtype, copy=False)
            return blocks

        if kind == "element":
            if a_vals is None or b_vals is None:
                raise ValueError("element plan needs a_vals/b_vals")
            a_blocks = rebuild(a_vals, a_scatter, a_shape, a_dtype, "a_vals")
            b_blocks = rebuild(b_vals, b_scatter, b_shape, b_dtype, "b_vals")
            nnz_a = int(np.asarray(a_scatter).shape[0])
            nnz_b = int(np.asarray(b_scatter).shape[0])
        else:
            if a_blocks is None or b_blocks is None:
                raise ValueError("block plan needs a_blocks/b_blocks")
            a_blocks = np.asarray(a_blocks)
            b_blocks = np.asarray(b_blocks)
            if tuple(a_blocks.shape) != a_shape or a_blocks.dtype != a_dtype:
                raise ValueError(
                    f"a_blocks {a_blocks.shape}/{a_blocks.dtype} vs "
                    f"persisted {a_shape}/{a_dtype}"
                )
            if tuple(b_blocks.shape) != b_shape or b_blocks.dtype != b_dtype:
                raise ValueError(
                    f"b_blocks {b_blocks.shape}/{b_blocks.dtype} vs "
                    f"persisted {b_shape}/{b_dtype}"
                )
            nnz_a = nnz_b = 0  # bound to staged blocks below (lazy)
        report = _make_report(
            pattern_key, tile, group, backend, out_shape,
            nnz_a, nnz_b, a_shape[0] if a_blocks.ndim == 3 else 0,
            b_shape[0] if b_blocks.ndim == 3 else 0, schedule,
        )
        report.schedule_builds = 0
        report.loads = 1
        report.load_hits = 1
        plan_cls, extra = _resolve_plan_cls(mesh, mesh_axis)
        if mesh is not None and "shard_bounds" in arrays:
            extra["shards"] = shards_from_bounds(
                schedule, arrays["shard_bounds"]
            )
        plan = plan_cls(
            schedule=schedule,
            a_blocks=a_blocks,
            b_blocks=b_blocks,
            backend=backend,
            out_shape=out_shape,
            report=report,
            a_scatter=None if a_scatter is None else np.asarray(a_scatter),
            b_scatter=None if b_scatter is None else np.asarray(b_scatter),
            a_pattern=a_pattern,
            b_pattern=b_pattern,
            assembly=assembly,
            output=output,
            compact=compact,
            **extra,
        )
        if kind == "block":
            report._nnz_a = _staged_nnz(plan, "_a_blocks", "nnz_a")
            report._nnz_b = _staged_nnz(plan, "_b_blocks", "nnz_b")
        tuned_meta = meta.get("tuned_config")
        if tuned_meta is not None:
            # Import here: autotune imports this module at its top level.
            from repro.spgemm.autotune import TunedConfig

            plan.apply_tuned_config(
                TunedConfig.from_meta(dict(tuned_meta), source="persisted")
            )
        return plan

    # -- numeric phase ----------------------------------------------------

    def _rebind(
        self,
        vals,
        blocks: Optional[np.ndarray],
        scatter: Optional[np.ndarray],
        nnz: int,
        name: str,
        shape: Tuple[int, ...],
        dtype,
    ) -> np.ndarray:
        vals = np.asarray(vals)
        if scatter is not None:
            if vals.shape != (nnz,):
                raise ValueError(
                    f"{name}: expected [{nnz}] values in canonical pattern "
                    f"order, got shape {vals.shape}"
                )
            if blocks is None:  # scratch was released; reallocate
                blocks = np.zeros(shape, dtype)
            # Positions outside `scatter` are structurally zero and never
            # written, so in-place rebinding is sound.
            blocks.reshape(-1)[scatter] = vals.astype(blocks.dtype, copy=False)
            return blocks
        if vals.shape != shape:
            raise ValueError(
                f"{name}: expected packed blocks of shape {shape}, "
                f"got {vals.shape}"
            )
        return vals

    def value_shapes(self) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """Per-set operand shapes the numeric phase accepts:
        ``(want_a, want_b)`` — ``[nnz]`` vectors for element plans, packed
        block arrays for block plans. ``execute_batch``/``submit`` take the
        same shapes with a shared leading batch axis. This is the
        validation contract serving front ends (the gateway) check
        requests against before queueing them."""
        if self._a_scatter is not None and self._b_scatter is not None:
            return (self.report.nnz_a,), (self.report.nnz_b,)
        return self._a_shape, self._b_shape

    def value_nbytes(self) -> int:
        """Bytes of one request's operand values (a_vals + b_vals at the
        plan's packed dtypes) — the admission-control unit the gateway's
        in-flight byte budget counts."""
        want_a, want_b = self.value_shapes()
        return (
            int(np.prod(want_a)) * self._a_dtype.itemsize
            + int(np.prod(want_b)) * self._b_dtype.itemsize
        )

    def _empty_csr(self) -> CSR:
        return CSR(
            np.zeros(self._m + 1, np.int64), np.zeros(0, np.int32),
            np.zeros(0, np.float32), (self._m, self._n),
        )

    def _wrap_packed(self, packed: np.ndarray) -> CSR:
        """Packed C values (active-map order) -> CSR on the precomputed
        structure. indptr/indices are shared across this plan's results."""
        asm = self._active()
        return CSR(asm.indptr, asm.indices, packed, (self._m, self._n))

    def output_pattern(self) -> "StructuralPattern":
        """C's value-independent output structure — the seed for the next
        plan in a chain (:func:`plan_from_structural_pattern`). Under
        ``output="compact"`` this is the element-exact pattern; under the
        default block output it is the block-structural pattern (explicit
        zero fill included)."""
        asm = self._active()
        return StructuralPattern(asm.indptr, asm.indices, (self._m, self._n))

    def device_indptr(self):
        """Device-resident CSR ``indptr`` of the active output map (the
        device half of the compaction bookkeeping; see
        :meth:`repro.spgemm.executor.SpGEMMExecutor.device_indptr`).
        Together with a ``_run_packed`` result this is a complete CSR
        replica of C that never leaves the device."""
        if self._executor is None:
            return jnp.asarray(self._active().indptr.astype(np.int32))
        return self._executor.device_indptr()

    def then(self, b, **kwargs) -> "SpGEMMChain":
        """Compose this plan with a next operand: plan ``C @ b`` directly
        from this plan's structural output pattern (no COO conversion of
        C) and return the two-stage :class:`SpGEMMChain`. ``kwargs``
        forward to :func:`plan_from_structural_pattern`; tile/group/
        backend/output default to this plan's own config. Chain further
        with :meth:`SpGEMMChain.then`."""
        return SpGEMMChain([self, self._plan_next(b, **kwargs)])

    def _plan_next(self, b, **kwargs) -> "SpGEMMPlan":
        kwargs.setdefault("tile", self.report.tile)
        kwargs.setdefault("group", self.report.group)
        kwargs.setdefault("backend", self.backend)
        kwargs.setdefault("output", self.output)
        kwargs.setdefault("dtype", self._a_dtype)
        return plan_from_structural_pattern(
            self.output_pattern(), b, **kwargs
        )

    def execute(self, a_vals=None, b_vals=None) -> CSR:
        """Numeric phase only: C = A @ B for fresh values on the planned
        pattern. Zero schedule-construction work; the whole phase (kernel +
        output assembly) runs inside the executor's jit."""
        packed = self._run_packed(a_vals, b_vals)
        if packed is None:
            return self._empty_csr()
        return self._wrap_packed(np.asarray(packed))

    def _run_packed(self, a_vals=None, b_vals=None):
        """``execute``'s device core: dispatch the numeric phase and return
        the packed C values *without* materializing them on host (``None``
        for an empty plan). Single-device plans return a device array —
        the handoff ``execute_chain`` keeps resident between stages;
        sharded plans return host arrays (their executor concatenates
        per-shard segments on host by design)."""
        with self._lock:
            self._check_released()
            # report.nnz_* is read only on the scatter (element-plan) path:
            # block plans keep their lazy count_nonzero report fields
            # unresolved through executes.
            if a_vals is not None:
                self._a_blocks = self._rebind(
                    a_vals, self._a_blocks, self._a_scatter,
                    self.report.nnz_a if self._a_scatter is not None else 0,
                    "a_vals", self._a_shape, self._a_dtype,
                )
                self._a_dev = None
            if b_vals is not None:
                self._b_blocks = self._rebind(
                    b_vals, self._b_blocks, self._b_scatter,
                    self.report.nnz_b if self._b_scatter is not None else 0,
                    "b_vals", self._b_shape, self._b_dtype,
                )
                self._b_dev = None
            if self._a_blocks is None or self._b_blocks is None:
                raise ValueError(
                    "plan values were released (release_values); pass "
                    "a_vals/b_vals to execute"
                )
            # Element plans called with both value vectors take the fully
            # fused device path (rebind + kernel + assembly in one jit):
            # only [nnz] vectors cross to device, not full packed blocks.
            # The host rebind above still ran, so no-arg execute() stays
            # current; device block staging is left to the next such call.
            fused_values = (
                a_vals is not None and b_vals is not None
                and self._a_scatter is not None
                and self._b_scatter is not None
            )
            if fused_values:
                a_send = np.asarray(a_vals, dtype=self._a_dtype)
                b_send = np.asarray(b_vals, dtype=self._b_dtype)
            else:
                if self._a_dev is None:
                    self._a_dev = self._stage_a(self._a_blocks)
                if self._b_dev is None:
                    self._b_dev = self._stage_b(self._b_blocks)
                # Snapshot under the lock so a concurrent rebind on this
                # shared plan cannot mix one caller's A with another's B.
                a_dev, b_dev = self._a_dev, self._b_dev
            self.report.executes += 1

        if self._executor is None:
            return None
        if fused_values:
            return self._executor.run_values(a_send, b_send)
        return self._executor.run(a_dev, b_dev)

    def _run_packed_chained(self, c_packed):
        """Stage ``s >= 2`` of :func:`execute_chain`: the previous stage's
        packed C values (active-map order == canonical row-major element
        order) are this plan's A values, consumed directly on device
        through the fused rebind/kernel/assembly jit — no host transfer.
        B values are the plan's own staged element values, shipped to
        device once and reused across chain executes."""
        if self._a_scatter is None or self._b_scatter is None:
            raise ValueError(
                "chained stages need element plans (built from COO/CSR "
                "inputs or plan_from_structural_pattern)"
            )
        with self._lock:
            self._check_released()
            if self._b_vals_dev is None:
                if self.b_pattern is None:
                    raise ValueError(
                        "chained stage has no B values: the plan was built "
                        "without a B pattern (release_values?); rebuild via "
                        "plan_from_structural_pattern with B in hand"
                    )
                self._b_vals_dev = jnp.asarray(
                    np.asarray(self.b_pattern.val, dtype=self._b_dtype)
                )
            b_dev = self._b_vals_dev
            self.report.executes += 1
        if c_packed is None:  # previous stage was empty: A values all zero
            c_packed = jnp.zeros((self.report.nnz_a,), self._a_dtype)
        if c_packed.shape != (self.report.nnz_a,):
            raise ValueError(
                f"chained values: expected [{self.report.nnz_a}] from the "
                f"previous stage, got shape {tuple(c_packed.shape)}"
            )
        if self._executor is None:
            return None
        return self._executor.run_values(
            c_packed.astype(self._a_dtype), b_dev
        )

    __call__ = execute

    def execute_batch(self, a_vals, b_vals) -> list:
        """Batched numeric phase: one vmapped device call over a leading
        value-batch axis (the serving workload).

        ``a_vals`` is ``[batch, nnz_a]`` for element plans or
        ``[batch, nnzb_a, bm, bk]`` packed blocks for block plans
        (``b_vals`` likewise). Returns a list of ``batch`` CSR results that
        share this plan's precomputed ``indptr``/``indices``.

        Stateless with respect to the plan's staged values: it never touches
        the buffers no-arg ``execute()`` reuses, so it is safe to interleave
        with single executes and works after ``release_values()``. The
        batch honors the plan's backend: pallas plans run the batch-folded
        Pallas grid, jnp plans the offset-folded scatter-add reference —
        both bitwise-equal to looping ``execute`` per element.
        """
        a_vals = np.asarray(a_vals)
        b_vals = np.asarray(b_vals)
        rebind = self._a_scatter is not None and self._b_scatter is not None
        want_a, want_b = self.value_shapes()
        if a_vals.ndim != len(want_a) + 1 or a_vals.shape[1:] != want_a:
            raise ValueError(
                f"a_vals: expected [batch, {', '.join(map(str, want_a))}], "
                f"got shape {a_vals.shape}"
            )
        if b_vals.shape[1:] != want_b or b_vals.shape[0] != a_vals.shape[0]:
            raise ValueError(
                f"b_vals: expected [{a_vals.shape[0]}, "
                f"{', '.join(map(str, want_b))}], got shape {b_vals.shape}"
            )
        batch = int(a_vals.shape[0])
        with self._lock:
            self._check_released()
            self.report.executes += batch
        if batch == 0:
            return []
        if self._executor is None:
            return [self._empty_csr() for _ in range(batch)]
        # Match execute()'s rebind semantics: values are cast to the plan's
        # packed dtype.
        a_vals = a_vals.astype(self._a_dtype, copy=False)
        b_vals = b_vals.astype(self._b_dtype, copy=False)
        # Oversized batches are split so the device accumulator working set
        # stays cache-resident (see SpGEMMExecutor.batch_chunk); each chunk
        # is still one fused device call.
        chunk = min(batch, self._executor.batch_chunk())
        out = []
        for lo in range(0, batch, chunk):
            hi = min(lo + chunk, batch)
            # Host slices go down as-is: the executor owns device layout
            # (plain jnp.asarray unsharded; per-shard slicing + mesh
            # placement on sharded plans).
            packed = np.asarray(
                self._executor.run_batch(
                    a_vals[lo:hi], b_vals[lo:hi], rebind=rebind,
                )
            )
            out.extend(self._wrap_packed(packed[i]) for i in range(hi - lo))
        return out

    # -- async serving (the stage-split pipeline surface) ------------------

    def pipeline(self, depth: Optional[int] = None) -> SpGEMMPipeline:
        """A bounded-depth submit/collect pipeline over this plan.

        ``depth=None`` takes the plan's tuned pipeline depth when an
        autotuner config is applied, else 2 — the paper's double buffer:
        one step staging (H2D + rebind) while one computes. See
        :class:`repro.spgemm.pipeline.SpGEMMPipeline`."""
        return SpGEMMPipeline(
            self, depth=self._default_depth() if depth is None else depth
        )

    def execute_async(self, a_vals=None, b_vals=None) -> SpGEMMTicket:
        """Dispatch one numeric phase without blocking; redeem the
        returned ticket with ``.result()``.

        Same operand shapes as ``execute`` (a leading batch axis makes
        the ticket redeem to ``execute_batch``'s list-of-CSR output).
        Each call is its own depth-1 pipeline — in-flight count is
        caller-managed; use :meth:`pipeline` for bounded-depth serving.
        """
        return SpGEMMPipeline(self, depth=1).submit(a_vals, b_vals)

    def execute_stream(self, value_iter, *, depth: Optional[int] = None):
        """Stream value sets through a ``depth``-deep pipeline, yielding
        one CSR per item in order (``depth=None``: the tuned depth if an
        autotuner config is applied, else 2).

        ``value_iter`` yields ``(a_vals, b_vals)`` tuples or ``{"a_vals",
        "b_vals"}`` dicts — e.g.
        :meth:`repro.data.pipeline.SpGEMMValueStream.value_iter`. Results
        are bitwise-equal to calling ``execute`` per item; step ``s+1``'s
        staging overlaps step ``s``'s kernel throughout."""
        return self.pipeline(depth).stream(value_iter)

    @property
    def in_flight(self) -> int:
        """Pipeline steps submitted against this plan and not yet
        collected (or discarded). Buffer teardown refuses while > 0."""
        with self._lock:
            return self._inflight

    def _check_released(self) -> None:
        """Call under ``self._lock``."""
        if self._released:
            raise RuntimeError(
                "plan was released (release()); build or fetch a new plan"
            )

    def _check_no_inflight(self, what: str) -> None:
        """Call under ``self._lock``."""
        if self._inflight:
            raise RuntimeError(
                f"cannot {what}: {self._inflight} in-flight pipeline "
                f"step(s) still read this plan's staged buffers; collect "
                f"the tickets or close the pipeline first"
            )

    def _pipe_check(self, a_vals, b_vals) -> _Prepared:
        """Validate one submission and prepare its operands (host work +
        plan-state snapshot only; no device compute is dispatched).

        Stateless w.r.t. the plan's staged values — explicit operands
        never touch the buffers no-arg ``execute()`` reuses — except that
        the no-arg form stages (and caches) the plan's own values exactly
        like ``execute()`` does."""
        if (a_vals is None) != (b_vals is None):
            raise ValueError(
                "submit takes both a_vals and b_vals, or neither "
                "(to reuse the plan's staged values)"
            )
        if a_vals is None:
            with self._lock:
                self._check_released()
                if self._a_blocks is None or self._b_blocks is None:
                    raise ValueError(
                        "plan values were released (release_values); pass "
                        "a_vals/b_vals to submit"
                    )
                if self._executor is not None:
                    if self._a_dev is None:
                        self._a_dev = self._stage_a(self._a_blocks)
                    if self._b_dev is None:
                        self._b_dev = self._stage_b(self._b_blocks)
                return _Prepared("blocks", self._a_dev, self._b_dev,
                                 None, 1)
        with self._lock:
            self._check_released()
        a_vals = np.asarray(a_vals)
        b_vals = np.asarray(b_vals)
        rebind = self._a_scatter is not None and self._b_scatter is not None
        want_a, want_b = self.value_shapes()
        single = a_vals.shape == want_a and b_vals.shape == want_b
        batched = (
            a_vals.ndim == len(want_a) + 1 and a_vals.shape[1:] == want_a
            and b_vals.shape[:1] == a_vals.shape[:1]
            and b_vals.shape[1:] == want_b
        )
        if not (single or batched):
            raise ValueError(
                f"submit: expected a_vals {want_a} / b_vals {want_b} "
                f"(optionally with a shared leading batch axis), got "
                f"{a_vals.shape} / {b_vals.shape}"
            )
        a_vals = a_vals.astype(self._a_dtype, copy=False)
        b_vals = b_vals.astype(self._b_dtype, copy=False)
        if single:
            if rebind:
                return _Prepared("values", a_vals, b_vals, None, 1)
            # Packed-block operands: stage now (copy-on-stage, the
            # executor's device layout) so the caller may reuse buffers.
            return _Prepared(
                "blocks", self._stage_a(a_vals), self._stage_b(b_vals),
                None, 1,
            )
        mode = "batch_values" if rebind else "batch_blocks"
        batch = int(a_vals.shape[0])
        return _Prepared(mode, a_vals, b_vals, batch, batch)

    def _pipe_begin(self, n_execs: int) -> None:
        with self._lock:
            self._check_released()
            self.report.executes += n_execs
            self._inflight += 1

    def _pipe_end(self) -> None:
        with self._lock:
            self._inflight -= 1

    def _pipe_dispatch(self, prep: _Prepared):
        """Dispatch one prepared step's device work (stage -> kernel ->
        assemble) without blocking; returns the packed device result (a
        list of per-chunk results for batch submissions)."""
        if self._executor is None or (prep.batch == 0):
            return None
        ex = self._executor
        if prep.batch is None:
            staged = (
                (prep.a, prep.b) if prep.mode == "blocks"
                else ex.pipe_stage(prep.a, prep.b, mode=prep.mode)
            )
            panels = ex.pipe_kernel(staged, mode="single")
            return ex.pipe_assemble(panels, mode="single")
        # Batch submissions chunk exactly like execute_batch, so the
        # device accumulator working set stays cache-resident; each chunk
        # is dispatched back-to-back (still zero host blocking).
        chunk = min(prep.batch, ex.batch_chunk())
        out = []
        for lo in range(0, prep.batch, chunk):
            hi = min(lo + chunk, prep.batch)
            staged = ex.pipe_stage(
                prep.a[lo:hi], prep.b[lo:hi], mode=prep.mode)
            panels = ex.pipe_kernel(staged, mode="batch")
            out.append(ex.pipe_assemble(panels, mode="batch"))
        return out

    def _pipe_collect(self, prep: _Prepared, packed):
        """Materialize one dispatched step on host (the blocking D2H) and
        wrap it in the plan's precomputed CSR structure."""
        if prep.batch is None:
            if self._executor is None:
                return self._empty_csr()
            return self._wrap_packed(
                self._executor.pipe_collect(packed, mode="single"))
        if self._executor is None:
            return [self._empty_csr() for _ in range(prep.batch)]
        out = []
        for chunk_packed in (packed or ()):
            arr = self._executor.pipe_collect(chunk_packed, mode="batch")
            out.extend(self._wrap_packed(arr[i])
                       for i in range(arr.shape[0]))
        return out

    # -- teardown ----------------------------------------------------------

    def release_device_values(self) -> None:
        """Drop only the staged device copies of the packed block values.

        The next execute restages from the host arrays on demand. Refuses
        while pipeline steps are in flight (they read these buffers).
        """
        with self._lock:
            self._check_no_inflight("release device values")
            self._a_dev = None
            self._b_dev = None
            self._b_vals_dev = None

    def release_values(self) -> None:
        """Drop host AND device copies of the packed block values.

        Cached plans outlive individual calls; one-shot callers (the
        ``ops.spgemm`` shim) release values after executing so a warm
        cache pins only the pattern state (schedule, scatter indices,
        assembly map) — not operand-sized value arrays. After release,
        ``execute`` requires explicit ``a_vals``/``b_vals``
        (``execute_batch`` is unaffected — it never reads staged values).
        Refuses while pipeline steps are in flight.
        """
        with self._lock:
            self._check_no_inflight("release values")
            self._a_dev = None
            self._b_dev = None
            self._b_vals_dev = None
            self._a_blocks = None
            self._b_blocks = None

    def release(self) -> None:
        """Full teardown: values (host + device) AND the executor's
        device-resident constants. The plan is dead afterwards — any
        execute/submit raises — and it evicts itself from the cache that
        holds it, so the next ``spgemm_plan`` for this pattern builds (or
        disk-loads) a fresh plan instead of hitting the dead one. Refuses
        while pipeline steps are in flight; serving operators drain or
        ``close()`` pipelines first.
        """
        with self._lock:
            self._check_no_inflight("release plan")
            self._released = True
            self._a_dev = None
            self._b_dev = None
            self._b_vals_dev = None
            self._a_blocks = None
            self._b_blocks = None
            self._executor = None
            ref = self._cache_ref
        # Self-evict outside the plan lock (eviction re-checks in_flight,
        # which takes it). in_flight is 0 and submits now refuse, so the
        # guarded evict cannot race back to RuntimeError.
        if ref is not None:
            cache = ref[0]()
            if cache is not None:
                cache.evict(ref[1], only=self)

    def host_nbytes(self) -> int:
        """Approximate bytes of host arrays this plan retains — the sizing
        basis for :class:`~repro.spgemm.cache.PlanCache` byte budgets."""
        sch = self.schedule
        arrays = [
            sch.a_slot, sch.b_slot, sch.panel, sch.sub_row, sch.start,
            sch.panel_group, sch.panel_bcol, sch.c_brow, sch.c_bcol,
        ]
        with self._lock:
            arrays += [self._a_blocks, self._b_blocks]
        arrays += [self._a_scatter, self._b_scatter]
        for pat in (self.a_pattern, self.b_pattern):
            if pat is not None:
                arrays += [pat.row, pat.col, pat.val]
        compact = self.compact.nbytes() if self.compact is not None else 0
        return self.assembly.nbytes() + compact + sum(
            a.nbytes for a in arrays if a is not None
        )


class ShardedSpGEMMPlan(SpGEMMPlan):
    """A mesh-aware :class:`SpGEMMPlan`: the panel schedule is partitioned
    across the devices of one mesh axis and the numeric phase runs as a
    single ``shard_map`` call.

    Construction (via ``spgemm_plan(..., mesh=...)``) partitions the
    symbolic schedule at block-row-group boundaries balanced by **triple
    count** (:func:`~repro.core.schedule.partition_spgemm_schedule`), builds
    each shard's own :class:`~repro.core.schedule.AssemblyMap` slice, and
    stages each shard's packed A blocks / schedule / gather map on its own
    device (B replicated). ``execute`` / ``execute_batch`` keep the exact
    single-device semantics — same lock / staged-value / copy-on-stage
    behavior, same structural CSR output sharing the plan-wide
    ``indptr``/``indices`` — because C's per-shard segments are contiguous
    row ranges: the final CSR data is one concatenation along the
    precomputed indptr boundaries.
    """

    def __init__(
        self,
        *,
        mesh: Mesh,
        mesh_axis: Optional[str] = None,
        shards: Optional[List[ScheduleShard]] = None,
        **kw,
    ):
        if mesh_axis is None:
            mesh_axis = mesh.axis_names[0]
        if mesh_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has no axis {mesh_axis!r}: {mesh.axis_names}"
            )
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.n_shards = int(mesh.shape[mesh_axis])
        # ``shards`` is the persistence seam: a rehydrated plan passes the
        # deserialized partition here so _make_executor skips the
        # partitioner along with the rest of the symbolic phase.
        self._preloaded_shards = shards
        self._shards: List[ScheduleShard] = []
        self._shard_assemblies: List[AssemblyMap] = []
        self._shard_compacts: List[AssemblyMap] = []
        super().__init__(**kw)

    def _make_executor(self):
        if self._preloaded_shards is not None:
            if len(self._preloaded_shards) != self.n_shards:
                raise ValueError(
                    f"{len(self._preloaded_shards)} persisted shards for "
                    f"a {self.n_shards}-device mesh axis"
                )
            self._shards = self._preloaded_shards
        else:
            self._shards = partition_spgemm_schedule(
                self.schedule, self.n_shards
            )
        bm, bn, g = self._bm, self._bn, self._group
        for sh in self._shards:
            row_lo = min(sh.group_lo * g * bm, self._m)
            row_hi = min(sh.group_hi * g * bm, self._m)
            self._shard_assemblies.append(build_assembly_map(
                sh.schedule, (bm, bn), (row_hi - row_lo, self._n)
            ))
        if sum(a.nnz for a in self._shard_assemblies) != self.assembly.nnz:
            raise AssertionError(
                "shard assembly slices do not cover the plan assembly"
            )
        # Compact output: each shard gathers through its own slice of the
        # element-exact pattern (subset of its block map, rows rebased to
        # the shard). Shard row ranges are contiguous, so the plan-wide
        # compact rows split into per-shard runs by searchsorted; the
        # executor's pad-trim/concat bookkeeping then counts compact nnz.
        active_assemblies = self._shard_assemblies
        if self.output == "compact":
            rows_c = np.repeat(
                np.arange(self._m, dtype=np.int64),
                np.diff(self.compact.indptr),
            )
            self._shard_compacts = []
            for sh, asm in zip(self._shards, self._shard_assemblies):
                row_lo = min(sh.group_lo * g * bm, self._m)
                row_hi = min(sh.group_hi * g * bm, self._m)
                lo, hi = np.searchsorted(rows_c, [row_lo, row_hi])
                self._shard_compacts.append(build_compact_map(
                    asm, rows_c[lo:hi] - row_lo,
                    self.compact.indices[lo:hi],
                ))
            if sum(a.nnz for a in self._shard_compacts) != self.compact.nnz:
                raise AssertionError(
                    "shard compact slices do not cover the compact map"
                )
            active_assemblies = self._shard_compacts
        a_val_bounds = None
        if self._a_scatter is not None:
            # Element values are canonical row-major, and shards own
            # contiguous row ranges: each shard's A values are one slice.
            a_val_bounds = np.concatenate([
                np.searchsorted(
                    self.a_pattern.row,
                    [sh.group_lo * g * bm for sh in self._shards],
                ),
                [self.a_pattern.nnz],
            ]).astype(np.int64)
        return ShardedSpGEMMExecutor(
            shards=self._shards,
            assemblies=active_assemblies,
            mesh=self.mesh,
            axis=self.mesh_axis,
            backend=self.backend,
            a_scatter=self._a_scatter,
            b_scatter=self._b_scatter,
            a_shape=self._a_shape,
            b_shape=self._b_shape,
            a_val_bounds=a_val_bounds,
        )

    def _stage_a(self, blocks: np.ndarray):
        if self._executor is None:  # empty plan: nothing to lay out
            return jnp.array(blocks, copy=True)
        return self._executor.stage_a(blocks)

    def _stage_b(self, blocks: np.ndarray):
        if self._executor is None:
            return jnp.array(blocks, copy=True)
        return self._executor.stage_b(blocks)

    def shard_stats(self) -> dict:
        """Per-shard load profile: triple/panel/nnz counts plus the
        max/mean triple-count imbalance the partitioner achieved."""
        triples = [sh.num_triples for sh in self._shards]
        mean = sum(triples) / max(len(triples), 1)
        return {
            "n_shards": self.n_shards,
            "mesh_axis": self.mesh_axis,
            "triples": triples,
            "panels": [sh.n_panels for sh in self._shards],
            "nnz_c": [a.nnz for a in self._shard_assemblies],
            "imbalance": (max(triples) / mean) if mean else 0.0,
        }

    def host_nbytes(self) -> int:
        return super().host_nbytes() + sum(
            a.nbytes()
            for a in self._shard_assemblies + self._shard_compacts
        )

    def persist_artifacts(self) -> Tuple[dict, dict]:
        """Adds the shard partition to the base artifacts: the group-bound
        vector alone reconstructs every :class:`ScheduleShard` slice
        bitwise (see :func:`repro.core.schedule.shards_from_bounds`), so
        per-shard executors rebuild from deserialized constants without
        re-running the partitioner. Empty plans (no executor, no shards)
        persist without bounds and re-partition trivially on load."""
        arrays, meta = super().persist_artifacts()
        if self._shards:
            arrays["shard_bounds"] = shards_to_bounds(self._shards)
        meta["n_shards"] = self.n_shards
        meta["mesh_axis"] = self.mesh_axis
        return arrays, meta


def _resolve_plan_cls(mesh: Optional[Mesh], mesh_axis: Optional[str]):
    """(plan class, extra ctor kwargs) for an optional mesh."""
    if mesh is None:
        return SpGEMMPlan, {}
    return ShardedSpGEMMPlan, {"mesh": mesh, "mesh_axis": mesh_axis}


def _mesh_key(mesh: Optional[Mesh], mesh_axis: Optional[str]):
    """Cache-key component for the mesh/shard axis: plans stage per-shard
    constants on concrete devices, so the key pins axis name, shard count,
    and device identity. ``None`` for single-device plans keeps every
    pre-mesh cache key shape unchanged."""
    if mesh is None:
        return None
    axis = mesh_axis if mesh_axis is not None else mesh.axis_names[0]
    return (axis, int(mesh.shape[axis]),
            tuple(int(d.id) for d in np.ravel(mesh.devices)))


def _coo_is_canonical(coo: COO) -> bool:
    """True when the COO is in canonical order: strictly increasing
    row-major (row, col) keys — sorted, deduplicated. O(nnz) vectorized,
    far cheaper than the sort ``sum_duplicates`` pays."""
    key = coo.row.astype(np.int64) * int(coo.shape[1]) + coo.col
    return bool(np.all(np.diff(key) > 0))


def _canonical_coo(coo: COO) -> COO:
    """The COO in canonical order, paying the sort only when needed."""
    return coo if _coo_is_canonical(coo) else coo.sum_duplicates()


def _value_dtype(x):
    """The value dtype of any plan input, or ``None`` if unreadable."""
    if x is None:
        return None
    v = getattr(x, "val", None)  # COO/CSR/CSC/CSV
    if v is not None:
        return np.asarray(v).dtype
    blocks = getattr(x, "blocks", None)  # BCSV/BCSR
    if blocks is not None:
        return np.asarray(blocks).dtype
    if isinstance(x, np.ndarray):
        return x.dtype
    return None


def _staged_nnz(plan: "SpGEMMPlan", attr: str, field: str):
    """Lazy element-count resolver reading the plan's staged blocks —
    holds no reference to operand arrays beyond what the plan itself
    stages, so unread reports cannot pin memory past release_values()."""
    def resolve() -> int:
        blocks = getattr(plan, attr)
        if blocks is None:
            raise ValueError(
                f"{field}: plan values were released before the lazy "
                f"report field was read"
            )
        return int(np.count_nonzero(blocks))

    return resolve


def _make_report(
    pattern_key, tile, group, backend, shape, nnz_a, nnz_b, nnzb_a, nnzb_b,
    schedule: SpGEMMSchedule,
) -> PlanReport:
    return PlanReport(
        pattern_key=pattern_key,
        tile=tuple(tile),
        group=group,
        backend=backend,
        shape=shape,
        nnz_a=nnz_a,
        nnz_b=nnz_b,
        nnzb_a=nnzb_a,
        nnzb_b=nnzb_b,
        nnzb_c=schedule.nnzb_c,
        num_triples=schedule.num_triples,
        n_panels=schedule.n_panels,
        b_fetches=schedule.b_fetches(),
        block_omar=schedule.block_omar(),
        # An operator env override beats everything (resolve_chunk_bytes);
        # the report says so up front rather than claiming "default".
        config_source=(
            "env-override" if os.environ.get(CHUNK_BYTES_ENV) else "default"
        ),
    )


def _block_pattern_key(a: BCSV, b: BCSR) -> str:
    return pattern_digest(
        a.brow, a.bcol, a.group_ptr, b.indptr, b.indices,
        meta=("blocks", a.shape, b.shape, a.block_shape, b.block_shape,
              a.group, str(a.blocks.dtype), str(b.blocks.dtype)),
    )


def _normalize_tile(tile: Union[int, Tuple[int, ...]]) -> Tuple[int, int, int]:
    if isinstance(tile, int):
        return (tile, tile, tile)
    tile = tuple(int(t) for t in tile)
    if len(tile) == 2:
        return (tile[0], tile[1], tile[1])
    if len(tile) != 3:
        raise ValueError(f"tile must be int, (bm, bk) or (bm, bk, bn); got {tile}")
    return tile


def _deep_verify(plan) -> None:
    """``validate="deep"``: run the full static verifier on ``plan``.

    Raises :class:`repro.analysis.verify.PlanVerificationError` (an
    ``AssertionError``) when any invariant fails. Called *inside* the
    disk-rehydrate loaders, the raise is swallowed by the cache's loader
    fallback (``load_failures``) and the plan is rebuilt symbolically —
    a corrupted-but-digest-valid artifact fails verification, never
    executes. Called on a fresh build or memory hit, the raise
    propagates to the caller."""
    from repro.analysis.verify import verify_plan

    verify_plan(plan).raise_if_failed()


def _loaded_block_plan(arrays, meta, a, b, *, backend, pattern_key,
                       mesh, mesh_axis, validate=None, output="block"):
    """Block-path disk rehydrate (+ optional deep verification)."""
    plan = SpGEMMPlan.from_artifacts(
        arrays, meta, backend=backend, pattern_key=pattern_key,
        a_blocks=a.blocks, b_blocks=b.blocks,
        mesh=mesh, mesh_axis=mesh_axis, output=output,
    )
    if validate == "deep":
        _deep_verify(plan)
    return plan


def _token_disk_loader(a, b, backend, mesh, mesh_axis, validate=None,
                       output="block"):
    """The loader :meth:`PlanCache.token_disk_get` rehydrates through.

    The whole point of the disk alias is to skip the pattern digest, so
    the loader validates this call's operands against the *persisted*
    meta instead: value dtypes must match exactly (``from_artifacts``
    would silently cast), input types must match the persisted plan kind,
    and ``from_artifacts`` itself re-checks element counts / block
    geometry. Any mismatch raises -> ``load_failures`` -> the caller
    falls back to the digest path, which settles conflicts explicitly.
    """

    def load(key: Tuple, arrays: dict, meta: dict) -> SpGEMMPlan:
        kind = meta.get("kind")
        if kind == "element" and isinstance(a, COO) and isinstance(b, COO):
            if (str(np.asarray(a.val).dtype) != meta["a_dtype"]
                    or str(np.asarray(b.val).dtype) != meta["b_dtype"]):
                raise ValueError("value dtype differs from persisted plan")
            a_c, b_c = _canonical_coo(a), _canonical_coo(b)
            plan = SpGEMMPlan.from_artifacts(
                arrays, meta, backend=backend, pattern_key=key[0],
                a_vals=a_c.val, b_vals=b_c.val,
                a_pattern=a_c, b_pattern=b_c,
                mesh=mesh, mesh_axis=mesh_axis, output=output,
            )
            if validate == "deep":
                _deep_verify(plan)
            return plan
        if kind == "block" and isinstance(a, BCSV) and isinstance(b, BCSR):
            if (str(a.blocks.dtype) != meta["a_dtype"]
                    or str(b.blocks.dtype) != meta["b_dtype"]):
                raise ValueError("block dtype differs from persisted plan")
            plan = SpGEMMPlan.from_artifacts(
                arrays, meta, backend=backend, pattern_key=key[0],
                a_blocks=a.blocks, b_blocks=b.blocks,
                mesh=mesh, mesh_axis=mesh_axis, output=output,
            )
            if validate == "deep":
                _deep_verify(plan)
            return plan
        raise ValueError(
            f"input types {type(a).__name__}/{type(b).__name__} do not "
            f"match persisted plan kind {kind!r}"
        )

    return load


PlanInput = Union[np.ndarray, COO, CSR, BCSV, BCSR]


def spgemm_plan(
    a,
    b,
    *,
    tile: Union[int, Tuple[int, ...]] = 64,
    group: int = 4,
    backend: str = "auto",
    cache: Optional[PlanCache] = None,
    mesh: Optional[Mesh] = None,
    mesh_axis: Optional[str] = None,
    pattern_token: Optional[str] = None,
    autotune: Union[bool, dict, None] = None,
    validate: Optional[str] = None,
    output: str = "block",
) -> SpGEMMPlan:
    """Build — or fetch from the plan cache — an :class:`SpGEMMPlan`.

    ``a``/``b`` may be dense arrays, any element-level sparse format
    (COO/CSR/CSC/CSV), or pre-converted BCSV/BCSR blocks (in which case
    ``tile``/``group`` are taken from the formats themselves). All symbolic
    work happens here, once per distinct
    ``(pattern, tile, group, backend, mesh shard axis)``.

    Pass ``mesh`` (e.g. from :func:`repro.launch.mesh.make_shard_mesh`) to
    get a :class:`ShardedSpGEMMPlan` whose panel schedule is partitioned
    over ``mesh_axis`` (default: the mesh's first axis); ``mesh=None`` is
    the unchanged single-device path. Pass ``cache=PlanCache(...)`` to
    isolate from the process-level cache.

    ``pattern_token`` is the serving warm path's fast key: a caller's
    name for the sparsity pattern (e.g. a model/layer id). On a cache hit
    the token resolves the plan directly — no ``to_coo``
    canonicalization, no pattern digest, which is most of the warm path's
    host cost on large patterns. The token is the caller's *claim* of
    pattern equality: it is validated against the digest whenever both
    are present (the first build, and any later digest-path lookup —
    binding one token to two different patterns/configs raises), and
    echoed in ``report.pattern_token``. On a token hit, values are
    rebound only when ``a``/``b`` are :class:`COO` inputs (canonical
    row-major order is verified in O(nnz) and restored by a sort only
    when an input needs it; an element-count mismatch raises); other
    input types are returned with whatever values the plan has staged —
    serving callers pass fresh values to ``execute``/``submit`` anyway.
    A value-dtype mismatch never hits the token: it falls through to the
    digest path, which raises the token conflict instead of silently
    casting. ``a=None, b=None`` with a token is a pure lookup (raises
    ``KeyError`` on a miss).

    With the disk tier enabled, a token miss with operands in hand also
    consults the store's persisted token-alias index before falling back
    to the digest path: a restarted worker's first ``spgemm_plan`` call
    resolves token -> full key -> disk artifacts without ever paying the
    COO pattern digest (``stats.token_disk_hits``).

    ``autotune=True`` (or a dict of
    :func:`repro.spgemm.autotune.autotune_plan` keyword overrides, e.g.
    ``{"repeats": 5}``) runs the per-pattern config search — or loads
    its persisted result with zero probes — and returns the winning plan
    with its :class:`~repro.spgemm.autotune.TunedConfig` applied.

    ``validate="deep"`` opts this call into full static verification
    (:func:`repro.analysis.verify.verify_plan`): the returned plan —
    fresh build, cache hit, or disk rehydrate — has every schedule,
    assembly, race-freedom, and shard-partition invariant checked, and a
    failure raises :class:`~repro.analysis.verify.PlanVerificationError`.
    Disk rehydrates are verified *inside* the loader, so a
    corrupted-but-digest-valid artifact counts as a ``load_failure`` and
    falls back to a clean symbolic rebuild instead of executing.

    ``output="compact"`` selects the element-exact (nnz-compacted) output
    path: the plan additionally precomputes the compact gather map and
    results store only C's true structural nonzeros — no explicit zero
    block fill. The default ``output="block"`` is bitwise-unchanged from
    the pre-compaction behavior (same keys, same artifacts, same CSR).
    Compact plans live under their own cache keys (the base key suffixed
    ``"compact"``), so both modes of one pattern can be resident at once.
    """
    global _SCHEDULE_BUILDS
    if validate not in (None, "deep"):
        raise ValueError(
            f"validate must be None or 'deep', got {validate!r}"
        )
    if output not in ("block", "compact"):
        raise ValueError(
            f"output must be 'block' or 'compact', got {output!r}"
        )
    if autotune and output != "block":
        raise ValueError(
            "autotune composes with output='block' only: tune the block "
            "plan, then request output='compact' separately (tuned knobs "
            "are output-independent)"
        )
    if autotune:
        from repro.spgemm.autotune import autotune_plan

        spec = dict(autotune) if isinstance(autotune, dict) else {}
        plan = autotune_plan(
            a, b, tile=tile, group=group, backend=backend, cache=cache,
            mesh=mesh, mesh_axis=mesh_axis, pattern_token=pattern_token,
            **spec,
        )
        # The tuned plan is verified post-hoc (the search itself builds
        # candidates through this function without `validate`).
        if validate == "deep":
            _deep_verify(plan)
        return plan
    backend = resolve_backend(backend)
    if cache is None:
        cache = default_cache()
    shard_key = _mesh_key(mesh, mesh_axis)
    # Compact plans get their own keys by suffix; block keys (and thus
    # every pre-compaction persisted artifact) are byte-identical.
    out_key = ("compact",) if output == "compact" else ()

    token_key = None
    if pattern_token is not None:
        token_key = ("token", str(pattern_token), _normalize_tile(tile),
                     int(group), backend, shard_key) + out_key
        plan = cache.token_get(token_key)
        # Value dtype is part of the full (digest) key but not the token
        # key — a dtype mismatch must not be served (and silently cast) by
        # the token hit. Fall through to the digest path instead, where
        # token_bind raises the conflict explicitly.
        if plan is not None:
            dt_a, dt_b = _value_dtype(a), _value_dtype(b)
            if ((dt_a is not None and dt_a != plan._a_dtype)
                    or (dt_b is not None and dt_b != plan._b_dtype)):
                plan = None
        if plan is None and a is not None and b is not None:
            # Warm restart: the in-memory token map is empty but the
            # store's alias index may resolve the token straight to a
            # disk load — no canonicalization or digest unless needed.
            plan, fresh = cache.token_disk_get(
                token_key,
                _token_disk_loader(a, b, backend, mesh, mesh_axis,
                                   validate=validate, output=output),
            )
            if fresh:
                # Values were bound by the loader; nothing to rebind.
                plan.report.pattern_token = str(pattern_token)
                plan.report.cache_stats = cache.stats()
                return plan
            if plan is not None:
                dt_a, dt_b = _value_dtype(a), _value_dtype(b)
                if ((dt_a is not None and dt_a != plan._a_dtype)
                        or (dt_b is not None and dt_b != plan._b_dtype)):
                    plan = None
        if plan is not None:
            element = (plan._a_scatter is not None
                       and plan._b_scatter is not None)
            with plan._lock:
                plan.report.cache_hits += 1
                if a is None and b is None:
                    pass  # pure lookup: staged values stay as they are
                elif (element
                        and isinstance(a, COO) and isinstance(b, COO)):
                    # Scatter indices assume canonical row-major order;
                    # verify it (O(nnz)) and pay the canonicalizing sort
                    # only for inputs that need it. An element-count
                    # mismatch means the token named a different pattern
                    # — refuse rather than stage garbage.
                    a_c, b_c = _canonical_coo(a), _canonical_coo(b)
                    if (a_c.nnz != plan.report.nnz_a
                            or b_c.nnz != plan.report.nnz_b):
                        raise ValueError(
                            f"pattern_token {pattern_token!r}: input nnz "
                            f"({a_c.nnz}, {b_c.nnz}) does not match the "
                            f"token's plan ({plan.report.nnz_a}, "
                            f"{plan.report.nnz_b}); the token must name "
                            f"this exact sparsity pattern"
                        )
                    plan._a_blocks = plan._rebind(
                        a_c.val, plan._a_blocks, plan._a_scatter,
                        plan.report.nnz_a, "a_vals", plan._a_shape,
                        plan._a_dtype,
                    )
                    plan._a_dev = None
                    plan._b_blocks = plan._rebind(
                        b_c.val, plan._b_blocks, plan._b_scatter,
                        plan.report.nnz_b, "b_vals", plan._b_shape,
                        plan._b_dtype,
                    )
                    plan._b_dev = None
                elif (not element
                        and isinstance(a, BCSV) and isinstance(b, BCSR)):
                    # Block plans: mirror the digest hit path's rebind of
                    # this call's packed blocks (geometry-checked — a
                    # shape mismatch means the token lied).
                    if (tuple(a.blocks.shape) != plan._a_shape
                            or tuple(b.blocks.shape) != plan._b_shape):
                        raise ValueError(
                            f"pattern_token {pattern_token!r}: packed "
                            f"block shapes {a.blocks.shape}/"
                            f"{b.blocks.shape} do not match the token's "
                            f"plan {plan._a_shape}/{plan._b_shape}"
                        )
                    plan._a_blocks = a.blocks
                    plan._b_blocks = b.blocks
                    plan._a_dev = None
                    plan._b_dev = None
                else:
                    # Any other input type would silently keep the
                    # previous caller's staged values — refuse instead
                    # (the digest path, which converts anything, is one
                    # dropped kwarg away).
                    raise ValueError(
                        f"pattern_token {pattern_token!r}: the token fast "
                        f"path rebinds values only for COO (element "
                        f"plans) or BCSV/BCSR (block plans) inputs, or "
                        f"a=b=None for a pure lookup; got "
                        f"{type(a).__name__}/{type(b).__name__} — drop "
                        f"pattern_token to take the full conversion path"
                    )
            plan.report.cache_stats = cache.stats()
            if validate == "deep":
                _deep_verify(plan)
            return plan
        if a is None or b is None:
            raise KeyError(
                f"pattern_token {pattern_token!r} is not resident in the "
                f"plan cache and no operands were given to build from"
            )

    def bind_token(plan: SpGEMMPlan, key: Tuple) -> None:
        if token_key is None:
            return
        cache.token_bind(token_key, key)
        plan.report.pattern_token = str(pattern_token)

    if isinstance(a, BCSV) and isinstance(b, BCSR):
        if a.block_shape[1] != b.block_shape[0]:
            raise ValueError(
                f"block inner dims mismatch: {a.block_shape} vs {b.block_shape}"
            )
        tile3 = (a.block_shape[0], a.block_shape[1], b.block_shape[1])
        key = (_block_pattern_key(a, b), tile3, a.group, backend,
               shard_key) + out_key
        plan, hit = cache.get_or_build(
            key, lambda: SpGEMMPlan.from_blocks(
                a, b, backend=backend, pattern_key=key[0],
                mesh=mesh, mesh_axis=mesh_axis, output=output),
            # Disk tier (warm restart): rehydrate the persisted symbolic
            # artifacts with this call's packed blocks as the values.
            loader=lambda arrays, meta: _loaded_block_plan(
                arrays, meta, a, b, backend=backend, pattern_key=key[0],
                mesh=mesh, mesh_axis=mesh_axis, validate=validate,
                output=output),
        )
        bind_token(plan, key)
        plan.report.cache_stats = cache.stats()
        if hit:
            with plan._lock:
                plan.report.cache_hits += 1
                # Pattern-equal but possibly fresh values: rebind this
                # call's packed blocks so execute() without args is current
                # (device staging is lazy — execute pays H2D once).
                plan._a_blocks = a.blocks
                plan._b_blocks = b.blocks
                plan._a_dev = None
                plan._b_dev = None
        if validate == "deep":
            _deep_verify(plan)
        return plan

    bm, bk, bn = _normalize_tile(tile)
    # sum_duplicates already emits canonical row-major order.
    a_coo = to_coo(a).sum_duplicates()
    b_coo = to_coo(b).sum_duplicates()
    if a_coo.shape[1] != b_coo.shape[0]:
        raise ValueError(f"inner dims mismatch: {a_coo.shape} x {b_coo.shape}")
    # Value dtype is part of the key: a float64 request must not be served
    # (and silently downcast) by a float32-built plan.
    pattern = pattern_digest(
        a_coo.row, a_coo.col, b_coo.row, b_coo.col,
        meta=("coo", a_coo.shape, b_coo.shape,
              str(a_coo.val.dtype), str(b_coo.val.dtype)),
    )
    key = (pattern, (bm, bk, bn), group, backend, shard_key) + out_key

    def build() -> SpGEMMPlan:
        global _SCHEDULE_BUILDS
        a_bcsv, a_scatter = bcsv_from_coo(a_coo, (bm, bk), group)
        b_bcsr, b_scatter = bcsr_from_coo(b_coo, (bk, bn))
        schedule = build_spgemm_schedule(a_bcsv, b_bcsr)
        _SCHEDULE_BUILDS += 1
        report = _make_report(
            pattern, (bm, bk, bn), group, backend,
            (a_coo.shape[0], b_coo.shape[1]),
            a_coo.nnz, b_coo.nnz, a_bcsv.nnzb, b_bcsr.nnzb, schedule,
        )
        plan_cls, extra = _resolve_plan_cls(mesh, mesh_axis)
        return plan_cls(
            schedule=schedule,
            a_blocks=a_bcsv.blocks,
            b_blocks=b_bcsr.blocks,
            backend=backend,
            out_shape=(a_coo.shape[0], b_coo.shape[1]),
            report=report,
            a_scatter=a_scatter,
            b_scatter=b_scatter,
            a_pattern=a_coo,
            b_pattern=b_coo,
            output=output,
            **extra,
        )

    def load(arrays: dict, meta: dict) -> SpGEMMPlan:
        # Disk tier (warm restart): the symbolic artifacts come from the
        # store, the values from this call's (already canonicalized) COOs.
        plan = SpGEMMPlan.from_artifacts(
            arrays, meta, backend=backend, pattern_key=pattern,
            a_vals=a_coo.val, b_vals=b_coo.val,
            a_pattern=a_coo, b_pattern=b_coo,
            mesh=mesh, mesh_axis=mesh_axis, output=output,
        )
        if validate == "deep":
            _deep_verify(plan)
        return plan

    plan, hit = cache.get_or_build(key, build, loader=load)
    bind_token(plan, key)
    plan.report.cache_stats = cache.stats()
    if hit:
        with plan._lock:
            plan.report.cache_hits += 1
            # A cache hit may carry stale values from the previous caller;
            # the pattern matches by construction, so rebind this call's
            # values (device staging is lazy — execute pays H2D once).
            plan._a_blocks = plan._rebind(
                a_coo.val, plan._a_blocks, plan._a_scatter,
                plan.report.nnz_a, "a_vals", plan._a_shape, plan._a_dtype,
            )
            plan._a_dev = None
            plan._b_blocks = plan._rebind(
                b_coo.val, plan._b_blocks, plan._b_scatter,
                plan.report.nnz_b, "b_vals", plan._b_shape, plan._b_dtype,
            )
            plan._b_dev = None
    if validate == "deep":
        _deep_verify(plan)
    return plan


# ---------------------------------------------------------------------------
# Structural plan composition (the chaining layer)
#
# C's pattern is value-independent, so one plan's output *structure* fully
# determines the next plan's A-side input structure — no values, no COO
# conversion, no canonicalizing sort. These are the pieces that turn
# one-shot SpGEMM into device-resident chains (A @ B @ C, A^k): a plan's
# ``output_pattern()`` feeds ``plan_from_structural_pattern``, and
# ``execute_chain`` hands each stage's packed device values straight to the
# next stage's fused rebind/kernel/assembly jit.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StructuralPattern:
    """A CSR-shaped structural sparsity pattern, detached from any values.

    This is a plan's value-independent output structure
    (:meth:`SpGEMMPlan.output_pattern`) in the exact arrays the plan's
    results share — and the seed :func:`plan_from_structural_pattern`
    builds the next chained plan from. The pattern order (row-major,
    strictly ascending ``(row, col)``) is canonical COO order, which is
    what lets a previous stage's packed values bind positionally as the
    next stage's A values.
    """

    indptr: np.ndarray  # [m + 1] CSR row pointers
    indices: np.ndarray  # [nnz] int32 CSR column ids
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def rows(self) -> np.ndarray:
        """The expanded per-element row ids (canonical order)."""
        return np.repeat(
            np.arange(self.shape[0], dtype=np.int64), np.diff(self.indptr)
        )

    def to_coo(self, val=None, dtype=np.float32) -> COO:
        """The pattern as canonical COO; ``val=None`` fills placeholder
        zeros (chained plans bind real values per execute)."""
        if val is None:
            val = np.zeros(self.nnz, dtype)
        return COO(self.rows(), self.indices, val, self.shape)


def _check_chain_link(p: SpGEMMPlan, q: SpGEMMPlan, stage: int) -> None:
    """Stage ``stage + 1``'s A pattern must be stage ``stage``'s output
    pattern, elementwise — the positional-binding contract of
    :func:`execute_chain`."""
    if q._a_scatter is None or q._b_scatter is None:
        raise ValueError(
            f"chain stage {stage + 1} is not an element plan; chained "
            f"stages are built by plan_from_structural_pattern"
        )
    asm = p._active()
    pat = q.a_pattern
    if pat is None or tuple(pat.shape) != (p._m, p._n):
        got = None if pat is None else tuple(pat.shape)
        raise ValueError(
            f"chain stage {stage + 1}: A shape {got} != stage {stage} "
            f"output shape {(p._m, p._n)}"
        )
    if q.report.nnz_a != asm.nnz or not (
        np.array_equal(pat.col, asm.indices)
        and np.array_equal(
            np.bincount(pat.row, minlength=p._m), np.diff(asm.indptr)
        )
    ):
        raise ValueError(
            f"chain stage {stage + 1}: A pattern does not match stage "
            f"{stage}'s output pattern; build it from that plan's "
            f"output_pattern() (plan.then / plan_from_structural_pattern)"
        )


class SpGEMMChain:
    """An ordered composition of plans: ``A @ B1 @ B2 @ ...`` where stage
    ``s + 1``'s A pattern *is* stage ``s``'s structural output pattern
    (validated at construction). :meth:`execute` runs the whole chain with
    every intermediate staying device-resident — the only D2H transfer is
    the final result (single-device plans; sharded stages concatenate
    per-shard segments on host by design)."""

    def __init__(self, plans: Sequence[SpGEMMPlan]):
        plans = list(plans)
        if not plans:
            raise ValueError("a chain needs at least one plan")
        for s, (p, q) in enumerate(zip(plans, plans[1:])):
            _check_chain_link(p, q, s)
        self.plans = plans

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.plans[0]._m, self.plans[-1]._n)

    def then(self, b, **kwargs) -> "SpGEMMChain":
        """Extend the chain by one more operand (see
        :meth:`SpGEMMPlan.then`)."""
        return SpGEMMChain(
            self.plans + [self.plans[-1]._plan_next(b, **kwargs)]
        )

    def output_pattern(self) -> StructuralPattern:
        return self.plans[-1].output_pattern()

    def device_indptr(self):
        return self.plans[-1].device_indptr()

    def execute(self, a_vals=None, b_vals=None) -> CSR:
        """Run the chain; ``a_vals``/``b_vals`` are stage 1's operands
        (same contract as :meth:`SpGEMMPlan.execute`), later stages use
        their own staged B values."""
        return execute_chain(self.plans, a_vals=a_vals, b_vals=b_vals)

    __call__ = execute


def chain_plans(plans: Sequence[SpGEMMPlan]) -> SpGEMMChain:
    """Validate and wrap an ordered plan list as a :class:`SpGEMMChain`
    (each plan's A pattern must be its predecessor's output pattern)."""
    return SpGEMMChain(plans)


def execute_chain(plans, a_vals=None, b_vals=None) -> CSR:
    """Run ``A @ B1 @ B2 @ ...`` through a validated plan chain with
    intermediates device-resident.

    Stage 1 dispatches exactly like ``plans[0].execute`` but keeps its
    packed C values on device; every later stage consumes the previous
    packed values directly as its A values (active-map order is canonical
    element order, so the binding is positional) against its own staged B
    values — no intermediate CSR wrap, no host transfer, no re-staging.
    The final stage's values are materialized once and wrapped in its
    precomputed CSR structure. Bitwise-equal to executing each stage
    independently with a host round trip between them (same jits, same
    operand bits).

    ``plans`` is a :class:`SpGEMMChain` or a plan sequence (validated
    here when raw); ``a_vals``/``b_vals`` optionally rebind stage 1's
    operands.
    """
    if isinstance(plans, SpGEMMChain):
        plans = plans.plans
    else:
        plans = list(plans)
        if not plans:
            raise ValueError("a chain needs at least one plan")
        for s, (p, q) in enumerate(zip(plans, plans[1:])):
            _check_chain_link(p, q, s)
    packed = plans[0]._run_packed(a_vals, b_vals)
    for stage in plans[1:]:
        packed = stage._run_packed_chained(packed)
    last = plans[-1]
    if packed is None:
        return last._empty_csr()
    return last._wrap_packed(np.asarray(packed))


def plan_from_structural_pattern(
    c_pattern: StructuralPattern,
    b,
    *,
    tile: Union[int, Tuple[int, ...]] = 64,
    group: int = 4,
    backend: str = "auto",
    cache: Optional[PlanCache] = None,
    mesh: Optional[Mesh] = None,
    mesh_axis: Optional[str] = None,
    output: str = "block",
    validate: Optional[str] = None,
    dtype=np.float32,
) -> SpGEMMPlan:
    """Plan ``C @ b`` directly from a prior plan's structural output
    pattern — the chaining fast path.

    Where :func:`spgemm_plan` would convert C to COO and pay
    ``sum_duplicates``'s canonicalizing sort plus a digest over expanded
    row/col arrays, this builds the A-side COO *positionally* from the
    CSR pattern (already canonical by construction) and fingerprints the
    CSR arrays themselves. A values are zero placeholders — chained
    executes bind the previous stage's packed device values per run;
    ``dtype`` fixes the value dtype those stages flow at (it is part of
    the cache key, like every plan's value dtype).

    Chained plans get their own cache keys (a ``"chain"``-tagged digest)
    and the same two-tier :class:`~repro.spgemm.cache.PlanCache`
    persistence as any other plan — a warm restart rehydrates the whole
    chain from disk without re-running any symbolic phase.
    """
    backend = resolve_backend(backend)
    if validate not in (None, "deep"):
        raise ValueError(
            f"validate must be None or 'deep', got {validate!r}"
        )
    if output not in ("block", "compact"):
        raise ValueError(
            f"output must be 'block' or 'compact', got {output!r}"
        )
    if cache is None:
        cache = default_cache()
    bm, bk, bn = _normalize_tile(tile)
    b_coo = _canonical_coo(to_coo(b))
    if c_pattern.shape[1] != b_coo.shape[0]:
        raise ValueError(
            f"inner dims mismatch: {c_pattern.shape} x {b_coo.shape}"
        )
    a_coo = c_pattern.to_coo(dtype=dtype)
    shard_key = _mesh_key(mesh, mesh_axis)
    out_key = ("compact",) if output == "compact" else ()
    pattern = pattern_digest(
        c_pattern.indptr, c_pattern.indices, b_coo.row, b_coo.col,
        meta=("chain", c_pattern.shape, b_coo.shape,
              str(np.dtype(dtype)), str(b_coo.val.dtype)),
    )
    key = (pattern, (bm, bk, bn), group, backend, shard_key) + out_key
    with cache._lock:
        cache.stats.chain_lookups += 1

    def build() -> SpGEMMPlan:
        global _SCHEDULE_BUILDS
        a_bcsv, a_scatter = bcsv_from_coo(a_coo, (bm, bk), group)
        b_bcsr, b_scatter = bcsr_from_coo(b_coo, (bk, bn))
        schedule = build_spgemm_schedule(a_bcsv, b_bcsr)
        _SCHEDULE_BUILDS += 1
        report = _make_report(
            pattern, (bm, bk, bn), group, backend,
            (c_pattern.shape[0], b_coo.shape[1]),
            a_coo.nnz, b_coo.nnz, a_bcsv.nnzb, b_bcsr.nnzb, schedule,
        )
        plan_cls, extra = _resolve_plan_cls(mesh, mesh_axis)
        return plan_cls(
            schedule=schedule,
            a_blocks=a_bcsv.blocks,
            b_blocks=b_bcsr.blocks,
            backend=backend,
            out_shape=(c_pattern.shape[0], b_coo.shape[1]),
            report=report,
            a_scatter=a_scatter,
            b_scatter=b_scatter,
            a_pattern=a_coo,
            b_pattern=b_coo,
            output=output,
            **extra,
        )

    def load(arrays: dict, meta: dict) -> SpGEMMPlan:
        plan = SpGEMMPlan.from_artifacts(
            arrays, meta, backend=backend, pattern_key=pattern,
            a_vals=a_coo.val, b_vals=b_coo.val,
            a_pattern=a_coo, b_pattern=b_coo,
            mesh=mesh, mesh_axis=mesh_axis, output=output,
        )
        if validate == "deep":
            _deep_verify(plan)
        return plan

    plan, hit = cache.get_or_build(key, build, loader=load)
    plan.report.cache_stats = cache.stats()
    if hit:
        with plan._lock:
            plan.report.cache_hits += 1
            # Pattern-equal hit serving a possibly different B operand:
            # rebind this call's B values (blocks + the chained-stage
            # device copy) so both standalone and chained executes see
            # them. A-side placeholders are untouched — chain runs bind A
            # per execute, on device.
            plan._b_blocks = plan._rebind(
                b_coo.val, plan._b_blocks, plan._b_scatter,
                plan.report.nnz_b, "b_vals", plan._b_shape, plan._b_dtype,
            )
            plan._b_dev = None
            plan._b_vals_dev = None
            plan.b_pattern = b_coo
    if validate == "deep":
        _deep_verify(plan)
    return plan
