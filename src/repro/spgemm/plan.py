"""Plan/execute SpGEMM: amortize the paper's host pre-processing.

FSpGEMM's host-side claim (Sec. 4.3) is that CSV pre-processing "only needs
to be performed once". This module is that claim as an API, in the
descriptor/setup-execute shape of cuSPARSE-style two-phase SpGEMM and the
symbolic/numeric split of Nagasaka et al.:

* :func:`spgemm_plan` runs every amortizable step once — sparse-native
  format conversion (COO -> BCSV/BCSR with value-scatter indices), the
  symbolic block-Gustavson phase (C structure + static triple schedule),
  schedule padding, and device-array staging — and returns a
  :class:`SpGEMMPlan`.
* :meth:`SpGEMMPlan.execute` runs only the numeric phase: rebind fresh
  values into the packed block arrays, launch the scheduled kernel,
  assemble C sparsely. No symbolic work, no densification.
* Plans are cached process-wide (``repro.spgemm.cache``) keyed on
  ``(pattern hash, tile, group, backend)`` — the serving path where one
  sparsity pattern meets millions of fresh value sets pays the symbolic
  phase exactly once.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import SpGEMMSchedule, build_spgemm_schedule
from repro.kernels import ref
from repro.kernels.gustavson_spgemm import pad_schedule_arrays, spgemm_scheduled
from repro.sparse.convert import bcsr_from_coo, bcsv_from_coo, to_coo
from repro.sparse.formats import BCSR, BCSV, COO, CSR
from repro.spgemm.cache import PlanCache, default_cache, pattern_digest

__all__ = [
    "PlanReport",
    "SpGEMMPlan",
    "spgemm_plan",
    "resolve_backend",
    "schedule_build_count",
]

# Global count of symbolic-phase runs (schedule constructions). Tests and
# the acceptance criteria assert this stays flat across cached re-executes.
_SCHEDULE_BUILDS = 0


def schedule_build_count() -> int:
    return _SCHEDULE_BUILDS


def resolve_backend(backend: str = "auto") -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "pallas_interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


@dataclasses.dataclass
class PlanReport:
    """Structured statistics of one plan: what was built, what it costs,
    and how often it has been reused."""

    pattern_key: str
    tile: Tuple[int, int, int]
    group: int
    backend: str
    shape: Tuple[int, int]  # output C shape
    nnz_a: int
    nnz_b: int
    nnzb_a: int
    nnzb_b: int
    nnzb_c: int
    num_triples: int
    n_panels: int
    b_fetches: int
    block_omar: float
    # Lifecycle counters (mutable).
    schedule_builds: int = 1  # symbolic-phase runs for this plan (0 when a
    # pre-built schedule was supplied, else 1)
    cache_hits: int = 0  # times this plan was served from a PlanCache
    executes: int = 0  # numeric-phase runs

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class SpGEMMPlan:
    """A fully pre-processed SpGEMM: symbolic phase done, numeric phase
    repeatable with fresh values.

    Build through :func:`spgemm_plan` (cached) or
    :meth:`SpGEMMPlan.from_blocks` (explicit). ``execute`` / ``__call__``
    accept new value sets bound to the *same* sparsity pattern:

    * element plans (built from COO/CSR/dense inputs): ``a_vals`` is a
      ``[nnz_a]`` vector aligned with ``plan.a_pattern`` (canonical
      row-major deduplicated order), likewise ``b_vals``;
    * block plans (built from BCSV/BCSR inputs): ``a_vals`` is a packed
      ``[nnzb_a, bm, bk]`` block array, likewise ``b_vals``.

    Passing ``None`` reuses the values staged at build / last execute.
    """

    def __init__(
        self,
        *,
        schedule: SpGEMMSchedule,
        a_blocks: np.ndarray,
        b_blocks: np.ndarray,
        backend: str,
        out_shape: Tuple[int, int],
        report: PlanReport,
        a_scatter: Optional[np.ndarray] = None,
        b_scatter: Optional[np.ndarray] = None,
        a_pattern: Optional[COO] = None,
        b_pattern: Optional[COO] = None,
    ):
        self.schedule = schedule
        self.backend = backend
        self.report = report
        self.a_pattern = a_pattern
        self.b_pattern = b_pattern
        self._a_scatter = a_scatter
        self._b_scatter = b_scatter
        self._a_blocks: Optional[np.ndarray] = a_blocks
        self._b_blocks: Optional[np.ndarray] = b_blocks
        # Packed-array geometry survives release_values(): rebinds validate
        # against (and reallocate to) these.
        self._a_shape = tuple(a_blocks.shape)
        self._b_shape = tuple(b_blocks.shape)
        self._a_dtype = a_blocks.dtype
        self._b_dtype = b_blocks.dtype
        self._m, self._n = out_shape
        self._group = schedule.group
        self._bm = int(a_blocks.shape[1]) if a_blocks.ndim == 3 else 0
        self._bn = int(b_blocks.shape[2]) if b_blocks.ndim == 3 else 0
        # Device staging: pad once, ship the schedule to device once. The
        # jnp backend consumes the unpadded numpy schedule directly, so
        # only the Pallas backends pay for this.
        if schedule.num_triples and backend in ("pallas", "pallas_interpret"):
            a_slot, b_slot, panel, sub_row, start, _ = pad_schedule_arrays(
                schedule.a_slot, schedule.b_slot, schedule.panel,
                schedule.sub_row, schedule.start, schedule.n_panels,
            )
            self._dev_schedule = tuple(
                jnp.asarray(x) for x in (a_slot, b_slot, panel, sub_row, start)
            )
        else:
            self._dev_schedule = None
        # Device block values are staged lazily (first execute) so building
        # a plan never pays H2D for values that are immediately rebound.
        self._a_dev = None
        self._b_dev = None
        # Guards value rebinds + report counters: plans are shared objects
        # (PlanCache returns the same instance to every pattern-equal
        # caller), so concurrent executes must each see a consistent
        # (values, device array) pair.
        self._lock = threading.Lock()

    # -- construction -----------------------------------------------------

    @classmethod
    def from_blocks(
        cls,
        a: BCSV,
        b: BCSR,
        *,
        backend: str = "auto",
        schedule: Optional[SpGEMMSchedule] = None,
        pattern_key: str = "",
    ) -> "SpGEMMPlan":
        """Plan from pre-converted block formats (the ops.spgemm shim path).

        When ``schedule`` is supplied the symbolic phase is skipped entirely
        (and not counted as a build).
        """
        global _SCHEDULE_BUILDS
        backend = resolve_backend(backend)
        built = 0
        if schedule is None:
            schedule = build_spgemm_schedule(a, b)
            _SCHEDULE_BUILDS += 1
            built = 1
        if not pattern_key:
            pattern_key = _block_pattern_key(a, b)
        report = _make_report(
            pattern_key,
            (a.block_shape[0], a.block_shape[1], b.block_shape[1]),
            a.group, backend, (a.shape[0], b.shape[1]),
            int(np.count_nonzero(a.blocks)), int(np.count_nonzero(b.blocks)),
            a.nnzb, b.nnzb, schedule,
        )
        report.schedule_builds = built
        return cls(
            schedule=schedule,
            a_blocks=a.blocks,
            b_blocks=b.blocks,
            backend=backend,
            out_shape=(a.shape[0], b.shape[1]),
            report=report,
        )

    # -- numeric phase ----------------------------------------------------

    def _rebind(
        self,
        vals,
        blocks: Optional[np.ndarray],
        scatter: Optional[np.ndarray],
        nnz: int,
        name: str,
        shape: Tuple[int, ...],
        dtype,
    ) -> np.ndarray:
        vals = np.asarray(vals)
        if scatter is not None:
            if vals.shape != (nnz,):
                raise ValueError(
                    f"{name}: expected [{nnz}] values in canonical pattern "
                    f"order, got shape {vals.shape}"
                )
            if blocks is None:  # scratch was released; reallocate
                blocks = np.zeros(shape, dtype)
            # Positions outside `scatter` are structurally zero and never
            # written, so in-place rebinding is sound.
            blocks.reshape(-1)[scatter] = vals.astype(blocks.dtype, copy=False)
            return blocks
        if vals.shape != shape:
            raise ValueError(
                f"{name}: expected packed blocks of shape {shape}, "
                f"got {vals.shape}"
            )
        return vals

    def execute(self, a_vals=None, b_vals=None) -> CSR:
        """Numeric phase only: C = A @ B for fresh values on the planned
        pattern. Performs zero schedule-construction work."""
        with self._lock:
            if a_vals is not None:
                self._a_blocks = self._rebind(
                    a_vals, self._a_blocks, self._a_scatter,
                    self.report.nnz_a, "a_vals", self._a_shape, self._a_dtype,
                )
                self._a_dev = None
            if b_vals is not None:
                self._b_blocks = self._rebind(
                    b_vals, self._b_blocks, self._b_scatter,
                    self.report.nnz_b, "b_vals", self._b_shape, self._b_dtype,
                )
                self._b_dev = None
            if self._a_blocks is None or self._b_blocks is None:
                raise ValueError(
                    "plan values were released (release_values); pass "
                    "a_vals/b_vals to execute"
                )
            # copy=True: on CPU backends jnp.asarray may alias the numpy
            # scratch buffer, and a later rebind would mutate an earlier
            # caller's staged values mid-flight.
            if self._a_dev is None:
                self._a_dev = jnp.array(self._a_blocks, copy=True)
            if self._b_dev is None:
                self._b_dev = jnp.array(self._b_blocks, copy=True)
            # Snapshot under the lock so a concurrent rebind on this shared
            # plan cannot mix one caller's A with another's B.
            a_dev, b_dev = self._a_dev, self._b_dev
            self.report.executes += 1

        sch = self.schedule
        if sch.num_triples == 0:
            return CSR(
                np.zeros(self._m + 1, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (self._m, self._n),
            )
        if self.backend in ("pallas", "pallas_interpret"):
            a_slot, b_slot, panel, sub_row, start = self._dev_schedule
            panels = spgemm_scheduled(
                a_dev, b_dev,
                a_slot, b_slot, panel, sub_row, start,
                n_panels=sch.n_panels,
                group=self._group,
                interpret=(self.backend == "pallas_interpret"
                           or jax.default_backend() != "tpu"),
            )
        else:
            panels = ref.spgemm_scheduled_ref(
                a_dev, b_dev,
                sch.a_slot, sch.b_slot, sch.panel, sch.sub_row,
                sch.n_panels, self._group,
            )
        return self._assemble(np.asarray(panels))

    __call__ = execute

    def release_device_values(self) -> None:
        """Drop only the staged device copies of the packed block values.

        The next execute restages from the host arrays on demand.
        """
        with self._lock:
            self._a_dev = None
            self._b_dev = None

    def release_values(self) -> None:
        """Drop host AND device copies of the packed block values.

        Cached plans outlive individual calls; one-shot callers (the
        ``ops.spgemm`` shim) release values after executing so a warm
        cache pins only the pattern state (schedule, scatter indices,
        coordinates) — not operand-sized value arrays. After release,
        ``execute`` requires explicit ``a_vals``/``b_vals``.
        """
        with self._lock:
            self._a_dev = None
            self._b_dev = None
            self._a_blocks = None
            self._b_blocks = None

    def _assemble(self, panels: np.ndarray) -> CSR:
        """Scatter output panels into CSR sparsely (no dense C)."""
        sch = self.schedule
        rows_l, cols_l, vals_l = [], [], []
        span = self._group * self._bm
        for p in range(sch.n_panels):
            g = int(sch.panel_group[p])
            j = int(sch.panel_bcol[p])
            r0 = g * span
            sub = panels[p][: min(span, self._m - r0)]
            rr, cc = np.nonzero(sub)
            if rr.size == 0:
                continue
            rows_l.append(rr + r0)
            cols_l.append(cc + j * self._bn)
            vals_l.append(sub[rr, cc])
        if not rows_l:
            return CSR(
                np.zeros(self._m + 1, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (self._m, self._n),
            )
        coo = COO(
            np.concatenate(rows_l).astype(np.int32),
            np.concatenate(cols_l).astype(np.int32),
            np.concatenate(vals_l),
            (self._m, self._n),
        )
        return CSR.from_coo(coo)


def _make_report(
    pattern_key, tile, group, backend, shape, nnz_a, nnz_b, nnzb_a, nnzb_b,
    schedule: SpGEMMSchedule,
) -> PlanReport:
    return PlanReport(
        pattern_key=pattern_key,
        tile=tuple(tile),
        group=group,
        backend=backend,
        shape=shape,
        nnz_a=nnz_a,
        nnz_b=nnz_b,
        nnzb_a=nnzb_a,
        nnzb_b=nnzb_b,
        nnzb_c=schedule.nnzb_c,
        num_triples=schedule.num_triples,
        n_panels=schedule.n_panels,
        b_fetches=schedule.b_fetches(),
        block_omar=schedule.block_omar(),
    )


def _block_pattern_key(a: BCSV, b: BCSR) -> str:
    return pattern_digest(
        a.brow, a.bcol, a.group_ptr, b.indptr, b.indices,
        meta=("blocks", a.shape, b.shape, a.block_shape, b.block_shape,
              a.group, str(a.blocks.dtype), str(b.blocks.dtype)),
    )


def _normalize_tile(tile: Union[int, Tuple[int, ...]]) -> Tuple[int, int, int]:
    if isinstance(tile, int):
        return (tile, tile, tile)
    tile = tuple(int(t) for t in tile)
    if len(tile) == 2:
        return (tile[0], tile[1], tile[1])
    if len(tile) != 3:
        raise ValueError(f"tile must be int, (bm, bk) or (bm, bk, bn); got {tile}")
    return tile


PlanInput = Union[np.ndarray, COO, CSR, BCSV, BCSR]


def spgemm_plan(
    a,
    b,
    *,
    tile: Union[int, Tuple[int, ...]] = 64,
    group: int = 4,
    backend: str = "auto",
    cache: Optional[PlanCache] = None,
) -> SpGEMMPlan:
    """Build — or fetch from the plan cache — an :class:`SpGEMMPlan`.

    ``a``/``b`` may be dense arrays, any element-level sparse format
    (COO/CSR/CSC/CSV), or pre-converted BCSV/BCSR blocks (in which case
    ``tile``/``group`` are taken from the formats themselves). All symbolic
    work happens here, once per distinct ``(pattern, tile, group, backend)``.

    Pass ``cache=PlanCache(...)`` to isolate from the process-level cache.
    """
    global _SCHEDULE_BUILDS
    backend = resolve_backend(backend)
    if cache is None:
        cache = default_cache()

    if isinstance(a, BCSV) and isinstance(b, BCSR):
        if a.block_shape[1] != b.block_shape[0]:
            raise ValueError(
                f"block inner dims mismatch: {a.block_shape} vs {b.block_shape}"
            )
        tile3 = (a.block_shape[0], a.block_shape[1], b.block_shape[1])
        key = (_block_pattern_key(a, b), tile3, a.group, backend)
        plan, hit = cache.get_or_build(
            key, lambda: SpGEMMPlan.from_blocks(
                a, b, backend=backend, pattern_key=key[0])
        )
        if hit:
            with plan._lock:
                plan.report.cache_hits += 1
                # Pattern-equal but possibly fresh values: rebind this
                # call's packed blocks so execute() without args is current
                # (device staging is lazy — execute pays H2D once).
                plan._a_blocks = a.blocks
                plan._b_blocks = b.blocks
                plan._a_dev = None
                plan._b_dev = None
        return plan

    bm, bk, bn = _normalize_tile(tile)
    # sum_duplicates already emits canonical row-major order.
    a_coo = to_coo(a).sum_duplicates()
    b_coo = to_coo(b).sum_duplicates()
    if a_coo.shape[1] != b_coo.shape[0]:
        raise ValueError(f"inner dims mismatch: {a_coo.shape} x {b_coo.shape}")
    # Value dtype is part of the key: a float64 request must not be served
    # (and silently downcast) by a float32-built plan.
    pattern = pattern_digest(
        a_coo.row, a_coo.col, b_coo.row, b_coo.col,
        meta=("coo", a_coo.shape, b_coo.shape,
              str(a_coo.val.dtype), str(b_coo.val.dtype)),
    )
    key = (pattern, (bm, bk, bn), group, backend)

    def build() -> SpGEMMPlan:
        global _SCHEDULE_BUILDS
        a_bcsv, a_scatter = bcsv_from_coo(a_coo, (bm, bk), group)
        b_bcsr, b_scatter = bcsr_from_coo(b_coo, (bk, bn))
        schedule = build_spgemm_schedule(a_bcsv, b_bcsr)
        _SCHEDULE_BUILDS += 1
        report = _make_report(
            pattern, (bm, bk, bn), group, backend,
            (a_coo.shape[0], b_coo.shape[1]),
            a_coo.nnz, b_coo.nnz, a_bcsv.nnzb, b_bcsr.nnzb, schedule,
        )
        return SpGEMMPlan(
            schedule=schedule,
            a_blocks=a_bcsv.blocks,
            b_blocks=b_bcsr.blocks,
            backend=backend,
            out_shape=(a_coo.shape[0], b_coo.shape[1]),
            report=report,
            a_scatter=a_scatter,
            b_scatter=b_scatter,
            a_pattern=a_coo,
            b_pattern=b_coo,
        )

    plan, hit = cache.get_or_build(key, build)
    if hit:
        with plan._lock:
            plan.report.cache_hits += 1
            # A cache hit may carry stale values from the previous caller;
            # the pattern matches by construction, so rebind this call's
            # values (device staging is lazy — execute pays H2D once).
            plan._a_blocks = plan._rebind(
                a_coo.val, plan._a_blocks, plan._a_scatter,
                plan.report.nnz_a, "a_vals", plan._a_shape, plan._a_dtype,
            )
            plan._a_dev = None
            plan._b_blocks = plan._rebind(
                b_coo.val, plan._b_blocks, plan._b_scatter,
                plan.report.nnz_b, "b_vals", plan._b_shape, plan._b_dtype,
            )
            plan._b_dev = None
    return plan
