"""Paper Table 9 / Fig. 8: energy per SpGEMM.

E = R x avg power. No RAPL / nvidia-smi / board sensors exist here, so all
energies are modeled from runtimes x device power models, with the paper's
measured table reprinted; the reduction ratios are the reproduced claim.
"""
from __future__ import annotations

from repro.core.gustavson import gustavson_flops
from repro.core.perfmodel import (
    CPU_XEON_E5_2637,
    FPGA_ARRIA10,
    GPU_TITAN_X,
    PAPER_MATRICES,
    PAPER_TABLE7_MS,
    PAPER_TABLE9_J,
    energy,
)


def run(quiet: bool = False):
    print("energy,matrix,fpga_J(modeled),cpu_J(modeled),gpu_J(modeled),"
          "paper_mkl_J,paper_cusparse_J,paper_fspgemm_J")
    red_cpu, red_gpu, rows = [], [], []
    for name in PAPER_MATRICES:
        t = PAPER_TABLE7_MS[name]
        e_fpga = energy(t["fspgemm"] / 1e3, FPGA_ARRIA10)
        e_cpu = energy(t["mkl"] / 1e3, CPU_XEON_E5_2637)
        e_gpu = energy(t["cusparse"] / 1e3, GPU_TITAN_X)
        p = PAPER_TABLE9_J[name]
        red_cpu.append(p["mkl"] / p["fspgemm"])
        red_gpu.append(p["cusparse"] / p["fspgemm"])
        rows.append({
            "matrix": name, "fpga_J": e_fpga, "cpu_J": e_cpu,
            "gpu_J": e_gpu, "paper_mkl_J": p["mkl"],
            "paper_cusparse_J": p["cusparse"],
            "paper_fspgemm_J": p["fspgemm"],
        })
        print(f"energy,{name},{e_fpga:.3f},{e_cpu:.2f},{e_gpu:.2f},"
              f"{p['mkl']},{p['cusparse']},{p['fspgemm']}")
    print(f"energy,paper_avg_reduction_vs_cpu,{sum(red_cpu)/len(red_cpu):.1f}"
          f" (paper reports 31.9x)")
    print(f"energy,paper_avg_reduction_vs_gpu,{sum(red_gpu)/len(red_gpu):.1f}"
          f" (paper reports 13.1x)")
    return {
        "rows": rows,
        "avg_reduction_vs_cpu": sum(red_cpu) / len(red_cpu),
        "avg_reduction_vs_gpu": sum(red_gpu) / len(red_gpu),
    }


def main():
    return run()


if __name__ == "__main__":
    main()
