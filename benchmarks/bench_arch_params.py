"""Paper Sec. 4.2.4: analytical architectural-parameter derivation.

Validates the closed-form (SW, NUM_PE) against the paper's published
optimum on Arria 10 GX and reports the TPU re-target (bm, bk, bn, G tiles
under the VMEM capacity + lane-alignment constraints).
"""
from __future__ import annotations

from repro.core.tuning import (
    ARRIA10_GX,
    FPGASpec,
    TPU_V5E,
    derive_fpga_params,
    fpga_runtime_model,
    tpu_tile_params,
)


def run(quiet: bool = False):
    sw, num_pe = derive_fpga_params(ARRIA10_GX)
    print(f"arch_params,arria10_gx,SW={sw},NUM_PE={num_pe},"
          f"paper=(16,32),match={(sw, num_pe) == (16, 32)}")

    # Sensitivity: a board with 2x bandwidth doubles SW, halves NUM_PE
    # under the same logic budget (the paper's trade-off).
    fast = FPGASpec("2x-bw", 1518, 30.0, 236e6, 512.0, 1.0)
    sw2, pe2 = derive_fpga_params(fast)
    print(f"arch_params,2x_bandwidth_board,SW={sw2},NUM_PE={pe2}")

    # Runtime model at the optimum for a representative N_ops.
    r = fpga_runtime_model(2e9, ARRIA10_GX, stuf=3.4e-3 * 1518 / 512)
    print(f"arch_params,modeled_runtime_2GFLOP_ms,{r * 1e3:.1f}")

    bm, bk, bn, g = tpu_tile_params(TPU_V5E)
    print(f"arch_params,tpu_v5e_tiles,bm={bm},bk={bk},bn={bn},G={g}")
    vmem = (g * bm * bn * 4 + 2 * bk * bn * 4 + 2 * bm * bk * 4) / 2**20
    print(f"arch_params,tpu_v5e_vmem_MiB,{vmem:.1f} (budget "
          f"{TPU_V5E.vmem_bytes * 0.7 / 2**20:.1f})")
    return {
        "arria10_gx": {"SW": sw, "NUM_PE": num_pe,
                       "matches_paper": (sw, num_pe) == (16, 32)},
        "2x_bandwidth_board": {"SW": sw2, "NUM_PE": pe2},
        "modeled_runtime_2GFLOP_ms": r * 1e3,
        "tpu_v5e_tiles": {"bm": bm, "bk": bk, "bn": bn, "G": g},
        "tpu_v5e_vmem_MiB": vmem,
    }


def main():
    return run()


if __name__ == "__main__":
    main()
