"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Tuple


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time in seconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def fmt_row(*cols, widths=None) -> str:
    widths = widths or [18] * len(cols)
    return "  ".join(str(c)[: w].ljust(w) for c, w in zip(cols, widths))
