"""Compacted output size + device-resident chain throughput.

Two claims, both CI-gated through the record's ``ok`` flag:

* **Compact C is smaller**: on every (scaled) Table 4 matrix the
  element-exact ``output="compact"`` result carries fewer C bytes than
  the default block-structural CSR — the block result stores every
  element of every structurally nonzero tile, explicit padding zeros
  included, so any matrix whose pattern doesn't perfectly fill its
  tiles (all of them) must shrink.
* **Chains beat host round trips**: ``execute_chain`` over a composed
  A @ B @ C plan pair must deliver >= 1.2x the throughput of the
  pre-chaining workflow — execute stage 1, materialize the CSR on
  host, resolve stage 2 through ``spgemm_plan(c_result, ...)`` (a warm
  cache hit that still pays ``to_coo`` + canonicalization + the
  pattern digest + a host-side value rebind every iteration), execute
  stage 2. The chain skips all of it: stage 1's packed device values
  feed stage 2's fused rebind/kernel/assembly jit directly.

Results are bitwise-checked before timing.

``PYTHONPATH=src python -m benchmarks.bench_chain [--scale S]``
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.spgemm import PlanCache, spgemm_plan

# Smallest two Table 4 matrices at a CI-friendly scale; A @ A^T @ A like
# the paper's A @ A^T harness extended by one hop.
MATRICES = [("poisson3Da", 0.02), ("2cubes_sphere", 0.004)]

SPEEDUP_GATE = 1.2


def _operands(name: str, scale: float):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0])).sum_duplicates()
    return a, b


def _best_s(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _csr_bytes(csr) -> int:
    return int(csr.data.nbytes + np.asarray(csr.indices).nbytes
               + np.asarray(csr.indptr).nbytes)


def run(scale: float = 1.0, tile: int = 16, group: int = 2,
        backend: str = "jnp", repeats: int = 5, quiet: bool = False):
    rows = []
    for name, base_scale in MATRICES:
        a, b = _operands(name, base_scale * scale)
        cache = PlanCache()
        blk = spgemm_plan(a, b, tile=tile, group=group, backend=backend,
                          cache=cache)
        cmp_ = spgemm_plan(a, b, tile=tile, group=group, backend=backend,
                           cache=cache, output="compact")
        r_blk, r_cmp = blk.execute(), cmp_.execute()
        assert np.array_equal(r_blk.todense(), r_cmp.todense())
        block_bytes, compact_bytes = _csr_bytes(r_blk), _csr_bytes(r_cmp)

        # Chained A @ B @ A (3-stage product) vs the host round trip.
        chain = cmp_.then(a, cache=cache)

        def round_trip():
            r = cmp_.execute()
            p2 = spgemm_plan(r, a, tile=tile, group=group, backend=backend,
                             cache=cache, output="compact")
            return p2.execute()

        out_chain = chain.execute()
        out_rt = round_trip()
        assert np.array_equal(np.asarray(out_chain.data),
                              np.asarray(out_rt.data))
        chain_s = _best_s(chain.execute, repeats)
        rt_s = _best_s(round_trip, repeats)
        values = int(out_chain.data.size)
        speedup = rt_s / chain_s if chain_s else float("inf")
        ok = compact_bytes < block_bytes and speedup >= SPEEDUP_GATE
        rows.append({
            "matrix": name,
            "nnz_a": int(a.nnz),
            "block_nnz_c": int(r_blk.data.size),
            "compact_nnz_c": int(r_cmp.data.size),
            "block_c_bytes": block_bytes,
            "compact_c_bytes": compact_bytes,
            "bytes_ratio": compact_bytes / block_bytes,
            "chain_ms": chain_s * 1e3,
            "round_trip_ms": rt_s * 1e3,
            "chain_values_per_s": values / chain_s if chain_s else None,
            "round_trip_values_per_s": values / rt_s if rt_s else None,
            "chain_speedup": speedup,
            "ok": ok,
        })
    ok = all(r["ok"] for r in rows)
    if not quiet:
        print("matrix,block_nnz,compact_nnz,bytes_ratio,"
              "chain_ms,round_trip_ms,speedup")
        for r in rows:
            print(f"{r['matrix']},{r['block_nnz_c']},{r['compact_nnz_c']},"
                  f"{r['bytes_ratio']:.2f},{r['chain_ms']:.2f},"
                  f"{r['round_trip_ms']:.2f},{r['chain_speedup']:.2f}")
        print(f"ok={ok} (gate: compact C bytes < block C bytes and chain "
              f">= {SPEEDUP_GATE}x round-trip)")
    return {"rows": rows, "ok": ok}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="extra scale factor on the per-matrix defaults")
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    return run(scale=args.scale, tile=args.tile, group=args.group,
               backend=args.backend, repeats=args.repeats)


if __name__ == "__main__":
    main()
