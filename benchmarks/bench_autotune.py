"""Per-pattern autotuner: tuned-vs-default throughput on paper matrices.

Runs :func:`repro.spgemm.autotune.autotune_plan` on (scaled) Table 4
matrices and reports measured ``values_per_s`` for the winning config
against the requested default — the autotuner's value proposition in one
table — plus the model-vs-measured ranking agreement (how much of the
candidate grid the roofline pruning can safely discard on this host).

Because the default config is force-included in the measured survivors,
the tuned config can never be meaningfully *slower* than the default; CI
gates on ``ok`` = every row's speedup >= 0.95 (slack for probe jitter on
shared runners).

``PYTHONPATH=src python -m benchmarks.bench_autotune [--scale S]``
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.sparse.formats import COO
from repro.sparse.random import suite_matrix
from repro.spgemm import PlanCache
from repro.spgemm.autotune import autotune_plan, probe_run_count

# Smallest two Table 4 matrices at a CI-friendly scale; A @ A^T like the
# paper's benchmark harness.
MATRICES = [("poisson3Da", 0.02), ("2cubes_sphere", 0.004)]

# Tuned throughput must not regress past probe jitter on a shared host.
MIN_SPEEDUP = 0.95


def _pattern(name: str, scale: float):
    a = suite_matrix(name, scale=scale).to_coo().sum_duplicates()
    rng = np.random.default_rng(11)
    v = rng.integers(-4, 5, a.nnz).astype(np.float32)
    a.val = np.where(v == 0, np.float32(1.0), v)
    b = COO(a.col, a.row, a.val, (a.shape[1], a.shape[0]))
    return a, b


def run(scale: float = 1.0, tile: int = 16, group: int = 2,
        backend: str = "jnp", repeats: int = 3, quiet: bool = False):
    rows = []
    for name, base_scale in MATRICES:
        a, b = _pattern(name, base_scale * scale)
        before = probe_run_count()
        plan = autotune_plan(
            a, b, tile=tile, group=group, backend=backend,
            cache=PlanCache(), model_top_k=2, probe_batch=4,
            repeats=repeats, depth_candidates=(1, 2, 4),
        )
        cfg = plan.tuned_config
        rows.append({
            "matrix": name,
            "shape": list(a.shape),
            "nnz": int(a.nnz),
            "default_tile": tile,
            "default_group": group,
            "tuned_tile": list(cfg.tile),
            "tuned_group": cfg.group,
            "tuned_chunk_bytes": cfg.chunk_bytes,
            "tuned_depth": cfg.pipeline_depth,
            "default_values_per_s": cfg.default_values_per_s,
            "tuned_values_per_s": cfg.values_per_s,
            "speedup": cfg.speedup,
            "model_rank": cfg.model_rank,
            "ranking_agreement": cfg.ranking_agreement,
            "probes": probe_run_count() - before,
        })
    ok = all(r["speedup"] >= MIN_SPEEDUP for r in rows)
    if not quiet:
        print("matrix,nnz,tuned_tile,tuned_group,chunk_bytes,depth,"
              "default_vps,tuned_vps,speedup,model_rank,agreement,probes")
        for r in rows:
            print(f"{r['matrix']},{r['nnz']},"
                  f"{'x'.join(str(t) for t in r['tuned_tile'])},"
                  f"{r['tuned_group']},{r['tuned_chunk_bytes']},"
                  f"{r['tuned_depth']},{r['default_values_per_s']:.1f},"
                  f"{r['tuned_values_per_s']:.1f},{r['speedup']:.2f},"
                  f"{r['model_rank']},{r['ranking_agreement']:.2f},"
                  f"{r['probes']}")
        print(f"ok={ok} (gate: every speedup >= {MIN_SPEEDUP})")
    return {"rows": rows, "ok": ok, "min_speedup_gate": MIN_SPEEDUP}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="extra scale factor on the per-matrix defaults")
    ap.add_argument("--tile", type=int, default=16)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    return run(scale=args.scale, tile=args.tile, group=args.group,
               backend=args.backend, repeats=args.repeats)


if __name__ == "__main__":
    main()
