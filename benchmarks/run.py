"""Benchmark driver: one section per paper table/figure + the roofline
report. ``PYTHONPATH=src python -m benchmarks.run``

Each section writes a machine-readable ``BENCH_<slug>.json`` next to its
stdout report (default ``benchmarks/out/``, override with ``--out-dir``)
so the perf trajectory is tracked across PRs: the payload carries the
section's returned rows/dict (``data``), wall time, and ok/error status.

Exits nonzero when any section fails so CI can gate on it."""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
import traceback

# XLA_FLAGS is read once at backend init, so the opt-in GPU preset must be
# merged before anything below pulls in jax (xla_flags itself is jax-free).
from repro.launch.xla_flags import maybe_apply_gpu_xla_flags

maybe_apply_gpu_xla_flags()

from benchmarks import (
    bench_arch_params,
    bench_autotune,
    bench_chain,
    bench_chunk_knee,
    bench_energy,
    bench_gateway,
    bench_kernels,
    bench_omar,
    bench_runtime,
    bench_stuf,
    bench_verify,
    roofline,
)

SECTIONS = [
    ("Fig 6 — OMAR vs NUM_PE", bench_omar.main),
    ("Table 7 — runtime", bench_runtime.main),
    ("Table 8 — STUF", bench_stuf.main),
    ("Table 9 / Fig 8 — energy", bench_energy.main),
    ("Sec 4.2.4 — architectural parameters", bench_arch_params.main),
    # --devices 4: the sharded-plan section runs in a forced-host-device
    # subprocess (per-shard imbalance + values/s scaling vs 1 device).
    # --pipeline-depth: the async-serving streaming section (pipelined
    # steps/s vs synchronous at depths 1/2/4).
    ("Kernel schedule metrics",
     lambda: bench_kernels.main(
         ["--devices", "4", "--pipeline-depth", "1,2,4"])),
    # Measures the fused-vs-split run_batch knee on this host and reports
    # it against the configured _CHUNK_POLICY row (the policy's data
    # source; see repro.core.tuning.measure_chunk_knee).
    ("Chunk-fusion knee calibration",
     lambda: bench_chunk_knee.main(["--repeats", "2"])),
    # Tuned-vs-default values/s on paper matrices (+ model agreement);
    # the record's "ok" flag is the CI gate: tuned >= 0.95x default.
    ("Autotune", lambda: bench_autotune.main(["--repeats", "2"])),
    ("Gateway serving — throughput/latency", bench_gateway.main),
    # Compact-vs-block C bytes + chained A@B@A vs host round trip; the
    # record's "ok" gate: compact bytes < block bytes and chain >= 1.2x.
    ("Chain", lambda: bench_chain.main(["--repeats", "2"])),
    # Static-verifier cost: verify_plan + kernel lint timed against the
    # symbolic build they guard (the validate="deep" tax).
    ("Verify", lambda: bench_verify.main(["--repeats", "2"])),
    ("Roofline (from dry-run artifacts)", roofline.main),
]


def _slug(title: str) -> str:
    """'Table 7 — runtime' -> 'table_7_runtime' (filename-safe)."""
    return re.sub(r"_+", "_", re.sub(r"[^a-z0-9]+", "_", title.lower())).strip("_")


def _jsonable(obj):
    """Best-effort JSON coercion: numpy scalars/arrays, tuples, dataclass
    reprs — anything stranger degrades to str rather than failing the
    section after it already ran."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and callable(obj.item):  # numpy scalar
        try:
            return obj.item()
        except Exception:
            pass
    if hasattr(obj, "tolist") and callable(obj.tolist):  # numpy array
        try:
            return obj.tolist()
        except Exception:
            pass
    return str(obj)


_EPILOG = """\
environment:
  REPRO_GPU_XLA_FLAGS=1   merge the GPU latency-hiding/pipelining XLA_FLAGS
                          preset (repro.launch.xla_flags) before jax starts;
                          flags you already set in XLA_FLAGS win. No-op on
                          CPU/TPU and by default.
  REPRO_SPGEMM_CHUNK_BYTES=<n>  override the per-set batch-fusion budget
                          measured by the chunk-knee calibration section.
"""


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--out-dir", default=os.path.join("benchmarks", "out"),
                    help="directory for BENCH_<section>.json artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on section titles")
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    failures = []
    for title, fn in SECTIONS:
        if args.only and args.only.lower() not in title.lower():
            continue
        print(f"\n=== {title} " + "=" * max(1, 60 - len(title)))
        rec = {"section": title, "ok": True, "elapsed_s": None,
               "data": None, "error": None,
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z")}
        t0 = time.perf_counter()
        try:
            rec["data"] = _jsonable(fn())
        except Exception as e:
            failures.append(title)
            rec["ok"] = False
            rec["error"] = f"{type(e).__name__}: {e}"
            print(f"SECTION FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
        rec["elapsed_s"] = time.perf_counter() - t0
        path = os.path.join(args.out_dir, f"BENCH_{_slug(title)}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[bench] wrote {path} ({rec['elapsed_s']:.1f}s)")
    print("\n=== benchmarks done"
          + (f" ({len(failures)} section(s) failed: {failures})"
             if failures else " (all sections passed)"))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
