"""Benchmark driver: one section per paper table/figure + the roofline
report. ``PYTHONPATH=src python -m benchmarks.run``

Exits nonzero when any section fails so CI can gate on it."""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    bench_arch_params,
    bench_energy,
    bench_kernels,
    bench_omar,
    bench_runtime,
    bench_stuf,
    roofline,
)

SECTIONS = [
    ("Fig 6 — OMAR vs NUM_PE", bench_omar.main),
    ("Table 7 — runtime", bench_runtime.main),
    ("Table 8 — STUF", bench_stuf.main),
    ("Table 9 / Fig 8 — energy", bench_energy.main),
    ("Sec 4.2.4 — architectural parameters", bench_arch_params.main),
    # --devices 4: the sharded-plan section runs in a forced-host-device
    # subprocess (per-shard imbalance + values/s scaling vs 1 device).
    # --pipeline-depth: the async-serving streaming section (pipelined
    # steps/s vs synchronous at depths 1/2/4).
    ("Kernel schedule metrics",
     lambda: bench_kernels.main(
         ["--devices", "4", "--pipeline-depth", "1,2,4"])),
    ("Roofline (from dry-run artifacts)", roofline.main),
]


def main() -> None:
    failures = []
    for title, fn in SECTIONS:
        print(f"\n=== {title} " + "=" * max(1, 60 - len(title)))
        try:
            fn()
        except Exception as e:
            failures.append(title)
            print(f"SECTION FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    print("\n=== benchmarks done"
          + (f" ({len(failures)} section(s) failed: {failures})"
             if failures else " (all sections passed)"))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
