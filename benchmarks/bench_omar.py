"""Paper Fig. 6: OMAR (%) vs NUM_PE for the 8 SuiteSparse matrices.

Synthetic matrices carry the published dimensions/densities (Table 4) at
FULL scale — OMAR only sorts index arrays, so the complete matrices are
cheap. The reproduced claim is Fig. 6's shape: monotone improvement with
NUM_PE within the published bands (exact per-matrix values depend on the
true sparsity patterns, which the synthetic structure classes approximate).
"""
from __future__ import annotations

from repro.core.buffering import omar
from repro.core.perfmodel import PAPER_MATRICES
from repro.sparse.random import suite_matrix

# Paper Sec. 5.2's reported OMAR bands per PE count.
PAPER_BANDS = {2: (1.7, 24.8), 4: (6.0, 38.6), 8: (15.9, 46.5),
               16: (28.1, 51.3), 32: (39.2, 54.0)}

PE_COUNTS = (2, 4, 8, 16, 32)


def run(scale: float = 1.0, quiet: bool = False):
    rows = []
    for name in PAPER_MATRICES:
        a = suite_matrix(name, scale=scale)
        vals = {pe: omar(a, pe) for pe in PE_COUNTS}
        rows.append((name, vals))
        if not quiet:
            cells = " ".join(f"{vals[pe]:5.1f}" for pe in PE_COUNTS)
            print(f"omar,{name},{cells}")
    # Monotonicity claim (Fig. 6)
    mono = all(
        all(v[a] <= v[b] for a, b in zip(PE_COUNTS, PE_COUNTS[1:]))
        for _, v in rows
    )
    if not quiet:
        print(f"omar,monotone_in_num_pe,{mono}")
        for pe, (lo, hi) in PAPER_BANDS.items():
            got = [v[pe] for _, v in rows]
            print(f"omar,band_pe{pe},paper=[{lo},{hi}],"
                  f"ours=[{min(got):.1f},{max(got):.1f}]")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
